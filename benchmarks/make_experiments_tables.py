"""Regenerate the EXPERIMENTS.md roofline/dry-run tables from
results/*.jsonl (run after a sweep)."""
from __future__ import annotations

import json


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return recs


def dryrun_table(recs, mesh):
    seen = {}
    for r in recs:
        if r.get("mesh") == mesh:
            seen[(r["arch"], r["shape"])] = r   # last wins
    lines = ["| arch | shape | status | chips | compile_s | args GB | temp GB"
             " | fits 16GB | collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(seen.items()):
        if r["status"] != "OK":
            lines.append(f"| {a} | {s} | {r['status']} | | | | | | |")
            continue
        m = r["mem"]
        cc = r["hlo"]["collective_counts"]
        cstr = " ".join(f"{k.split('-')[-1]}:{int(v)}" for k, v in
                        sorted(cc.items()))
        lines.append(
            f"| {a} | {s} | OK | {r['n_chips']} | {r['compile_s']} | "
            f"{m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} | "
            f"{'Y' if m['fits_16GB'] else 'N'} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs):
    seen = {}
    for r in recs:
        if r.get("mesh") == "single":
            seen[(r["arch"], r["shape"])] = r
    lines = ["| arch | shape | compute_s | memory_s | collective_s | dominant"
             " | 6ND/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(seen.items()):
        if r["status"] != "OK":
            lines.append(f"| {a} | {s} | {r['status']} | | | | | |")
            continue
        t = r["terms_s"]
        lines.append(
            f"| {a} | {s} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load("results/dryrun.jsonl")
    print("## single-pod dry-run\n")
    print(dryrun_table(recs, "single"))
    print("\n## multi-pod dry-run\n")
    print(dryrun_table(recs, "multi"))
    print("\n## roofline (single-pod)\n")
    print(roofline_table(recs))
