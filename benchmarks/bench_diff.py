"""Perf-trajectory regression gate: diff two BENCH_<tag>.json files.

    python benchmarks/bench_diff.py BENCH_baseline.json BENCH_ci.json

Compares the machine-readable perf trajectory written by
``benchmarks/run.py`` — modeled tokens/s per schedule, planner decisions
(per-layer TMP plans, joint PP x TMP, serving latency meshes) — against
the checked-in baseline and exits non-zero on ANY deviation beyond the
tolerance: numeric drift in either direction (the numbers are modeled and
deterministic, so a silent change means a cost-model edit nobody pinned)
and exact mismatches for planner decisions.

Provenance is part of the contract: a ``--dry-run`` candidate diffed
against a full-run baseline (or vice versa) compares files that exercised
different code paths, so mismatched ``dry_run`` flags fail loudly instead
of being skipped.  The CI modeled smoke passes ``--modeled-only``, which
skips the measured section AND the provenance check — the modeled numbers
are deterministic under both provenances, which is exactly why they can
be gated from a dry run.

The ``measured`` section holds wall-clock numbers, which are
host-dependent: it is diffed under its own looser ``--measured-tol`` and
its host/calibration metadata is never diffed.  The measured gate that
matters is single-file:

    python benchmarks/bench_diff.py --ranking BENCH_measured_ci.json

checks that the cost model's RANKING of the measured grid points agrees
with the wall clock's ranking (absolute numbers may differ; ordering must
not — this is the loop that stops the modeled perf gate from grading its
own homework).  An order flip only counts when both the modeled and the
measured relative gaps exceed ``--rank-margin`` (default 25%): pairs
that either view calls closer than that carry no ordering signal on a
time-shared CPU core (within-config schedule wall clock swings tens of
percent run-to-run there), while real schedule gaps on accelerator
hosts and the grid's ~2x cross-config FLOPs spread clear the margin
easily.

To move the baseline deliberately (an intentional cost-model or planner
change), regenerate it in the same PR:

    PYTHONPATH=src python benchmarks/run.py --tag baseline
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys

# run metadata, not perf trajectory (dry_run is deliberately NOT here:
# provenance mismatches are errors, see module docstring)
SKIP_KEYS = {"tag", "time"}
# measured-section metadata that legitimately differs across hosts
MEASURED_SKIP_KEYS = {"host", "hw_calibrated", "iters"}


def _walk(base, new, path, tol, errors, skip=SKIP_KEYS):
    if isinstance(base, dict):
        if not isinstance(new, dict):
            errors.append(f"{path}: shape changed ({type(new).__name__})")
            return
        for k, v in base.items():
            if k in skip and not path:
                continue
            if k not in new:
                errors.append(f"{path}/{k}: missing from candidate")
                continue
            _walk(v, new[k], f"{path}/{k}", tol, errors, skip)
        for k in new:
            if k not in base and not (k in skip and not path):
                errors.append(f"{path}/{k}: new key absent from baseline "
                              f"(regenerate BENCH_baseline.json)")
    elif isinstance(base, list):
        if not isinstance(new, list) or len(new) != len(base):
            errors.append(f"{path}: list changed shape "
                          f"({base!r} -> {new!r})")
            return
        for i, (bv, nv) in enumerate(zip(base, new)):
            _walk(bv, nv, f"{path}[{i}]", tol, errors, skip)
    elif isinstance(base, bool) or not isinstance(base, (int, float)):
        if base != new:
            errors.append(f"{path}: {base!r} -> {new!r}")
    else:
        if not isinstance(new, (int, float)) or isinstance(new, bool):
            errors.append(f"{path}: {base!r} -> {new!r}")
            return
        denom = max(abs(float(base)), 1e-12)
        rel = abs(float(new) - float(base)) / denom
        if rel > tol:
            errors.append(f"{path}: {base} -> {new} "
                          f"(rel drift {rel:.1%} > tol {tol:.1%})")


def diff(base: dict, new: dict, *, tol: float, measured_tol: float,
         modeled_only: bool) -> list:
    """All deviations between two BENCH dicts (empty list = pass)."""
    errors: list = []
    if not modeled_only and base.get("dry_run") != new.get("dry_run"):
        errors.append(
            f"provenance mismatch: baseline dry_run="
            f"{base.get('dry_run')!r} vs candidate dry_run="
            f"{new.get('dry_run')!r} — these files exercised different "
            f"code paths.  Diff modeled sections only with "
            f"--modeled-only, or regenerate both the same way.")
    base_m = base.get("measured")
    new_m = new.get("measured")
    # dry_run is owned by the provenance check above, measured by the
    # loose-tolerance walk below
    base = {k: v for k, v in base.items()
            if k not in ("measured", "dry_run")}
    new = {k: v for k, v in new.items()
           if k not in ("measured", "dry_run")}
    _walk(base, new, "", tol, errors)
    if not modeled_only and (base_m is not None or new_m is not None):
        if base_m is None or new_m is None:
            errors.append("measured: present in only one file "
                          "(use --modeled-only to skip it)")
        else:
            # wall-clock numbers drift across hosts and runs — diff the
            # structure exactly but the numbers under the loose tolerance
            _walk(_strip_measured(base_m), _strip_measured(new_m),
                  "/measured", measured_tol, errors,
                  skip=MEASURED_SKIP_KEYS)
    return errors


def _strip_measured(section):
    if not isinstance(section, dict):
        return section
    return {k: v for k, v in section.items()
            if k not in MEASURED_SKIP_KEYS}


def check_ranking(bench: dict, *, margin: float) -> list:
    """Modeled-vs-measured ranking disagreements in one BENCH file.

    For every pair of measured grid points, the cost model and the wall
    clock must order them the same way.  A flip only counts when BOTH
    relative gaps exceed ``margin`` — points the model calls a near-tie
    (or the clock measures as one) carry no ordering signal on a shared
    core.
    """
    errors: list = []
    section = bench.get("measured")
    if not isinstance(section, dict) or "points" not in section:
        errors.append("no measured section with points — run "
                      "benchmarks/run.py WITHOUT --dry-run to produce one")
        return errors
    pts = section["points"]
    if len(pts) < 2:
        errors.append(f"measured section has {len(pts)} point(s); "
                      f"ranking needs at least 2")
        return errors
    for a, b in itertools.combinations(pts, 2):
        try:
            ma, mb = float(a["modeled_tok_s"]), float(b["modeled_tok_s"])
            wa, wb = float(a["measured_tok_s"]), float(b["measured_tok_s"])
        except (KeyError, TypeError, ValueError):
            errors.append(f"malformed point pair {a.get('key')} / "
                          f"{b.get('key')}")
            continue
        gap_model = abs(ma - mb) / max(min(ma, mb), 1e-12)
        gap_meas = abs(wa - wb) / max(min(wa, wb), 1e-12)
        if gap_model <= margin or gap_meas <= margin:
            continue  # a near-tie on either axis has no ordering signal
        if (ma > mb) != (wa > wb):
            errors.append(
                f"ranking flip: model says {a['key']} "
                f"{'>' if ma > mb else '<'} {b['key']} "
                f"({ma:.0f} vs {mb:.0f} tok/s, gap {gap_model:.0%}) but "
                f"wall clock says the opposite "
                f"({wa:.0f} vs {wb:.0f} tok/s, gap {gap_meas:.0%})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate", nargs="?",
                    help="omit with --ranking (single-file mode)")
    ap.add_argument("--tol", type=float, default=0.02,
                    help="relative tolerance for modeled numeric drift "
                         "(default 2%%; the numbers are modeled, so this "
                         "only absorbs solver/library jitter)")
    ap.add_argument("--measured-tol", type=float, default=0.5,
                    help="relative tolerance for the measured (wall-"
                         "clock) section (default 50%%; host-dependent)")
    ap.add_argument("--modeled-only", action="store_true",
                    help="diff modeled sections only: skip the measured "
                         "section and the dry_run provenance check (the "
                         "CI modeled smoke diffs a --dry-run candidate "
                         "against the full-run baseline)")
    ap.add_argument("--ranking", action="store_true",
                    help="single-file mode: check that the modeled "
                         "ranking of the measured grid agrees with the "
                         "wall-clock ranking")
    ap.add_argument("--rank-margin", type=float, default=0.25,
                    help="--ranking: an order flip only counts when both "
                         "relative gaps exceed this (default 25%% — "
                         "below it, a pair is a tie with no ordering "
                         "signal)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)

    if args.ranking:
        if args.candidate:
            ap.error("--ranking takes a single BENCH file")
        errors = check_ranking(base, margin=args.rank_margin)
        if errors:
            print(f"MODELED-VS-MEASURED RANKING DISAGREEMENT in "
                  f"{args.baseline} ({len(errors)} problem(s)):")
            for e in errors:
                print(f"  {e}")
            print("The cost model mis-orders schedules the hardware can "
                  "measure — fix the model (or the measurement) before "
                  "trusting the modeled gates.")
            return 1
        n = len(base["measured"]["points"])
        print(f"ranking OK: modeled ordering agrees with measured "
              f"ordering across {n} points "
              f"(margin {args.rank_margin:.0%})")
        return 0

    if not args.candidate:
        ap.error("two files required (or --ranking for single-file mode)")
    with open(args.candidate) as f:
        new = json.load(f)
    errors = diff(base, new, tol=args.tol,
                  measured_tol=args.measured_tol,
                  modeled_only=args.modeled_only)
    if errors:
        print(f"PERF TRAJECTORY REGRESSION vs {args.baseline} "
              f"({len(errors)} deviation(s)):")
        for e in errors:
            print(f"  {e}")
        print("If intentional, regenerate the baseline in this PR:\n"
              "  PYTHONPATH=src python benchmarks/run.py --tag baseline")
        return 1
    what = "modeled sections" if args.modeled_only else "trajectory"
    print(f"perf {what} OK: {args.candidate} matches {args.baseline} "
          f"within {args.tol:.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
