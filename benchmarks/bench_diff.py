"""Perf-trajectory regression gate: diff two BENCH_<tag>.json files.

    python benchmarks/bench_diff.py BENCH_baseline.json BENCH_ci.json

Compares the machine-readable perf trajectory written by
``benchmarks/run.py`` — modeled tokens/s per schedule, planner decisions
(per-layer TMP plans, joint PP x TMP, serving latency meshes) — against
the checked-in baseline and exits non-zero on ANY deviation beyond the
tolerance: numeric drift in either direction (the numbers are modeled and
deterministic, so a silent change means a cost-model edit nobody pinned)
and exact mismatches for planner decisions.

To move the baseline deliberately (an intentional cost-model or planner
change), regenerate it in the same PR:

    PYTHONPATH=src python benchmarks/run.py --dry-run --tag baseline
"""
from __future__ import annotations

import argparse
import json
import sys

# run metadata, not perf trajectory
SKIP_KEYS = {"tag", "time", "dry_run"}


def _walk(base, new, path, tol, errors):
    if isinstance(base, dict):
        if not isinstance(new, dict):
            errors.append(f"{path}: shape changed ({type(new).__name__})")
            return
        for k, v in base.items():
            if k in SKIP_KEYS and not path:
                continue
            if k not in new:
                errors.append(f"{path}/{k}: missing from candidate")
                continue
            _walk(v, new[k], f"{path}/{k}", tol, errors)
        for k in new:
            if k not in base and not (k in SKIP_KEYS and not path):
                errors.append(f"{path}/{k}: new key absent from baseline "
                              f"(regenerate BENCH_baseline.json)")
    elif isinstance(base, bool) or not isinstance(base, (int, float)):
        if base != new:
            errors.append(f"{path}: {base!r} -> {new!r}")
    else:
        if not isinstance(new, (int, float)) or isinstance(new, bool):
            errors.append(f"{path}: {base!r} -> {new!r}")
            return
        denom = max(abs(float(base)), 1e-12)
        rel = abs(float(new) - float(base)) / denom
        if rel > tol:
            errors.append(f"{path}: {base} -> {new} "
                          f"(rel drift {rel:.1%} > tol {tol:.1%})")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--tol", type=float, default=0.02,
                    help="relative tolerance for numeric drift (default "
                         "2%%; the numbers are modeled, so this only "
                         "absorbs solver/library jitter)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        new = json.load(f)
    errors: list = []
    _walk(base, new, "", args.tol, errors)
    if errors:
        print(f"PERF TRAJECTORY REGRESSION vs {args.baseline} "
              f"({len(errors)} deviation(s)):")
        for e in errors:
            print(f"  {e}")
        print("If intentional, regenerate the baseline in this PR:\n"
              "  PYTHONPATH=src python benchmarks/run.py --dry-run "
              "--tag baseline")
        return 1
    print(f"perf trajectory OK: {args.candidate} matches {args.baseline} "
          f"within {args.tol:.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
