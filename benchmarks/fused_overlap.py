"""Microbenchmark: the blocked-AllReduce path under each overlap backend.

A stack of row-parallel matmul "layers" (the exact shape every TMP block
exit takes: ``x @ W`` followed by the completing collective) is timed
forward+backward under every schedule, on 8 virtual CPU devices:

* ``megatron`` — blocking AllReduce after each layer matmul,
* ``wang``     — chunked matmul + chunked AllReduce (intra-op pipelining),
* ``oases``    — two sub-batches, program-order overlap window,
* ``fused``    — ring collective-matmul kernels (guaranteed per-step
                 overlap; :mod:`repro.kernels.collective_matmul`).

On a shared-core CPU host the wall clock mostly measures op-dispatch, so
alongside measured times the script prints the planner cost model's
prediction for the same four schedules on paper hardware — the quantity
the Oases ILP actually optimizes (the overlapped ``max(T_comm, T_compute)``
term for ``fused``).

Run: ``PYTHONPATH=src python benchmarks/fused_overlap.py``
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.axes import mesh_info
from repro.core.schedule import SCHEDULES, TmpCtx, effective_split

BENCH_SCHEDULES = [s for s in SCHEDULES if s != "merak"]  # merak == oases here


def build_step(mesh, schedule, *, layers, batch, seq, d_model, d_ff):
    """Forward+backward through `layers` row-parallel matmul layers — the
    blocked-AllReduce path of Fig. 2/3 isolated from everything else."""
    info = mesh_info(mesh)
    ctx = TmpCtx(info, schedule=schedule)

    def body(ws, x):
        split = effective_split(schedule, 2, x.shape[0])
        subs = [x[i * (x.shape[0] // split):(i + 1) * (x.shape[0] // split)]
                for i in range(split)]
        total = jnp.float32(0.0)
        for w_up, w_down in zip(*ws):
            outs = []
            for s in subs:
                h = jnp.dot(s, w_up)            # column-parallel up
                outs.append(ctx.row_matmul(h, w_down))   # row-parallel + AR
            subs = [jnp.tanh(o) for o in outs]
        for s in subs:
            total = total + jnp.sum(s)
        return total

    sm = compat.shard_map(
        body, mesh=mesh,
        in_specs=((P(None, None, ("model",)), P(None, ("model",), None)),
                  P(("data",), None, None)),
        out_specs=P(), check_vma=False)

    def step(ws, x):
        return jax.value_and_grad(sm)(ws, x)

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    ws = (0.02 * jax.random.normal(k1, (layers, d_model, d_ff)),
          0.02 * jax.random.normal(k2, (layers, d_ff, d_model)))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, d_model))
    return jax.jit(step), ws, x


def measure(fn, ws, x, iters=5):
    out = fn(ws, x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(ws, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def model_prediction():
    """Planner cost-model step times for the same comparison on paper HW."""
    from repro.configs.base import SHAPES, TrainHParams
    from repro.configs.registry import get_config
    from repro.core.planner import estimate_iteration
    cfg = get_config("internlm2-1.8b")
    degrees = [8] * cfg.num_layers
    rows = {}
    for sched in BENCH_SCHEDULES:
        est = estimate_iteration(cfg, SHAPES["train_4k"],
                                 TrainHParams(schedule=sched), degrees)
        rows[sched] = est["iter_s"]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    print(f"mesh (2 data x 4 model), {args.layers} layers, "
          f"batch {args.batch} x seq {args.seq} x d {args.d_model} "
          f"(d_ff {args.d_ff})\n")
    print(f"{'schedule':<10} {'measured ms/step':>18}")
    base = None
    results = {}
    for sched in BENCH_SCHEDULES:
        fn, ws, x = build_step(mesh, sched, layers=args.layers,
                               batch=args.batch, seq=args.seq,
                               d_model=args.d_model, d_ff=args.d_ff)
        with compat.set_mesh(mesh):
            t = measure(fn, ws, x, args.iters)
        results[sched] = t
        base = base or t
        print(f"{sched:<10} {t * 1e3:>14.2f} ms   ({base / t:4.2f}x)")

    print("\ncost-model prediction (paper HW, internlm2-1.8b @ degree 8):")
    rows = model_prediction()
    base = rows[BENCH_SCHEDULES[0]]
    for sched, t in rows.items():
        print(f"{sched:<10} {t * 1e3:>14.1f} ms   ({base / t:4.2f}x)")

    # overlap headroom from the blocking step's own compiled HLO: the gap
    # between serial (compute + comm) and overlapped max(compute, comm)
    # roofline seconds is what kernel fusion can recover on paper HW
    from repro.core.planner import V5E
    from repro.launch import hlo_cost
    fn, ws, x = build_step(mesh, "megatron", layers=args.layers,
                           batch=args.batch, seq=args.seq,
                           d_model=args.d_model, d_ff=args.d_ff)
    with compat.set_mesh(mesh):
        txt = jax.jit(fn).lower(ws, x).compile().as_text()
    cost = hlo_cost.analyze(txt, default_group=4)
    rf = cost.roofline_seconds(peak_flops=V5E.peak_flops,
                               hbm_bw=V5E.hbm_bw, link_bw=V5E.link_bw,
                               mxu_eff=V5E.mxu_base_eff)
    print(f"\nHLO roofline of the blocking step (paper HW): "
          f"serial {rf['serial_s'] * 1e6:.1f} us vs overlapped "
          f"{rf['overlapped_s'] * 1e6:.1f} us "
          f"({rf['serial_s'] / max(rf['overlapped_s'], 1e-12):4.2f}x headroom)")


if __name__ == "__main__":
    main()
