"""§Roofline deliverable: per (arch x shape x mesh) the three roofline terms
from the compiled dry-run, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and the roofline fraction.  Reads results/dryrun.jsonl (produced by
``python -m repro.launch.dryrun --sweep``)."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS


def load(path=None):
    path = path or os.path.join(RESULTS, "dryrun.jsonl")
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"],
                  r.get("schedule", "oases"))] = r
    return recs


def run():
    recs = load()
    rows = []
    for (arch, shape, mesh, sched), r in sorted(recs.items()):
        if mesh != "single":      # roofline table is single-pod only
            continue
        if r["status"] != "OK":
            rows.append({"arch": arch, "shape": shape,
                         "status": r["status"],
                         "note": r.get("reason", "")[:60]})
            continue
        t = r["terms_s"]
        rows.append({
            "arch": arch, "shape": shape, "status": "OK",
            "compute_s": round(t["compute_s"], 4),
            "memory_s": round(t["memory_s"], 4),
            "collective_s": round(t["collective_s"], 4),
            "dominant": r["dominant"].replace("_s", ""),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "roofline_fraction": round(r["roofline_fraction"], 4),
            "fits_16GB": r["mem"]["fits_16GB"],
        })
    return rows
