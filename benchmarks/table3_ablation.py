"""Table 3: ablation — Megatron-LM -> Merak -> +cross-pass -> +fine-grained
recomputation -> +planner, throughput (k tokens/s) and speedups."""
from __future__ import annotations

from benchmarks.common import paper_hw, tokens_per_s
from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import plan


def run():
    hw = paper_hw()
    rows = []
    for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
        cfg, tmp, dp, gb = PAPER_TABLE4[key]
        shape = paper_shape(gb)
        d = [tmp] * cfg.num_layers
        variants = {
            "megatron": TrainHParams(schedule="megatron", fine_remat=False),
            "merak": TrainHParams(schedule="merak", fine_remat=False),
            "cross_pass": TrainHParams(schedule="oases", fine_remat=False),
            "fine_remat": TrainHParams(schedule="oases", fine_remat=True),
        }
        tps = {k: tokens_per_s(cfg, shape, hp, d, hw)
               for k, hp in variants.items()}
        hp = variants["fine_remat"]
        pr = plan(cfg, shape, hp, hw, mem_cap=hw.hbm_cap)
        tps["planner"] = tokens_per_s(cfg, shape, hp, pr.degrees, hw)
        base = tps["megatron"]
        rows.append({
            "model": key,
            "ktok_per_s": {k: round(v / 1e3, 1) for k, v in tps.items()},
            "speedup_vs_megatron": {k: round(v / base, 2)
                                    for k, v in tps.items()},
            "planner_strategy": pr.summary(),
        })
    return rows
