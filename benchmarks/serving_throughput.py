"""Serving-path throughput ladder: dense -> paged -> paged+prefix ->
paged+prefix+speculative, on a shared-prefix workload.

Runs the real continuous-batching engine (reduced 1.8B, 1-device CPU
mesh) over the same request set in all four configurations and reports
tokens/s and mean TTFT per variant.  The *deterministic* fields — engine
steps, decoded tokens, prefix hit rate, speculative accept rate, and the
ladder orderings — go into BENCH_<tag>.json for the perf-trajectory gate;
wall-clock numbers stay in results/bench_report.json (host-dependent).

The ladder's contract on a shared-prefix workload:

* every variant emits token-identical output (greedy equivalence),
* paged+prefix finishes in strictly fewer engine steps than dense
  (prefix hits skip the shared prefill span),
* speculation finishes in strictly fewer steps than paged+prefix
  (each accepted draft token saves a target forward).
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 2
MAX_SEQ = 48
PAGE_SIZE = 8
N_REQ = 6
MAX_NEW = 6
SPEC_K = 3


def _prompts(vocab):
    """Shared-prefix request mix: block-aligned reuse, mid-block
    divergence, and hits shorter/longer than one page."""
    rng = np.random.default_rng(7)
    base = rng.integers(3, vocab, 12, dtype=np.int32)
    out = []
    for i in range(N_REQ):
        keep = (6, 12, 9, 12, 6, 9)[i]
        tail = rng.integers(3, vocab, 3 + (i % 3), dtype=np.int32)
        out.append(np.concatenate([base[:keep], tail]).astype(np.int32))
    return out


def _run_variant(cfg, mesh, prompts, **eng_kw):
    from repro.serving import Request, ServingEngine
    eng = ServingEngine(cfg, mesh, slots=SLOTS, max_seq=MAX_SEQ, **eng_kw)
    eng.load(seed=0)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    ttft = {}
    t0 = time.perf_counter()
    while (not eng.queue.empty() or eng._pending is not None
           or any(a is not None for a in eng.active)):
        eng.step()
        now = time.perf_counter()
        for r in reqs:
            if r.out_tokens and r.rid not in ttft:
                ttft[r.rid] = now - t0
    wall = time.perf_counter() - t0
    stats = dict(eng.stats)
    row = {
        "steps": stats["steps"],
        "decoded_tokens": stats["decoded_tokens"],
        "tok_per_s": round(stats["decoded_tokens"] / max(wall, 1e-9), 1),
        "ttft_ms": round(1e3 * sum(ttft.values()) / max(len(ttft), 1), 2),
        "wall_s": round(wall, 3),
    }
    if eng.paged is not None:
        row["prefix_hit_rate"] = round(
            stats["prefix_hit_tokens"] / max(stats["prompt_tokens"], 1), 4)
        row["cow"] = eng.paged.stats["cow"]
    if eng.spec_k:
        row["spec_accept_rate"] = round(
            stats["spec_accepted"] / max(stats["spec_proposed"], 1), 4)
    return row, [r.out_tokens for r in reqs]


def run():
    from repro.configs.registry import get_config
    from repro.core import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    prompts = _prompts(cfg.vocab_size)

    variants = {}
    tokens = {}
    variants["dense"], tokens["dense"] = _run_variant(cfg, mesh, prompts)
    variants["paged"], tokens["paged"] = _run_variant(
        cfg, mesh, prompts, paged=True, page_size=PAGE_SIZE)
    variants["paged_prefix"], tokens["paged_prefix"] = _run_variant(
        cfg, mesh, prompts, paged=True, page_size=PAGE_SIZE,
        prefix_cache=True)
    variants["paged_prefix_spec"], tokens["paged_prefix_spec"] = \
        _run_variant(cfg, mesh, prompts, paged=True, page_size=PAGE_SIZE,
                     prefix_cache=True, draft=cfg, spec_k=SPEC_K)

    ref = tokens["dense"]
    section = {
        "workload": {"slots": SLOTS, "max_seq": MAX_SEQ,
                     "page_size": PAGE_SIZE, "requests": N_REQ,
                     "max_new": MAX_NEW, "spec_k": SPEC_K,
                     "arch": cfg.name},
        "variants": variants,
        "token_identical": all(tokens[v] == ref for v in tokens),
        # step counts are deterministic; wall clock is not — the BENCH
        # gate pins the ladder on steps, not seconds
        "paged_prefix_beats_dense":
            variants["paged_prefix"]["steps"] < variants["dense"]["steps"],
        "spec_beats_paged_prefix":
            variants["paged_prefix_spec"]["steps"]
            < variants["paged_prefix"]["steps"],
    }
    return section


def bench_fields(section):
    """The deterministic subset pinned into BENCH_<tag>.json."""
    return {
        "steps": {v: row["steps"] for v, row in section["variants"].items()},
        "decoded_tokens": section["variants"]["dense"]["decoded_tokens"],
        "prefix_hit_rate":
            section["variants"]["paged_prefix"]["prefix_hit_rate"],
        "spec_accept_rate":
            section["variants"]["paged_prefix_spec"]["spec_accept_rate"],
        "token_identical": section["token_identical"],
        "paged_prefix_beats_dense": section["paged_prefix_beats_dense"],
        "spec_beats_paged_prefix": section["spec_beats_paged_prefix"],
    }
