"""Subprocess body for the measured benchmarks: 8 virtual CPU devices,
times real train-step iterations across a grid of (model config x TMP
degree x schedule) points and prints one JSON dict.

Two tiers share the harness (pick with ``--tier``):

* ``fig6`` (default) — the cost-model Spearman grid: prints a flat
  ``{key: seconds}`` dict consumed by :mod:`benchmarks.fig6_costmodel`.
  On this single-core container the wall-clock signal across *sharding
  layouts alone* is flat (total FLOPs are constant and the core is
  shared), so the grid also varies the model config — the cost model must
  rank the full grid correctly, which is the property the Oases planner
  relies on (Appendix C).
* ``measured`` — the measured-speed bench tier (ROADMAP item 3): for each
  (config x schedule) point it reports BOTH wall-clock tokens/s and the
  calibrated cost model's prediction for the same point
  (``HWConfig.measure_fields`` run in-process on the same virtual
  devices), so ``bench_diff.py --ranking`` can gate modeled-vs-measured
  ranking agreement without any modeled number leaving this process.

All hot-path timing uses ``time.perf_counter()`` — ``time.time()`` is
non-monotonic and low-resolution, and an NTP slew mid-measurement
corrupts tokens/s silently."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import (ArchConfig, GLOBAL_ATTN, ShapeConfig,
                                TrainHParams)
from repro.core.axes import mesh_info
from repro.launch import steps as steps_mod
from repro.models import params as prm
from repro.optim import adamw


def make_cfg(d_model, layers, d_ff):
    return ArchConfig(
        name=f"bench-d{d_model}-l{layers}-f{d_ff}", family="dense",
        num_layers=layers, d_model=d_model, num_heads=max(d_model // 64, 2),
        num_kv_heads=max(d_model // 128, 1), d_ff=d_ff, vocab_size=8192,
        head_dim=64, layer_pattern=(GLOBAL_ATTN,), dtype="float32")


# (cfg, seq, batch) grid — spans ~20x in FLOPs
GRID = [
    (make_cfg(256, 2, 1024), 128, 8),
    (make_cfg(256, 4, 1024), 256, 8),
    (make_cfg(384, 4, 1536), 256, 8),
    (make_cfg(512, 4, 2048), 256, 8),
    (make_cfg(512, 6, 2048), 256, 8),
    (make_cfg(512, 4, 2048), 512, 8),
    (make_cfg(768, 4, 3072), 256, 8),
    (make_cfg(768, 6, 3072), 512, 8),
]
STRATS = [(8, "megatron", False), (8, "oases", True), (4, "oases", True),
          (2, "oases", True)]
BASE_CFG = make_cfg(512, 4, 2048)

# measured tier (ROADMAP item 3): the schedule ranking is the claim under
# test, so every schedule runs at the same (config, degree) point; two
# configs ~8x apart in FLOPs anchor the ranking where the single-core
# wall clock has real signal.
MEASURED_SCHEDULES = ["megatron", "wang", "oases", "fused"]
MEASURED_GRID = [
    (make_cfg(256, 2, 1024), 128, 8, 4),
    (make_cfg(512, 4, 2048), 256, 8, 4),
]


def measure(cfg, seq, batch, tmp_degree, schedule, fine, iters=3):
    dp = 8 // tmp_degree
    mesh = jax.make_mesh((dp, tmp_degree), ("data", "model"))
    hp = TrainHParams(schedule=schedule, fine_remat=fine, microbatch=1)
    fn, specs = steps_mod.build_train_step(cfg, mesh, hp,
                                           global_batch=batch, seq_len=seq)
    info = mesh_info(mesh)
    params = prm.init_params(specs, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, specs, info)
    k = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                      jnp.int32),
         "labels": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                      jnp.int32)}
    step = jax.jit(fn)
    with compat.set_mesh(mesh):
        params, opt, m = step(params, opt, b)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(iters):
            params, opt, m = step(params, opt, b)
        jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def run_fig6():
    out = {}
    for cfg, seq, batch in GRID:
        key = f"{cfg.name}|s{seq}|b{batch}|tmp4|oases"
        out[key] = measure(cfg, seq, batch, 4, "oases", True)
        print(f"# {key}: {out[key]*1e3:.0f} ms", file=sys.stderr, flush=True)
    for tmp, schedule, fine in STRATS:
        key = (f"{BASE_CFG.name}|s256|b8|tmp{tmp}|{schedule}"
               + ("" if fine else "-coarse"))
        out[key] = measure(BASE_CFG, 256, 8, tmp, schedule, fine)
        print(f"# {key}: {out[key]*1e3:.0f} ms", file=sys.stderr, flush=True)
    return out


def run_measured(points: int = 0, iters: int = 3, telemetry: str = ""):
    """The measured tier: wall-clock AND calibrated-model tokens/s per
    (config x schedule) point.  ``points`` > 0 truncates the grid (the CI
    smoke runs exactly one point end-to-end).  ``telemetry``: directory
    for a structured JSONL trace of the run — per-point timings plus the
    overlap-efficiency probe's per-layer-group exposed-communication
    events against the same calibrated model the ranking gate uses
    (uploaded as a CI artifact next to ``BENCH_<tag>.json``)."""
    from repro.core.planner import estimate_iteration
    from repro.core.planner.costmodel import HWConfig

    rec = None
    if telemetry:
        from repro import obs
        rec = obs.configure(telemetry)
    # calibrate FIRST (its ring mesh must not inherit a set_mesh scope)
    hw_fields = HWConfig.measure_fields(max_devices=8)
    hw = HWConfig(**hw_fields)
    todo = [(cfg, seq, batch, tmp, sched)
            for cfg, seq, batch, tmp in MEASURED_GRID
            for sched in MEASURED_SCHEDULES]
    if points > 0:
        todo = todo[:points]
    rows = []
    for cfg, seq, batch, tmp, sched in todo:
        fine = sched == "oases"
        key = f"{cfg.name}|s{seq}|b{batch}|tmp{tmp}|{sched}"
        t = measure(cfg, seq, batch, tmp, sched, fine, iters=iters)
        hp = TrainHParams(schedule=sched, fine_remat=fine, microbatch=1)
        shape = ShapeConfig("bench", seq, batch, "train")
        est = estimate_iteration(cfg, shape,
                                 hp, [tmp] * cfg.num_layers, hw,
                                 options=(2, 4, 8, 16))
        tokens = batch * seq
        rows.append({
            "key": key, "model": cfg.name, "seq": seq, "batch": batch,
            "tmp": tmp, "schedule": sched,
            "measured_s": t, "measured_tok_s": tokens / max(t, 1e-12),
            "modeled_s": est["iter_s"],
            "modeled_tok_s": est["tokens_per_s"],
        })
        if rec is not None:
            from repro import obs
            rec.observe("bench.measured_s", t, key=key)
            rec.event("bench.point", key=key,
                      measured_ms=round(t * 1e3, 2),
                      modeled_ms=round(est["iter_s"] * 1e3, 2))
            try:
                obs.OverlapProbe.for_run(
                    cfg, shape, hp, hw,
                    [tmp] * cfg.num_layers).report(t, rec)
            except Exception as e:
                rec.event("overlap.error", key=key,
                          msg=f"[overlap] bench probe failed: {e!r}")
        print(f"# {key}: measured {t*1e3:.0f} ms / modeled "
              f"{est['iter_s']*1e3:.0f} ms", file=sys.stderr, flush=True)
    if rec is not None:
        rec.close()
    return {"hw": hw_fields, "iters": iters, "points": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", choices=["fig6", "measured"], default="fig6")
    ap.add_argument("--points", type=int, default=0,
                    help="measured tier: truncate the grid to the first N "
                         "points (0 = full grid; the CI smoke uses 1)")
    ap.add_argument("--iters", type=int, default=3,
                    help="timed iterations per point (after one blocked "
                         "warm-up step)")
    ap.add_argument("--telemetry", default="",
                    help="measured tier: JSONL telemetry directory "
                         "(per-point timings + overlap-probe events)")
    args = ap.parse_args()
    if args.tier == "measured":
        print(json.dumps(run_measured(points=args.points,
                                      iters=args.iters,
                                      telemetry=args.telemetry)))
    else:
        print(json.dumps(run_fig6()))


if __name__ == "__main__":
    main()
