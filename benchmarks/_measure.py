"""Subprocess body for the measured benchmarks: 8 virtual CPU devices,
times real train-step iterations across a grid of (model config x TMP
degree x schedule) points and prints one JSON dict.

Used by fig6 (cost-model Spearman).  On this single-core container the
wall-clock signal across *sharding layouts alone* is flat (total FLOPs are
constant and the core is shared), so the grid also varies the model config
— the cost model must rank the full grid correctly, which is the property
the Oases planner relies on (Appendix C)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import ArchConfig, GLOBAL_ATTN, TrainHParams
from repro.core.axes import mesh_info
from repro.launch import steps as steps_mod
from repro.models import params as prm
from repro.optim import adamw


def make_cfg(d_model, layers, d_ff):
    return ArchConfig(
        name=f"bench-d{d_model}-l{layers}-f{d_ff}", family="dense",
        num_layers=layers, d_model=d_model, num_heads=max(d_model // 64, 2),
        num_kv_heads=max(d_model // 128, 1), d_ff=d_ff, vocab_size=8192,
        head_dim=64, layer_pattern=(GLOBAL_ATTN,), dtype="float32")


# (cfg, seq, batch) grid — spans ~20x in FLOPs
GRID = [
    (make_cfg(256, 2, 1024), 128, 8),
    (make_cfg(256, 4, 1024), 256, 8),
    (make_cfg(384, 4, 1536), 256, 8),
    (make_cfg(512, 4, 2048), 256, 8),
    (make_cfg(512, 6, 2048), 256, 8),
    (make_cfg(512, 4, 2048), 512, 8),
    (make_cfg(768, 4, 3072), 256, 8),
    (make_cfg(768, 6, 3072), 512, 8),
]
STRATS = [(8, "megatron", False), (8, "oases", True), (4, "oases", True),
          (2, "oases", True)]
BASE_CFG = make_cfg(512, 4, 2048)


def measure(cfg, seq, batch, tmp_degree, schedule, fine, iters=3):
    dp = 8 // tmp_degree
    mesh = jax.make_mesh((dp, tmp_degree), ("data", "model"))
    hp = TrainHParams(schedule=schedule, fine_remat=fine, microbatch=1)
    fn, specs = steps_mod.build_train_step(cfg, mesh, hp,
                                           global_batch=batch, seq_len=seq)
    info = mesh_info(mesh)
    params = prm.init_params(specs, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, specs, info)
    k = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                      jnp.int32),
         "labels": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                      jnp.int32)}
    step = jax.jit(fn)
    with compat.set_mesh(mesh):
        params, opt, m = step(params, opt, b)
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        for _ in range(iters):
            params, opt, m = step(params, opt, b)
        jax.block_until_ready(m["loss"])
    return (time.time() - t0) / iters


def main():
    out = {}
    for cfg, seq, batch in GRID:
        key = f"{cfg.name}|s{seq}|b{batch}|tmp4|oases"
        out[key] = measure(cfg, seq, batch, 4, "oases", True)
        print(f"# {key}: {out[key]*1e3:.0f} ms", file=sys.stderr, flush=True)
    for tmp, schedule, fine in STRATS:
        key = (f"{BASE_CFG.name}|s256|b8|tmp{tmp}|{schedule}"
               + ("" if fine else "-coarse"))
        out[key] = measure(BASE_CFG, 256, 8, tmp, schedule, fine)
        print(f"# {key}: {out[key]*1e3:.0f} ms", file=sys.stderr, flush=True)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
