"""Figure 6 / Appendix C: cost-model accuracy — Spearman correlation between
the planner's predicted iteration times and measured iteration times across
(TMP degree x schedule) strategies on the 8-device CPU testbed.

The paper reports Spearman 0.844/0.876 and argues ranking quality is what
matters for the planner; we reproduce the same protocol with CPU-calibrated
hardware constants (the paper's 'offline profiling')."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from scipy.stats import spearmanr

from benchmarks.common import ensure_results_dir
from repro.configs.base import ShapeConfig, TrainHParams
from repro.core.planner import estimate_iteration
from repro.core.planner.costmodel import HWConfig

CACHE = "fig6_measured.json"


def _measured(force=False):
    d = ensure_results_dir()
    path = os.path.join(d, CACHE)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    script = os.path.join(os.path.dirname(__file__), "_measure.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    p = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, timeout=3600, env=env)
    if p.returncode:
        raise RuntimeError(p.stderr[-2000:])
    out = json.loads(p.stdout.strip().splitlines()[-1])
    with open(path, "w") as f:
        json.dump(out, f)
    return out


def _cpu_hw() -> HWConfig:
    """Offline-profiled CPU constants (single-core container testbed)."""
    return HWConfig(n_chips=8, peak_flops=2.0e10, hbm_bw=8e9, link_bw=40e9,
                    hbm_cap=64e9, mxu_base_eff=1.0, comm_latency=2e-4)


def run(force=False):
    from benchmarks._measure import make_cfg
    measured = _measured(force)
    hw = _cpu_hw()
    rows = []
    pred, meas = [], []
    for key, t_meas in measured.items():
        name, s_s, b_s, tmp_s, sched_s = key.split("|")
        _, d, nl, f = name.split("-")
        cfg = make_cfg(int(d[1:]), int(nl[1:]), int(f[1:]))
        shape = ShapeConfig("bench", int(s_s[1:]), int(b_s[1:]), "train")
        tmp = int(tmp_s[3:])
        fine = not sched_s.endswith("-coarse")
        schedule = sched_s.replace("-coarse", "")
        hp = TrainHParams(schedule=schedule, fine_remat=fine, microbatch=1)
        est = estimate_iteration(cfg, shape, hp,
                                 [max(tmp, 2)] * cfg.num_layers, hw,
                                 options=(2, 4, 8, 16))
        rows.append({"strategy": key, "measured_ms": round(t_meas * 1e3, 1),
                     "predicted_ms": round(est["iter_s"] * 1e3, 1)})
        pred.append(est["iter_s"])
        meas.append(t_meas)
    rho = float(spearmanr(pred, meas).statistic)
    return {"points": rows, "spearman": round(rho, 3),
            "paper_reported": [0.844, 0.876]}
