"""Shared benchmark plumbing: the paper's model table, cost-model helpers,
and the measured-on-CPU calibration path (the paper's 'offline profiling',
§4.2) used by the cost-model-accuracy figure."""
from __future__ import annotations

import os

from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4
from repro.core.planner import V5E, estimate_iteration
from repro.core.planner.costmodel import HWConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

from repro.core.schedule import SCHEDULES as _ALL_SCHEDULES

SCHEDULES = list(_ALL_SCHEDULES)


def hp_for(schedule: str, fine: bool = None, planner: bool = False):
    fine = (schedule == "oases") if fine is None else fine
    return TrainHParams(schedule=schedule, fine_remat=fine,
                        use_planner=planner)


def model_rows():
    """(name, cfg, tmp_degree, dp, global_batch) from paper Table 4."""
    return [(k, *v) for k, v in PAPER_TABLE4.items()]


def estimate(cfg, shape, hp, degrees, hw=V5E):
    return estimate_iteration(cfg, shape, hp, degrees, hw)


def tokens_per_s(cfg, shape, hp, degrees, hw=V5E) -> float:
    return estimate(cfg, shape, hp, degrees, hw)["tokens_per_s"]


def paper_hw(n_chips: int = 32) -> HWConfig:
    """A '32 accelerators, commodity interconnect' stand-in used to
    reproduce the paper's *relative* numbers: low link bandwidth makes TMP
    comm the bottleneck exactly as on the 3090/PCIe clusters."""
    return HWConfig(n_chips=n_chips, peak_flops=71e12, hbm_bw=936e9,
                    link_bw=8e9, hbm_cap=24e9)


def ensure_results_dir():
    os.makedirs(RESULTS, exist_ok=True)
    return RESULTS
