"""Benchmark harness entrypoint — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = the primary latency
of the row where defined, else the modeled iteration time), then a readable
JSON dump per table to results/bench_report.json.

``--dry-run``: exercise every driver's modeled path but skip the measured
steps (the fig6 subprocess and the measured-speed tier, the only slow
steps) — the CI smoke that keeps the benchmark drivers from bit-rotting.

Without ``--dry-run`` the run additionally emits the MEASURED section:
wall-clock tokens/s per (config x schedule) grid point on this host's
devices, paired with the calibrated cost model's prediction for the same
point (benchmarks/measured.py).  ``bench_diff.py --ranking`` gates on the
modeled-vs-measured ranking agreement — the loop that stops the perf gate
from grading its own homework.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="modeled paths only; skip the measured fig6 "
                         "subprocess (CI smoke)")
    ap.add_argument("--tag", default="local",
                    help="label for the machine-readable BENCH_<tag>.json "
                         "written at the repo root (perf trajectory — "
                         "future PRs diff against it)")
    ap.add_argument("--measured-points", type=int, default=0,
                    help="truncate the measured-tier grid to the first N "
                         "points (0 = full grid; smokes use 1)")
    ap.add_argument("--measured-iters", type=int, default=3,
                    help="timed iterations per measured-tier point")
    ap.add_argument("--telemetry", default="", metavar="DIR",
                    help="measured tier: write a structured JSONL trace "
                         "(per-point timings + overlap-probe events) "
                         "under DIR — CI uploads it next to BENCH_<tag>")
    args = ap.parse_args()
    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, os.path.abspath(root))       # the benchmarks package
    sys.path.insert(0, os.path.join(root, "src"))
    from benchmarks import (fig2_breakdown, fig4_end_to_end, fig6_costmodel,
                            fig7_scaling, roofline_report, table2_device_eff,
                            table3_ablation, table6_planner)
    from benchmarks.common import ensure_results_dir

    report = {}
    print("name,us_per_call,derived")

    rows = fig2_breakdown.run()
    report["fig2_breakdown"] = rows
    for r in rows:
        print(f"fig2/{r['model']}/{r['schedule']},{r['iter_ms']*1e3:.0f},"
              f"comm_share={r['comm_share']}")

    rows = fig4_end_to_end.run()
    report["fig4_end_to_end"] = rows
    report["fig4_summary"] = fig4_end_to_end.summarize(rows)
    for r in rows:
        for sched, norm in r["normalized"].items():
            tps = r["tokens_per_s"][sched]
            us = 1e6 * r["batch"] * 1024 / max(tps, 1e-9)
            print(f"fig4/{r['model']}/{sched},{us:.0f},norm={norm}")

    rows = table2_device_eff.run()
    report["table2_device_eff"] = rows
    for r in rows:
        print(f"table2/{r['model']},0,meg={r['megatron']}"
              f";oases={r['oases']};ratio={r['ratio']}")

    rows = table3_ablation.run()
    report["table3_ablation"] = rows
    for r in rows:
        s = r["speedup_vs_megatron"]
        print(f"table3/{r['model']},0," + ";".join(
            f"{k}={v}" for k, v in s.items()))

    rows = table6_planner.run()
    report["table6_planner"] = rows
    for r in rows:
        print(f"table6/{r['model']},{r['optim_time_ms']*1e3:.0f},"
              f"plan={r['planned'].replace(',', ' ')}")

    rows = fig7_scaling.run()
    report["fig7_scaling"] = rows
    for r in rows:
        print(f"fig7/{r['model']}/{r['schedule']}/{r['chips']},0,"
              f"eff={r['scaling_eff']}")

    measured = None
    if args.dry_run:
        report["fig6_costmodel"] = {"skipped": "dry-run"}
        print("fig6/spearman,0,SKIPPED(dry-run)")
        report["measured"] = {"skipped": "dry-run"}
        print("measured/tier,0,SKIPPED(dry-run)")
    else:
        try:
            f6 = fig6_costmodel.run()
            report["fig6_costmodel"] = f6
            print(f"fig6/spearman,0,rho={f6['spearman']}")
            for p in f6["points"]:
                print(f"fig6/{p['strategy'].replace(',', ' ')},"
                      f"{p['measured_ms']*1e3:.0f},"
                      f"pred_ms={p['predicted_ms']}")
        except Exception as e:  # measured path needs the 8-dev subprocess
            report["fig6_costmodel"] = {"error": str(e)[:500]}
            print("fig6/spearman,0,ERROR")
        # measured-speed tier (ROADMAP item 3): wall-clock tokens/s per
        # (config x schedule), paired with the calibrated model's view of
        # the same point.  A failure here must fail the run — a silently
        # missing measured section would let the modeled gate grade its
        # own homework again.
        from benchmarks import measured as measured_mod
        measured = measured_mod.run(points=args.measured_points,
                                    iters=args.measured_iters,
                                    telemetry=args.telemetry)
        report["measured"] = measured
        for p in measured["points"]:
            print(f"measured/{p['key'].replace(',', ' ')},"
                  f"{p['measured_ms']*1e3:.0f},"
                  f"tok_s={p['measured_tok_s']}"
                  f";modeled_tok_s={p['modeled_tok_s']}")

    rows = roofline_report.run()
    report["roofline"] = rows
    for r in rows:
        if r["status"] != "OK":
            print(f"roofline/{r['arch']}/{r['shape']},0,{r['status']}")
            continue
        dom_us = 1e6 * max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']},{dom_us:.0f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']}")

    # joint PP x TMP planner decisions on the fixture HWConfigs (modeled;
    # the bubble fraction is the pipeline's idle share of the iteration)
    from repro.configs.base import TrainHParams
    from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
    from repro.core.planner import COMMODITY_25GBE, NVLINK_BOX, plan_joint
    cfg, _t, _d, gb = PAPER_TABLE4["gpt-h8192"]
    joint = {}
    for fixture, hw in (("commodity_25gbe", COMMODITY_25GBE),
                        ("nvlink_box", NVLINK_BOX)):
        r = plan_joint(cfg, paper_shape(gb), TrainHParams(schedule="oases"),
                       hw, options=(16,))
        joint[fixture] = {
            "pp": r.pp, "n_micro": r.n_micro,
            "degrees": [list(d) if isinstance(d, tuple) else d
                        for d in r.degrees],
            "predicted_ms": round(r.predicted_s * 1e3, 3),
            "tmp_only_ms": round(r.tmp_only_s * 1e3, 3),
            "bubble_fraction": round(r.bubble_fraction, 4),
            "p2p_ms": round(r.p2p_s * 1e3, 3),
        }
        print(f"joint/{fixture},{r.predicted_s*1e6:.0f},"
              f"pp={r.pp};bubble={r.bubble_fraction:.3f}")
    report["joint_pp_planner"] = joint

    # per-layer (degree, schedule) executable-plan search — the paper's
    # REAL search space.  Pins the mixed plan of the memory-cliff regime
    # on the commodity fixture against the best uniform schedule (the
    # tentpole golden, tests/test_planner_golden.py::MIXED_CASES).
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.core.plan import SCHEDULES
    from repro.core.planner import plan
    mixed = {}
    for arch, cap in (("llama-3.2-vision-11b", 18.5e9),
                      ("granite-moe-3b-a800m", 5.6e9)):
        mcfg = get_config(arch)
        mhp = TrainHParams()
        r = plan(mcfg, SHAPES["train_4k"], mhp, COMMODITY_25GBE,
                 options=(8, 16), mem_cap=cap, schedules="auto",
                 time_limit=30.0)
        uni = {s: plan(mcfg, SHAPES["train_4k"], mhp, COMMODITY_25GBE,
                       options=(8, 16), mem_cap=cap, schedules=(s,),
                       time_limit=30.0).predicted_s for s in SCHEDULES}
        best_s = min(uni, key=uni.get)
        mixed[arch] = {
            "plan": r.plan.summary(),
            "predicted_ms": round(r.predicted_s * 1e3, 3),
            "best_uniform": best_s,
            "best_uniform_ms": round(uni[best_s] * 1e3, 3),
            "mixed_speedup": round(uni[best_s] / r.predicted_s, 4),
        }
        print(f"planx/{arch},{r.predicted_s*1e6:.0f},"
              f"speedup_vs_{best_s}={mixed[arch]['mixed_speedup']}")
    report["mixed_schedule_planner"] = mixed

    # serving latency planner decisions (modeled per-token decode latency;
    # plan(objective="latency") over (dx, dy, pp) serving meshes)
    from repro.configs.base import ShapeConfig
    serve_shape = ShapeConfig("serve_b8_4k", 4096, 8, "decode")
    serving = {}
    for fixture, hw in (("commodity_25gbe", COMMODITY_25GBE),
                        ("nvlink_box", NVLINK_BOX)):
        r = plan(cfg, serve_shape, TrainHParams(schedule="fused"), hw,
                 options=(16,), objective="latency")
        serving[fixture] = {
            "degree": list(r.degree) if isinstance(r.degree, tuple)
            else r.degree,
            "pp": r.pp, "n_micro": r.n_micro,
            "predicted_ms": round(r.predicted_s * 1e3, 4),
            "tok_per_s": round(r.tok_per_s, 1),
            "tmp_only_ms": round(r.tmp_only_s * 1e3, 4),
        }
        print(f"serve/{fixture},{r.predicted_s*1e6:.0f},"
              f"pp={r.pp};tok_per_s={r.tok_per_s:.0f}")
    report["serving_latency_planner"] = serving

    # serving-path throughput ladder (dense -> paged -> +prefix -> +spec)
    # on the real engine: deterministic step counts feed the BENCH gate,
    # wall-clock tokens/s and TTFT stay in the report (host-dependent).
    # Runs under --dry-run too — the engine ladder is the CI smoke that
    # keeps the serving path from bit-rotting.
    from benchmarks import serving_throughput
    st = serving_throughput.run()
    report["serving_throughput"] = st
    for name, row in st["variants"].items():
        print(f"serve_tp/{name},{row['ttft_ms']*1e3:.0f},"
              f"steps={row['steps']};tok_s={row['tok_per_s']}")

    d = ensure_results_dir()
    with open(os.path.join(d, "bench_report.json"), "w") as f:
        json.dump(report, f, indent=1)
    print("# wrote results/bench_report.json", file=sys.stderr)

    # machine-readable perf trajectory at the repo root: the numbers a
    # future PR diffs against (tokens/s per schedule, planner decisions,
    # bubble fraction)
    bench = {
        "tag": args.tag,
        "time": time.time(),
        "dry_run": bool(args.dry_run),
        "tokens_per_s": {r["model"]: r["tokens_per_s"]
                         for r in report["fig4_end_to_end"]},
        "schedule_speedup_vs_megatron": {
            r["model"]: r["speedup_vs_megatron"]
            for r in report["table3_ablation"]},
        "planner_decisions": {r["model"]: r["planned"]
                              for r in report["table6_planner"]},
        "joint_pp_planner": joint,
        "serving_latency_planner": serving,
        "mixed_schedule_planner": mixed,
        "serving_throughput": serving_throughput.bench_fields(st),
    }
    if measured is not None:
        bench["measured"] = measured
    out = os.path.abspath(os.path.join(root, f"BENCH_{args.tag}.json"))
    with open(out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"# wrote {out}", file=sys.stderr)


if __name__ == "__main__":
    main()
