"""Figure 4: end-to-end training throughput on the paper's seven model
settings, normalized to Megatron-LM, for all four schedules (+ Oases
planner).  Evaluated with the overlap-aware cost model on the
commodity-interconnect hardware profile (paper cluster analogue)."""
from __future__ import annotations

from benchmarks.common import (SCHEDULES, hp_for, model_rows, paper_hw,
                               tokens_per_s)
from repro.configs.gpt_oases import paper_shape
from repro.core.planner import plan


def run():
    hw = paper_hw()
    rows = []
    for name, cfg, tmp, dp, gb in model_rows():
        shape = paper_shape(gb)
        base = None
        per = {}
        for sched in SCHEDULES:
            hp = hp_for(sched)
            tps = tokens_per_s(cfg, shape, hp, [tmp] * cfg.num_layers, hw)
            per[sched] = tps
            if sched == "megatron":
                base = tps
        # + planner on top of the oases schedule
        hp = hp_for("oases", planner=True)
        pr = plan(cfg, shape, hp, hw, mem_cap=hw.hbm_cap)
        per["oases+planner"] = tokens_per_s(cfg, shape, hp, pr.degrees, hw)
        row = {"model": name, "tmp": tmp, "batch": gb,
               "tokens_per_s": {k: round(v, 1) for k, v in per.items()},
               "normalized": {k: round(v / base, 3) for k, v in per.items()}}
        rows.append(row)
    return rows


def summarize(rows):
    best_base = []
    for r in rows:
        n = r["normalized"]
        bb = max(n["megatron"], n["wang"], n["merak"])
        best_base.append(n["oases+planner"] / bb)
    return {
        "speedup_over_megatron": [r["normalized"]["oases+planner"]
                                  for r in rows],
        "speedup_over_best_baseline": [round(x, 3) for x in best_base],
    }
