"""Table 6: per-layer parallel strategies found by the Oases planner and
the ILP optimization time."""
from __future__ import annotations

from benchmarks.common import hp_for, paper_hw
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import plan, estimate_iteration


def run():
    hw = paper_hw()
    rows = []
    for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
        cfg, tmp, dp, gb = PAPER_TABLE4[key]
        shape = paper_shape(gb)
        hp = hp_for("oases")
        uni = estimate_iteration(cfg, shape, hp, [tmp] * cfg.num_layers, hw)
        pr = plan(cfg, shape, hp, hw, mem_cap=hw.hbm_cap)
        rows.append({
            "model": key,
            "uniform": f"[[{tmp}] * {cfg.num_layers}]",
            "uniform_tok_s": round(uni["tokens_per_s"], 1),
            "planned": " + ".join(f"[{d}] * {n}" for d, n in pr.groups),
            "planned_tok_s": round(
                estimate_iteration(cfg, shape, hp, pr.degrees,
                                   hw)["tokens_per_s"], 1),
            "optim_time_ms": round(pr.solve_ms, 1),
            "ilp_status": pr.status,
        })
    return rows
