"""Table 6: per-layer parallel strategies found by the Oases planner and
the ILP optimization time.

Planner v2 extension: for each model the table also reports the 2D
hybrid-partition search (``layout='auto'``) on the heterogeneous
commodity-server fixture (fast intra-node lanes + thin inter-node NIC,
``COMMODITY_25GBE``), where the per-axis cost model can move the wide
x-ring off the NIC — the regime where 2D beats every 1D plan.
"""
from __future__ import annotations

from benchmarks.common import hp_for, paper_hw
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import (COMMODITY_25GBE, estimate_iteration, plan)
from repro.core.planner.ilp import _fmt_degree


def _fmt_groups(groups) -> str:
    return " + ".join(f"[{_fmt_degree(d)}] * {n}" for d, n in groups)


def run():
    hw = paper_hw()
    rows = []
    for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
        cfg, tmp, dp, gb = PAPER_TABLE4[key]
        shape = paper_shape(gb)
        hp = hp_for("oases")
        uni = estimate_iteration(cfg, shape, hp, [tmp] * cfg.num_layers, hw)
        pr = plan(cfg, shape, hp, hw, mem_cap=hw.hbm_cap)
        # 2D hybrid search on the heterogeneous commodity fixture, against
        # the best 1D plan under the same per-axis cost model.  The option
        # space is pinned to the full 16-way group (the memory-bound
        # regime): the 1D ring must cross the NIC, the hybrid keeps its
        # wide x-ring on the intra-node lanes.
        p1 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,),
                  layout="1d")
        p2 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,),
                  layout="auto")
        rows.append({
            "model": key,
            "uniform": f"[[{tmp}] * {cfg.num_layers}]",
            "uniform_tok_s": round(uni["tokens_per_s"], 1),
            "planned": _fmt_groups(pr.groups),
            "planned_tok_s": round(
                estimate_iteration(cfg, shape, hp, pr.degrees,
                                   hw)["tokens_per_s"], 1),
            "optim_time_ms": round(pr.solve_ms, 1),
            "ilp_status": pr.status,
            "hetero_1d": _fmt_groups(p1.groups),
            "hetero_1d_ms": round(p1.predicted_s * 1e3, 1),
            "hetero_2d": _fmt_groups(p2.groups),
            "hetero_2d_ms": round(p2.predicted_s * 1e3, 1),
            "hetero_2d_speedup": round(p1.predicted_s / p2.predicted_s, 3),
        })
    return rows
