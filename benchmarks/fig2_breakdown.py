"""Figure 2: TMP training iteration breakdown (exposed comm share),
Megatron-LM vs Oases, on the two motivating model settings."""
from __future__ import annotations

from benchmarks.common import hp_for, paper_hw
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import estimate_iteration


def run():
    hw = paper_hw()
    rows = []
    for key in ("gpt-h2048", "gpt-h4096"):
        cfg, tmp, dp, gb = PAPER_TABLE4[key]
        shape = paper_shape(gb)
        for sched in ("megatron", "oases"):
            hp = hp_for(sched)
            est = estimate_iteration(cfg, shape, hp,
                                     [tmp] * cfg.num_layers, hw)
            # exposed comm = iteration - pure-compute iteration
            hp0 = hp_for(sched)
            est_nocomm = estimate_iteration(
                cfg, shape, hp0, [tmp] * cfg.num_layers,
                type(hw)(**{**hw.__dict__, "link_bw": 1e18}))
            exposed = max(est["iter_s"] - est_nocomm["iter_s"], 0.0)
            rows.append({
                "model": key, "schedule": sched,
                "iter_ms": round(est["iter_s"] * 1e3, 2),
                "exposed_comm_ms": round(exposed * 1e3, 2),
                "comm_share": round(exposed / est["iter_s"], 3),
            })
    return rows
