"""Figure 7 / Appendix E: weak scaling (global batch grows with chips) vs
ideal linear, Megatron vs Oases."""
from __future__ import annotations

import dataclasses

from benchmarks.common import hp_for, paper_hw
from repro.configs.base import ShapeConfig
from repro.configs.gpt_oases import PAPER_TABLE4
from repro.core.planner import estimate_iteration


def run():
    rows = []
    for key in ("gpt-h2048", "gpt-h3072"):
        cfg, tmp, dp, gb = PAPER_TABLE4[key]
        base_tps = {}
        for chips in (8, 16, 32, 64, 128, 256, 512):
            hw = dataclasses.replace(paper_hw(), n_chips=chips)
            shape = ShapeConfig(f"weak_{chips}", 1024,
                                gb * chips // 32, "train")
            opts = tuple(o for o in (2, 4, 8, 16) if o <= chips)
            for sched in ("megatron", "oases"):
                est = estimate_iteration(cfg, shape, hp_for(sched),
                                         [tmp] * cfg.num_layers, hw,
                                         options=opts)
                tps = est["tokens_per_s"]
                if chips == 8:
                    base_tps[sched] = tps / 8
                rows.append({
                    "model": key, "chips": chips, "schedule": sched,
                    "tokens_per_s": round(tps, 1),
                    "scaling_eff": round(tps / (base_tps[sched] * chips), 3),
                })
    return rows
