"""Measured-speed bench tier (ROADMAP item 3): run the 8-virtual-device
subprocess grid and shape its output into the ``measured`` section of
``BENCH_<tag>.json``.

The section carries, per (config x schedule) grid point, BOTH wall-clock
tokens/s and the calibrated cost model's prediction for the same point —
the pairing ``bench_diff.py --ranking`` gates on (modeled ordering must
agree with measured ordering; absolute numbers are host-dependent and are
only ever diffed under the looser measured tolerance).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Optional


def _host_meta() -> Dict[str, object]:
    import platform

    import jax
    return {
        "hostname": platform.node() or "unknown",
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "python": platform.python_version(),
    }


def run_subprocess(points: int = 0, iters: int = 3,
                   timeout: float = 3600.0, telemetry: str = "") -> Dict:
    """Spawn ``benchmarks/_measure.py --tier measured`` (it pins its own
    XLA_FLAGS device count before importing jax) and parse its JSON."""
    script = os.path.join(os.path.dirname(__file__), "_measure.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    cmd = [sys.executable, script, "--tier", "measured",
           "--iters", str(iters)]
    if points:
        cmd += ["--points", str(points)]
    if telemetry:
        cmd += ["--telemetry", telemetry]
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env)
    if p.returncode:
        raise RuntimeError(p.stderr[-2000:])
    return json.loads(p.stdout.strip().splitlines()[-1])


def build_section(raw: Dict, host: Optional[Dict] = None) -> Dict:
    """The BENCH json ``measured`` section from the subprocess dict.

    Numbers are rounded for stable diffs; the per-point
    measured/modeled pairing is preserved verbatim for the ranking gate.
    """
    pts = []
    for r in raw["points"]:
        pts.append({
            "key": r["key"], "schedule": r["schedule"],
            "model": r["model"], "tmp": r["tmp"],
            "measured_tok_s": round(float(r["measured_tok_s"]), 1),
            "modeled_tok_s": round(float(r["modeled_tok_s"]), 1),
            "measured_ms": round(float(r["measured_s"]) * 1e3, 2),
            "modeled_ms": round(float(r["modeled_s"]) * 1e3, 2),
        })
    return {
        "host": host if host is not None else _host_meta(),
        "hw_calibrated": {k: (round(v, 3) if isinstance(v, float) else v)
                          for k, v in raw["hw"].items()},
        "iters": raw.get("iters", 3),
        "points": pts,
    }


def run(points: int = 0, iters: int = 3, telemetry: str = "") -> Dict:
    """Measured tier end-to-end: subprocess grid -> BENCH section.
    ``telemetry``: JSONL trace directory for the subprocess (CI artifact)."""
    return build_section(run_subprocess(points=points, iters=iters,
                                        telemetry=telemetry))
