"""Table 2: device efficiency (busy fraction) during TMP training —
compute-time / iteration-time from the overlap-aware cost model."""
from __future__ import annotations

from benchmarks.common import hp_for, model_rows, paper_hw
from repro.core.planner import estimate_iteration
from repro.core.planner.costmodel import HWConfig


def run():
    hw = paper_hw()
    rows = []
    for name, cfg, tmp, dp, gb in model_rows():
        from repro.configs.gpt_oases import paper_shape
        shape = paper_shape(gb)
        out = {"model": name}
        for sched in ("megatron", "oases"):
            hp = hp_for(sched)
            est = estimate_iteration(cfg, shape, hp,
                                     [tmp] * cfg.num_layers, hw)
            comp_only = estimate_iteration(
                cfg, shape, hp, [tmp] * cfg.num_layers,
                HWConfig(**{**hw.__dict__, "link_bw": 1e18,
                            "comm_latency": 0.0}))
            out[sched] = round(comp_only["iter_s"] / est["iter_s"], 3)
        out["ratio"] = round(out["oases"] / out["megatron"], 2)
        rows.append(out)
    return rows
