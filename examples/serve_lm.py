"""Batched serving example (deliverable b): continuous batching over more
requests than slots.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.serving import Request, ServingEngine

cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
mesh = make_smoke_mesh()
engine = ServingEngine(cfg, mesh, slots=4, max_seq=96)
engine.load(seed=0)

rng = np.random.default_rng(0)
reqs = []
for i in range(10):
    r = Request(rid=i,
                prompt=rng.integers(3, cfg.vocab_size, int(rng.integers(4, 10)),
                                    dtype=np.int32),
                max_new_tokens=12)
    reqs.append(r)
    engine.submit(r)

stats = engine.run_until_drained()
print(f"served {stats['admitted']} requests, "
      f"{stats['decoded_tokens']} tokens in {stats['steps']} engine steps "
      f"({stats['tok_per_s']:.1f} tok/s on this CPU testbed)")
for r in reqs[:3]:
    print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
          f"-> {r.out_tokens}")
