"""End-to-end training driver example (deliverable b).

CPU demo (default, ~3M params, a few hundred steps):
    PYTHONPATH=src python examples/train_lm.py --steps 200

~100M-parameter run (use on real hardware, or be patient on CPU):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Exercises the full substrate stack: synthetic packed data pipeline with
prefetch, Oases schedule + fine-grained remat, AdamW + ZeRO-1, async
checkpointing, straggler detection.
"""
import argparse

from repro.configs.base import ArchConfig, GLOBAL_ATTN, TrainHParams

PRESETS = {
    "demo": ArchConfig(
        name="demo-3m", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=2048,
        head_dim=32, layer_pattern=(GLOBAL_ATTN,), dtype="float32"),
    "100m": ArchConfig(
        name="oases-110m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
        head_dim=64, layer_pattern=(GLOBAL_ATTN,), dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--schedule", default="oases")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    from repro.launch.mesh import make_smoke_mesh
    from repro.runtime import Trainer

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params")
    mesh = make_smoke_mesh()
    hp = TrainHParams(schedule=args.schedule, learning_rate=1e-3,
                      warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps)
    trainer = Trainer(cfg, mesh, hp, global_batch=args.batch,
                      seq_len=args.seq, ckpt_dir=args.ckpt_dir)
    res = trainer.train(args.steps, ckpt_every=max(args.steps // 4, 10))
    print(f"loss: {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f} over "
          f"{res['final_step']} steps; straggler events: "
          f"{len(res['slow_steps'])}")


if __name__ == "__main__":
    main()
