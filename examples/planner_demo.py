"""Oases planner demo (deliverable b): per-layer TMP degrees from the ILP
for the paper's model table, plus the cost model's view of each schedule.

    PYTHONPATH=src python examples/planner_demo.py
"""
from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import estimate_iteration, plan
from repro.core.planner.costmodel import HWConfig

HW = HWConfig(n_chips=32, peak_flops=71e12, hbm_bw=936e9, link_bw=8e9,
              hbm_cap=24e9)

for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
    cfg, tmp, dp, gb = PAPER_TABLE4[key]
    shape = paper_shape(gb)
    print(f"\n== {key} (paper strategy: TMP={tmp}, DP={dp}, batch={gb}) ==")
    for sched in ("megatron", "merak", "oases"):
        hp = TrainHParams(schedule=sched, fine_remat=sched == "oases")
        est = estimate_iteration(cfg, shape, hp, [tmp] * cfg.num_layers, HW)
        print(f"  {sched:10s} uniform[{tmp:2d}]: "
              f"{est['tokens_per_s']/1e3:7.1f} k tok/s")
    hp = TrainHParams(schedule="oases", fine_remat=True)
    pr = plan(cfg, shape, hp, HW, mem_cap=HW.hbm_cap)
    est = estimate_iteration(cfg, shape, hp, pr.degrees, HW)
    print(f"  oases+ILP  {pr.summary()}")
    print(f"             -> {est['tokens_per_s']/1e3:7.1f} k tok/s")
