"""Oases planner demo (deliverable b): per-layer TMP degrees from the ILP
for the paper's model table, plus the cost model's view of each schedule,
the Planner-v2 2D hybrid-partition search on a heterogeneous
(commodity-server) bandwidth profile, and the joint PP x TMP search
(pipeline stages across boxes, TMP within).

    PYTHONPATH=src python examples/planner_demo.py [--no-calibrate]

By DEFAULT the chip numbers come from on-device micro-bench measurements
(``HWConfig.from_measurements``, cached per host) — the same
profile-guided path the launchers run; ``--no-calibrate`` restores the
hard-coded paper stand-in constants.

The same search spaces are reachable from the launchers via
``--tmp-layout {1d,2d,auto}`` and ``--pp`` (train.py / dryrun.py).
"""
import argparse

from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import (COMMODITY_25GBE, NVLINK_BOX, calibrated_hw,
                                estimate_iteration, plan, plan_joint)
from repro.core.planner.calibrate import describe
from repro.core.planner.costmodel import HWConfig

ap = argparse.ArgumentParser()
ap.add_argument("--calibrate", action="store_true", default=True,
                help="fill flops/hbm/link bandwidths from on-device "
                     "micro-benches (the default; cached per host)")
ap.add_argument("--no-calibrate", dest="calibrate", action="store_false",
                help="use the stock paper stand-in chip numbers")
args = ap.parse_args()

if args.calibrate:
    # measured chip, declared cluster: the overrides describe the paper's
    # 32-accelerator commodity topology and win over the measurements
    HW = calibrated_hw(n_chips=32, node_size=8, hbm_cap=24e9)
    print("calibrated HWConfig:")
    print(" ", describe(HW))
else:
    HW = HWConfig(n_chips=32, peak_flops=71e12, hbm_bw=936e9, link_bw=8e9,
                  hbm_cap=24e9)

for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
    cfg, tmp, dp, gb = PAPER_TABLE4[key]
    shape = paper_shape(gb)
    print(f"\n== {key} (paper strategy: TMP={tmp}, DP={dp}, batch={gb}) ==")
    for sched in ("megatron", "merak", "oases"):
        hp = TrainHParams(schedule=sched, fine_remat=sched == "oases")
        est = estimate_iteration(cfg, shape, hp, [tmp] * cfg.num_layers, HW)
        print(f"  {sched:10s} uniform[{tmp:2d}]: "
              f"{est['tokens_per_s']/1e3:7.1f} k tok/s")
    hp = TrainHParams(schedule="oases", fine_remat=True)
    pr = plan(cfg, shape, hp, HW, mem_cap=HW.hbm_cap)
    est = estimate_iteration(cfg, shape, hp, pr.degrees, HW)
    print(f"  oases+ILP  {pr.summary()}")
    print(f"             -> {est['tokens_per_s']/1e3:7.1f} k tok/s")
    # Planner v2: 2D hybrid search under per-axis (intra- vs inter-node)
    # bandwidths.  The memory cap forces the full 16-way group, so the 1D
    # ring must cross the 25 GbE NIC while the 2D hybrid keeps its wide
    # x-ring on the intra-node lanes.
    p1 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,), layout="1d")
    p2 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,), layout="auto")
    print(f"  25GbE 1d   {p1.summary()}")
    print(f"  25GbE 2d   {p2.summary()} "
          f"({p1.predicted_s / p2.predicted_s:.2f}x)")
    # Planner v3: joint PP x TMP.  Same spanning regime — the joint search
    # instead cuts the stack into stages (one per box) and keeps every TMP
    # ring on the fast intra-node lanes; the NIC carries only the thin
    # microbatch activations.  On the uniform NVLink box it stays TMP-only.
    j = plan_joint(cfg, shape, hp, COMMODITY_25GBE, options=(16,))
    n = plan_joint(cfg, shape, hp, NVLINK_BOX, options=(16,))
    print(f"  25GbE PPxTMP  {j.summary()} "
          f"({p2.predicted_s / j.predicted_s:.2f}x vs 2d)")
    print(f"  NVLink PPxTMP {n.summary()}")

# The executable-plan tentpole: the per-layer search over (degree,
# schedule) PAIRS (the paper's real Table-6 space).  On the commodity
# fixture's memory cliff (cap between uniform-8 and uniform-16) no single
# schedule fits all layers: the NIC-crossing 16-way part of the stack is
# comm-dominated (wang's intra-op chunking wins) while the intra-node
# 8-way rest is compute-bound (barrier-free oases wins) — the mixed plan
# strictly beats every uniform schedule, and `.plan` is directly
# executable (train.py --plan plan.json).
from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.core.plan import SCHEDULES

print("\n== per-layer (degree, schedule) plans on the 25GbE memory "
      "cliff ==")
for arch, cap in (("llama-3.2-vision-11b", 18.5e9),
                  ("granite-moe-3b-a800m", 5.6e9)):
    mcfg = get_config(arch)
    mhp = TrainHParams()
    r = plan(mcfg, SHAPES["train_4k"], mhp, COMMODITY_25GBE,
             options=(8, 16), mem_cap=cap, schedules="auto")
    best = min((plan(mcfg, SHAPES["train_4k"], mhp, COMMODITY_25GBE,
                     options=(8, 16), mem_cap=cap,
                     schedules=(s,)).predicted_s, s) for s in SCHEDULES)
    print(f"  {arch:22s} {r.summary()}")
    print(f"  {'':22s} best uniform = {best[1]} "
          f"({best[0]*1e3:.1f} ms; mixed {best[0] / r.predicted_s:.3f}x)")
