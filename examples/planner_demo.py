"""Oases planner demo (deliverable b): per-layer TMP degrees from the ILP
for the paper's model table, plus the cost model's view of each schedule,
and the Planner-v2 2D hybrid-partition search on a heterogeneous
(commodity-server) bandwidth profile.

    PYTHONPATH=src python examples/planner_demo.py

The same search spaces are reachable from the launchers via
``--tmp-layout {1d,2d,auto}`` (train.py / dryrun.py).
"""
from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import COMMODITY_25GBE, estimate_iteration, plan
from repro.core.planner.costmodel import HWConfig

HW = HWConfig(n_chips=32, peak_flops=71e12, hbm_bw=936e9, link_bw=8e9,
              hbm_cap=24e9)

for key in ("gpt-h2048", "gpt-h4096", "gpt-h8192"):
    cfg, tmp, dp, gb = PAPER_TABLE4[key]
    shape = paper_shape(gb)
    print(f"\n== {key} (paper strategy: TMP={tmp}, DP={dp}, batch={gb}) ==")
    for sched in ("megatron", "merak", "oases"):
        hp = TrainHParams(schedule=sched, fine_remat=sched == "oases")
        est = estimate_iteration(cfg, shape, hp, [tmp] * cfg.num_layers, HW)
        print(f"  {sched:10s} uniform[{tmp:2d}]: "
              f"{est['tokens_per_s']/1e3:7.1f} k tok/s")
    hp = TrainHParams(schedule="oases", fine_remat=True)
    pr = plan(cfg, shape, hp, HW, mem_cap=HW.hbm_cap)
    est = estimate_iteration(cfg, shape, hp, pr.degrees, HW)
    print(f"  oases+ILP  {pr.summary()}")
    print(f"             -> {est['tokens_per_s']/1e3:7.1f} k tok/s")
    # Planner v2: 2D hybrid search under per-axis (intra- vs inter-node)
    # bandwidths.  The memory cap forces the full 16-way group, so the 1D
    # ring must cross the 25 GbE NIC while the 2D hybrid keeps its wide
    # x-ring on the intra-node lanes.
    p1 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,), layout="1d")
    p2 = plan(cfg, shape, hp, COMMODITY_25GBE, options=(16,), layout="auto")
    print(f"  25GbE 1d   {p1.summary()}")
    print(f"  25GbE 2d   {p2.summary()} "
          f"({p1.predicted_s / p2.predicted_s:.2f}x)")
