"""Quickstart: build an Oases-scheduled TMP model, take train steps, decode.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.core.axes import mesh_info
from repro.launch.mesh import make_smoke_mesh
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.models import params as prm
from repro.optim import adamw

# 1. pick an assigned architecture and shrink it for the CPU demo
cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
mesh = make_smoke_mesh()
hp = TrainHParams(schedule="oases", fine_remat=True, learning_rate=3e-3,
                  warmup_steps=2, total_steps=30)

# 2. the train step = Oases-scheduled forward + chunked vocab-parallel loss
#    + AdamW (ZeRO-1) — all inside one shard_map over the mesh
step_fn, specs = steps_mod.build_train_step(cfg, mesh, hp, global_batch=4,
                                            seq_len=64)
params = prm.init_params(specs, jax.random.PRNGKey(0))
opt = adamw.init_opt_state(params, specs, mesh_info(mesh))

k = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(k, (4, 64), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (4, 64), 0, cfg.vocab_size)}
step = jax.jit(step_fn)
with compat.set_mesh(mesh):
    for i in range(10):
        params, opt, m = step(params, opt, batch)
        print(f"step {i}: loss {float(m['loss']):.4f}")

# 3. serve: prefill a prompt, decode a few tokens greedily
pf, _, _ = lm.build_prefill(cfg, mesh, hp, global_batch=4, seq_len=64)
df, _, _ = lm.build_decode(cfg, mesh, hp, global_batch=4, seq_len=64)
with compat.set_mesh(mesh):
    tok, state = jax.jit(pf)(params, {"tokens": batch["tokens"]})
    outs = [int(t) for t in tok]
    pos = jnp.full((4,), 63, jnp.int32)
    for _ in range(5):
        tok, state = jax.jit(df)(params, state, tok, pos)
        pos = pos + 1
print("decoded continuation of sequence 0:", outs[0],
      "->", int(tok[0]))
print("OK")
