"""Batched serving engine: prefill + decode with slot-based continuous
batching, sharded over TMP / pipeline meshes.

The engine owns a fixed pool of ``slots`` (the decode batch dimension).
Requests are admitted into free slots (prompt consumption fills the slot's
KV range), every engine step decodes one token for all active slots, and
finished sequences free their slots for the admission queue — continuous
batching without re-compiling (all shapes static).

Parallel serving: the engine is mesh-agnostic — ``lm.build_decode`` routes
the decode matmuls through the same ``TmpCtx`` schedules as training (1D
and 2D TMP layouts; ``schedule="fused"`` rings the projection collectives
over the slot batch), shards the KV cache head-wise alongside the attention
weights, and on a ``pipe`` mesh streams decode micro-steps through the
stages (stage ``s`` decodes micro-group ``g`` while stage ``s-1`` decodes
``g+1`` — ``core/pipeline.decode_stream``).  Greedy decode is
token-identical to the single-device engine on every such mesh
(tests/_scripts/serving_equivalence.py).
"""
from __future__ import annotations

import queue
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ArchConfig, TrainHParams
from repro.models import lm
from repro.models import params as prm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """``prefill_len`` is the admission contract: the longest prompt a
    request may carry (longer prompts fail at :meth:`submit`, not deep in
    the decode loop).  It defaults to half of ``max_seq`` so a prompt-full
    slot still has decode headroom; pass an explicit value to trade prompt
    capacity against generation length (``launch/serve.py --prefill-len``).

    ``decode_micro``: micro-group count for pipeline-mesh decode streaming
    (0 = auto: one group per stage, ``pp * virtual_stages``).

    ``plan``: an executable :class:`repro.core.plan.ParallelPlan` — the
    engine projects it onto its hp (schedule/layout/virtual stages) and
    ``decode_micro``.  Mixed per-layer *schedules* serve under the plan's
    ``primary_schedule`` (all schedules are token-identical at decode;
    only overlap differs); mixed per-layer *degrees* are a training-only
    layout and are rejected with a friendly error."""

    def __init__(self, cfg: ArchConfig, mesh, *, slots: int, max_seq: int,
                 hp: Optional[TrainHParams] = None, eos_id: int = 2,
                 prefill_len: Optional[int] = None, decode_micro: int = 0,
                 plan=None, telemetry=None):
        self.cfg = cfg
        self.mesh = mesh
        self.plan = plan
        if plan is not None:
            from repro.core.axes import deg_total, mesh_info
            plan.validate_for(cfg)
            degs = {d for d in plan.degrees}
            if len(degs) > 1:
                raise ValueError(
                    f"plan {plan.summary()} pins mixed per-layer TMP "
                    f"degrees — the grouped layout is training-only; "
                    f"serve with a uniform-degree plan (e.g. "
                    f"plan(objective='latency').plan)")
            # a pinned uniform degree / pp must MATCH the mesh — silently
            # decoding under a different layout than the plan chose is
            # exactly the scattered-knob failure plans exist to kill
            info = mesh_info(mesh)
            deg = next(iter(degs))
            if deg is not None and deg_total(deg) != info.tp:
                raise ValueError(
                    f"plan {plan.summary()} pins TMP degree {deg} but the "
                    f"mesh's model group is {info.tp}-way — launch with "
                    f"the plan's recorded mesh (serve.py --plan rebuilds "
                    f"it) or a matching --mesh")
            if plan.pp != info.pp:
                raise ValueError(
                    f"plan {plan.summary()} expects pp={plan.pp} but the "
                    f"mesh has pp={info.pp} — launch with the plan's "
                    f"recorded mesh or a matching --pp")
            hp = plan.apply(hp or TrainHParams())
            if decode_micro == 0:
                decode_micro = plan.decode_micro
            if plan.has_seq_layers or plan.seq_shard > 1:
                # ring-attention seq shards are a training/prefill layout;
                # the plan carries them for provenance (checkpoint
                # manifests, relayout) but decode serves head-sharded —
                # surface the degradation instead of silently dropping it
                from repro.obs.recorder import get_recorder
                get_recorder().event(
                    "serving.seq_shard_ignored",
                    f"plan {plan.summary()} carries ring-attention seq "
                    f"shards; decode serves head-sharded (the KV ring "
                    f"spans training sequences, not the decode cache)",
                    seq_shard=plan.seq_shard)
        self.hp = hp or TrainHParams()
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        if prefill_len is None:
            prefill_len = max(max_seq // 2, 1)
        if not 1 <= prefill_len < max_seq:
            raise ValueError(
                f"prefill_len {prefill_len} must be in [1, max_seq) = "
                f"[1, {max_seq}) — a prompt-full slot needs at least one "
                f"position of decode headroom")
        self.prefill_len = prefill_len

        self.decode_fn, self.specs, self.state_specs = lm.build_decode(
            cfg, mesh, self.hp, global_batch=slots, seq_len=max_seq,
            n_micro=decode_micro)
        # donating the KV cache lets XLA alias it through the step on
        # accelerators; the CPU backend ignores donation (and warns), so
        # skip it there
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self.donate_argnums = donate
        self.decode_fn = jax.jit(self.decode_fn, donate_argnums=donate)

        self.params = None
        self.state = None
        self.pos = np.zeros((slots,), np.int32)
        self.cur_tok = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.stats = {"decoded_tokens": 0, "steps": 0, "admitted": 0}
        # None -> resolve the process-global recorder per tick, so
        # serve.py's --telemetry (obs.configure) reaches a pre-built engine
        self._telemetry = telemetry

    @property
    def rec(self):
        return (self._telemetry if self._telemetry is not None
                else obs.get_recorder())

    def load(self, seed: int = 0, params=None):
        self.params = params if params is not None else prm.init_params(
            self.specs, jax.random.PRNGKey(seed))
        self.state = prm.zeros_state(self.state_specs)

    @property
    def queued(self) -> int:
        """Requests waiting for a free slot (admission backlog depth)."""
        return self.queue.qsize()

    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) > self.prefill_len:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"exceeds prefill_len={self.prefill_len} (engine admission "
                f"contract; raise --prefill-len / max_seq or chunk the "
                f"prompt)")
        req._submit_t = time.perf_counter()   # TTFT clock starts here
        self.queue.put(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is not None:
                continue
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return
            # teacher-forced prompt consumption via decode steps (simple,
            # static-shape admission; a production engine would batch a
            # dedicated prefill_step — see examples/serve_lm.py)
            self.active[s] = req
            self.pos[s] = 0
            self.cur_tok[s] = int(req.prompt[0])
            req._prompt_cursor = 1
            self.stats["admitted"] += 1

    def step(self):
        """One engine iteration: admit, decode one token for all slots."""
        rec = self.rec
        self._admit()
        rec.gauge("serving.queue_depth", self.queued)
        rec.gauge("serving.slot_occupancy",
                  sum(a is not None for a in self.active) / self.slots)
        t0 = time.perf_counter()
        tokens = jnp.asarray(self.cur_tok)
        pos = jnp.asarray(self.pos)
        with obs.trace_annotation("engine_tick"):
            next_tok, self.state = self.decode_fn(self.params, self.state,
                                                  tokens, pos)
            next_tok = np.asarray(jax.device_get(next_tok))
        now = time.perf_counter()
        rec.observe("serving.decode_step_s", now - t0)
        self.stats["steps"] += 1
        decoded = 0
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            self.pos[s] += 1
            cur = getattr(req, "_prompt_cursor", len(req.prompt))
            if cur < len(req.prompt):       # still consuming the prompt
                self.cur_tok[s] = int(req.prompt[cur])
                req._prompt_cursor = cur + 1
                continue
            tok = int(next_tok[s])
            if not req.out_tokens and hasattr(req, "_submit_t"):
                rec.observe("serving.ttft_s", now - req._submit_t,
                            rid=req.rid)
            req.out_tokens.append(tok)
            self.stats["decoded_tokens"] += 1
            decoded += 1
            self.cur_tok[s] = tok
            if (tok == self.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[s] >= self.max_seq - 1):
                req.done = True
                self.active[s] = None
        if decoded:
            rec.counter("serving.decoded_tokens", decoded)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if self.queue.empty() and all(a is None for a in self.active):
                break
            self.step()
        dt = time.perf_counter() - t0
        rec = self.rec
        rec.gauge("serving.drain_s", dt)
        rec.gauge("serving.tok_per_s",
                  self.stats["decoded_tokens"] / max(dt, 1e-9))
        return {**self.stats, "wall_s": dt,
                "tok_per_s": self.stats["decoded_tokens"] / max(dt, 1e-9)}
