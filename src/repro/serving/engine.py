"""Batched serving engine: prefill + decode with slot-based continuous
batching, sharded over TMP / pipeline meshes.

The engine owns a fixed pool of ``slots`` (the decode batch dimension).
Requests are admitted into free slots (prompt consumption fills the slot's
KV range), every engine step decodes one token for all active slots, and
finished sequences free their slots for the admission queue — continuous
batching without re-compiling (all shapes static).

Parallel serving: the engine is mesh-agnostic — ``lm.build_decode`` routes
the decode matmuls through the same ``TmpCtx`` schedules as training (1D
and 2D TMP layouts; ``schedule="fused"`` rings the projection collectives
over the slot batch), shards the KV cache head-wise alongside the attention
weights, and on a ``pipe`` mesh streams decode micro-steps through the
stages (stage ``s`` decodes micro-group ``g`` while stage ``s-1`` decodes
``g+1`` — ``core/pipeline.decode_stream``).  Greedy decode is
token-identical to the single-device engine on every such mesh
(tests/_scripts/serving_equivalence.py).

Serving at scale (``--paged`` / ``--prefix-cache`` / ``--draft --spec-k``):

* **Paged KV** (``paged=True``): GLOBAL_ATTN caches live in a flat page
  pool instead of dense per-slot rows; a host-side
  :class:`repro.serving.paged_cache.PagedKVCache` allocates fixed-size
  blocks on demand and the engine passes each slot's block table (plus
  copy-on-write page pairs) into the jitted step every tick.  Admission
  becomes reservation-based: a request is only admitted when the free
  list plus evictable prefix pages cover its worst case, and a request
  that does not fit waits in a one-deep ``_pending`` buffer (cache-full
  backpressure) instead of deadlocking mid-decode.
* **Prefix cache** (``prefix_cache=True``): prompts are hashed at block
  granularity; a hit maps the donor's pages into the new slot's table
  (refcounted, COW on first divergent write) and skips prefill for the
  shared span — the slot starts at ``pos = hit`` with the remaining
  prompt teacher-forced as usual.
* **Speculative decoding** (``draft=<ArchConfig>, spec_k=k``): a small
  draft model proposes ``k`` tokens per round (plus one catch-up step
  re-consuming ``prev_tok`` to repair its cache after a rejected tail);
  the target verifies all ``k+1`` tokens in one batched ``lm.build_verify``
  forward and the engine accepts the longest agreeing run.  Greedy
  acceptance is *exactly* token-identical to undrafted decode: the
  verify forward returns, for every position, what single-token decode
  would have emitted there, so a divergence yields the oracle's own
  correction and full agreement yields a free bonus token.
"""
from __future__ import annotations

import queue
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import GLOBAL_ATTN, ArchConfig, TrainHParams
from repro.models import lm
from repro.models import params as prm
from repro.serving.paged_cache import PagedKVCache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """``prefill_len`` is the admission contract: the longest prompt a
    request may carry (longer prompts fail at :meth:`submit`, not deep in
    the decode loop).  It defaults to half of ``max_seq`` so a prompt-full
    slot still has decode headroom; pass an explicit value to trade prompt
    capacity against generation length (``launch/serve.py --prefill-len``).

    ``decode_micro``: micro-group count for pipeline-mesh decode streaming
    (0 = auto: one group per stage, ``pp * virtual_stages``).

    ``plan``: an executable :class:`repro.core.plan.ParallelPlan` — the
    engine projects it onto its hp (schedule/layout/virtual stages) and
    ``decode_micro``.  Mixed per-layer *schedules* serve under the plan's
    ``primary_schedule`` (all schedules are token-identical at decode;
    only overlap differs); mixed per-layer *degrees* are a training-only
    layout and are rejected with a friendly error.

    ``paged`` switches GLOBAL_ATTN KV to the page-pool layout
    (``pages`` physical pages of ``page_size`` tokens; 0 = auto-size so
    every slot can still reach ``max_seq``, plus the reserved null page).
    ``prefix_cache`` (requires ``paged``) reuses cached prompt blocks
    across requests.  ``draft`` + ``spec_k`` turn on speculative decoding
    (greedy, oracle-token-identical)."""

    def __init__(self, cfg: ArchConfig, mesh, *, slots: int, max_seq: int,
                 hp: Optional[TrainHParams] = None, eos_id: int = 2,
                 prefill_len: Optional[int] = None, decode_micro: int = 0,
                 plan=None, telemetry=None, paged: bool = False,
                 pages: int = 0, page_size: int = 16,
                 prefix_cache: bool = False,
                 draft: Optional[ArchConfig] = None, spec_k: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.plan = plan
        if plan is not None:
            from repro.core.axes import deg_total, mesh_info
            plan.validate_for(cfg)
            degs = {d for d in plan.degrees}
            if len(degs) > 1:
                raise ValueError(
                    f"plan {plan.summary()} pins mixed per-layer TMP "
                    f"degrees — the grouped layout is training-only; "
                    f"serve with a uniform-degree plan (e.g. "
                    f"plan(objective='latency').plan)")
            # a pinned uniform degree / pp must MATCH the mesh — silently
            # decoding under a different layout than the plan chose is
            # exactly the scattered-knob failure plans exist to kill
            info = mesh_info(mesh)
            deg = next(iter(degs))
            if deg is not None and deg_total(deg) != info.tp:
                raise ValueError(
                    f"plan {plan.summary()} pins TMP degree {deg} but the "
                    f"mesh's model group is {info.tp}-way — launch with "
                    f"the plan's recorded mesh (serve.py --plan rebuilds "
                    f"it) or a matching --mesh")
            if plan.pp != info.pp:
                raise ValueError(
                    f"plan {plan.summary()} expects pp={plan.pp} but the "
                    f"mesh has pp={info.pp} — launch with the plan's "
                    f"recorded mesh or a matching --pp")
            hp = plan.apply(hp or TrainHParams())
            if decode_micro == 0:
                decode_micro = plan.decode_micro
            if plan.has_seq_layers or plan.seq_shard > 1:
                # ring-attention seq shards are a training/prefill layout;
                # the plan carries them for provenance (checkpoint
                # manifests, relayout) but decode serves head-sharded —
                # surface the degradation instead of silently dropping it
                from repro.obs.recorder import get_recorder
                get_recorder().event(
                    "serving.seq_shard_ignored",
                    f"plan {plan.summary()} carries ring-attention seq "
                    f"shards; decode serves head-sharded (the KV ring "
                    f"spans training sequences, not the decode cache)",
                    seq_shard=plan.seq_shard)
        self.hp = hp or TrainHParams()
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        if prefill_len is None:
            prefill_len = max(max_seq // 2, 1)
        if not 1 <= prefill_len < max_seq:
            raise ValueError(
                f"prefill_len {prefill_len} must be in [1, max_seq) = "
                f"[1, {max_seq}) — a prompt-full slot needs at least one "
                f"position of decode headroom")
        self.prefill_len = prefill_len

        if prefix_cache and not paged:
            raise ValueError(
                "prefix_cache requires paged=True — prefix reuse maps "
                "cached KV *pages* into the new slot's block table; the "
                "dense per-slot cache has no shareable unit")
        if (spec_k > 0) != (draft is not None):
            raise ValueError(
                "speculative decoding needs both a draft model and "
                "spec_k >= 1 (serve.py --draft <config> --spec-k k); got "
                f"spec_k={spec_k}, draft="
                f"{draft.name if draft is not None else None}")
        if prefix_cache:
            _n, _pat, _tail = prm.stack_layout(cfg)
            other = sorted((set(_pat) | set(_tail)) - {GLOBAL_ATTN})
            if other:
                raise ValueError(
                    f"prefix cache requires an all-global-attention layer "
                    f"pattern; {cfg.name} mixes in {other} — skipping "
                    f"prefill for a shared span cannot reconstruct "
                    f"ring-buffer or recurrent layer states")
        self.spec_k = int(spec_k)
        self.draft_cfg = draft
        if draft is not None and draft.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft model {draft.name} has vocab {draft.vocab_size} "
                f"but target {cfg.name} has {cfg.vocab_size} — draft "
                f"proposals must live in the target's token space")

        self.paged: Optional[PagedKVCache] = None
        ptuple = None
        if paged:
            if pages <= 0:
                # auto: every slot can still reach max_seq (paged then
                # costs nothing in capacity and wins it back whenever
                # requests finish early or share prefixes)
                pages = slots * (max_seq // max(page_size, 1)) + 1
            self.paged = PagedKVCache(pages=pages, page_size=page_size,
                                      slots=slots, max_seq=max_seq,
                                      prefix_cache=prefix_cache)
            ptuple = (pages, page_size)

        self.decode_fn, self.specs, self.state_specs = lm.build_decode(
            cfg, mesh, self.hp, global_batch=slots, seq_len=max_seq,
            n_micro=decode_micro, paged=ptuple)
        # donating the KV cache lets XLA alias it through the step on
        # accelerators; the CPU backend ignores donation (and warns), so
        # skip it there
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self.donate_argnums = donate
        self.decode_fn = jax.jit(self.decode_fn, donate_argnums=donate)

        if self.spec_k:
            vf, _, _ = lm.build_verify(
                cfg, mesh, self.hp, global_batch=slots, seq_len=max_seq,
                paged=ptuple)
            self.verify_fn = jax.jit(vf, donate_argnums=donate)
            # the draft serves its own dense cache on the same mesh; its
            # rows are freely rewritten when a rejection rewinds pos
            # (stale rows beyond pos are position-masked, and every
            # revisited position is rewritten before it is attended)
            df, self.draft_specs, self.draft_state_specs = lm.build_decode(
                draft, mesh, self.hp, global_batch=slots, seq_len=max_seq)
            self.draft_fn = jax.jit(df, donate_argnums=donate)
            self.draft_params = None
            self.draft_state = None

        self.params = None
        self.state = None
        self.pos = np.zeros((slots,), np.int32)
        self.cur_tok = np.zeros((slots,), np.int32)
        # token at pos-1 per slot: the speculative catch-up input that
        # repairs the draft cache after a rejected tail
        self.prev_tok = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._pending: Optional[Request] = None
        self.stats = {"decoded_tokens": 0, "steps": 0, "admitted": 0,
                      "prompt_tokens": 0, "prefix_hits": 0,
                      "prefix_hit_tokens": 0, "spec_proposed": 0,
                      "spec_accepted": 0}
        # None -> resolve the process-global recorder per tick, so
        # serve.py's --telemetry (obs.configure) reaches a pre-built engine
        self._telemetry = telemetry

    @property
    def rec(self):
        return (self._telemetry if self._telemetry is not None
                else obs.get_recorder())

    def load(self, seed: int = 0, params=None, draft_params=None):
        self.params = params if params is not None else prm.init_params(
            self.specs, jax.random.PRNGKey(seed))
        self.state = prm.zeros_state(self.state_specs)
        if self.spec_k:
            self.draft_params = (draft_params if draft_params is not None
                                 else prm.init_params(
                                     self.draft_specs,
                                     jax.random.PRNGKey(seed + 1)))
            self.draft_state = prm.zeros_state(self.draft_state_specs)

    @property
    def queued(self) -> int:
        """Requests waiting for a free slot (admission backlog depth,
        including one held back by cache-full backpressure)."""
        return self.queue.qsize() + (self._pending is not None)

    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) > self.prefill_len:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"exceeds prefill_len={self.prefill_len} (engine admission "
                f"contract; raise --prefill-len / max_seq or chunk the "
                f"prompt)")
        req._submit_t = time.perf_counter()   # TTFT clock starts here
        self.queue.put(req)

    def _next_request(self) -> Optional[Request]:
        if self._pending is not None:
            req, self._pending = self._pending, None
            return req
        try:
            return self.queue.get_nowait()
        except queue.Empty:
            return None

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is not None:
                continue
            req = self._next_request()
            if req is None:
                return
            # teacher-forced prompt consumption via decode steps (simple,
            # static-shape admission; a production engine would batch a
            # dedicated prefill_step — see examples/serve_lm.py)
            hit = 0
            if self.paged is not None:
                shared, span = self.paged.lookup(req.prompt)
                # keep at least one prompt token to consume: the engine's
                # first step on the slot must produce a next-token
                hit = min(span, len(req.prompt) - 1)
                if not self.paged.can_admit(
                        len(req.prompt), req.max_new_tokens,
                        shared_pages=len(shared), headroom=self.spec_k):
                    # cache-full backpressure: park the request at the head
                    # of the line until a release frees enough blocks (FIFO
                    # order is preserved — nothing overtakes it)
                    self._pending = req
                    self.rec.counter("serving.admission_deferred", 1)
                    return
                self.paged.admit(s, len(req.prompt), req.max_new_tokens,
                                 headroom=self.spec_k, shared=shared)
                if hit:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_hit_tokens"] += hit
            self.active[s] = req
            self.pos[s] = hit
            self.cur_tok[s] = int(req.prompt[hit])
            self.prev_tok[s] = int(req.prompt[max(hit - 1, 0)])
            req._prompt_cursor = hit + 1
            req._inserted = False
            self.stats["admitted"] += 1
            self.stats["prompt_tokens"] += len(req.prompt)

    # ------------------------------------------------------------------
    # paged plumbing
    # ------------------------------------------------------------------
    def _paged_args(self, cow: List[Tuple[int, int]]):
        """Device-ready (tables, cow_src, cow_dst): the cow list is padded
        to a fixed length with (0, 0) pairs (copying the null page onto
        itself is a no-op), so the jitted step never recompiles."""
        if len(cow) > self.slots:
            raise RuntimeError(
                f"{len(cow)} COW copies in one step exceeds the padded "
                f"capacity of {self.slots} — at most one shared block can "
                f"enter a slot's write range per step")
        src = np.zeros((self.slots,), np.int32)
        dst = np.zeros((self.slots,), np.int32)
        for i, (a, b) in enumerate(cow):
            src[i], dst[i] = a, b
        return (jnp.asarray(self.paged.table), jnp.asarray(src),
                jnp.asarray(dst))

    def _maybe_insert_prefix(self, s: int):
        """Index the slot's prompt blocks once the full prompt is written
        (before any release, so the pages outlive the slot)."""
        req = self.active[s]
        if (self.paged is None or not self.paged.prefix_enabled
                or req is None or req._inserted
                or self.pos[s] < len(req.prompt)):
            return
        self.paged.insert(s, req.prompt)
        req._inserted = True

    def _release_slot(self, s: int):
        self.active[s] = None
        if self.paged is not None:
            self.paged.release(s)
            self.paged.check()
            self._check_invariants()

    def _check_invariants(self):
        """Engine-level reconciliation on top of ``PagedKVCache.check()``:
        released slots map nothing, and every non-null page is either
        free or held (slot tables / prefix index) — no leaked limbo."""
        pc = self.paged
        for s in range(self.slots):
            if self.active[s] is None and pc.mapped(s):
                raise RuntimeError(
                    f"slot {s} is free but still maps {pc.mapped(s)} "
                    f"pages — release leaked blocks")
        held = {int(pg) for srow in pc.table for pg in srow if pg}
        held |= {e.page for e in pc._index.values()}
        if len(held) + pc.free_pages != pc.pages - 1:
            raise RuntimeError(
                f"page conservation violated: {len(held)} held + "
                f"{pc.free_pages} free != {pc.pages - 1} allocatable")

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit, then decode one token for all
        slots (or one speculative round of up to ``spec_k + 1``)."""
        rec = self.rec
        self._admit()
        rec.gauge("serving.queue_depth", self.queued)
        rec.gauge("serving.slot_occupancy",
                  sum(a is not None for a in self.active) / self.slots)
        if self.paged is not None:
            rec.gauge("serving.free_pages", self.paged.free_pages)
            if self.paged.prefix_enabled and self.stats["prompt_tokens"]:
                rec.gauge("serving.prefix_hit_rate",
                          self.stats["prefix_hit_tokens"]
                          / self.stats["prompt_tokens"])
        if self.spec_k:
            if self.stats["spec_proposed"]:
                rec.gauge("serving.spec_accept_rate",
                          self.stats["spec_accepted"]
                          / self.stats["spec_proposed"])
            self._spec_step(rec)
        else:
            self._plain_step(rec)

    def _plain_step(self, rec):
        t0 = time.perf_counter()
        tokens = jnp.asarray(self.cur_tok)
        pos = jnp.asarray(self.pos)
        extra = ()
        if self.paged is not None:
            cow: List[Tuple[int, int]] = []
            for s in range(self.slots):
                if self.active[s] is not None:
                    cow += self.paged.ensure_writable(
                        s, int(self.pos[s]), int(self.pos[s]))
            extra = self._paged_args(cow)
        with obs.trace_annotation("engine_tick"):
            next_tok, self.state = self.decode_fn(self.params, self.state,
                                                  tokens, pos, *extra)
            next_tok = np.asarray(jax.device_get(next_tok))
        now = time.perf_counter()
        rec.observe("serving.decode_step_s", now - t0)
        self.stats["steps"] += 1
        decoded = 0
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            self.pos[s] += 1
            self.prev_tok[s] = self.cur_tok[s]
            self._maybe_insert_prefix(s)
            cur = getattr(req, "_prompt_cursor", len(req.prompt))
            if cur < len(req.prompt):       # still consuming the prompt
                self.cur_tok[s] = int(req.prompt[cur])
                req._prompt_cursor = cur + 1
                continue
            tok = int(next_tok[s])
            if not req.out_tokens and hasattr(req, "_submit_t"):
                rec.observe("serving.ttft_s", now - req._submit_t,
                            rid=req.rid)
            req.out_tokens.append(tok)
            self.stats["decoded_tokens"] += 1
            decoded += 1
            self.cur_tok[s] = tok
            if (tok == self.eos_id
                    or len(req.out_tokens) >= req.max_new_tokens
                    or self.pos[s] >= self.max_seq - 1):
                req.done = True
                self._release_slot(s)
        if decoded:
            rec.counter("serving.decoded_tokens", decoded)

    # ------------------------------------------------------------------
    # speculative decoding
    # ------------------------------------------------------------------
    def _spec_step(self, rec):
        """One speculative round: k+1 draft forwards (one catch-up plus k
        proposals), one batched verify, host-side longest-agreeing-run
        acceptance.  Greedy-token-identical to undrafted decode."""
        k = self.spec_k
        t0 = time.perf_counter()
        pos0 = self.pos.copy()
        # tok_block[:, j] is the token at absolute position pos0 + j;
        # column 0 is cur_tok, prompt positions are teacher-forced over
        # whatever the draft proposes
        tok_block = np.zeros((self.slots, k + 1), np.int32)
        tok_block[:, 0] = self.cur_tok

        def forced(s: int, j: int) -> Optional[int]:
            req = self.active[s]
            p = int(pos0[s]) + j
            if req is not None and p < len(req.prompt):
                return int(req.prompt[p])
            return None

        with obs.trace_annotation("spec_draft"):
            # catch-up: re-consume prev_tok at pos-1 so the draft cache
            # row the last rejection left stale is repaired before the
            # draft attends through it; its output (a prediction for the
            # already-known cur_tok) is discarded
            d_tok = jnp.asarray(self.prev_tok)
            d_pos = jnp.asarray(np.maximum(pos0 - 1, 0))
            _, self.draft_state = self.draft_fn(
                self.draft_params, self.draft_state, d_tok, d_pos)
            for j in range(1, k + 1):
                d_tok = jnp.asarray(tok_block[:, j - 1])
                d_pos = jnp.asarray(
                    np.minimum(pos0 + (j - 1), self.max_seq - 1))
                nt, self.draft_state = self.draft_fn(
                    self.draft_params, self.draft_state, d_tok, d_pos)
                prop = np.asarray(jax.device_get(nt))
                for s in range(self.slots):
                    f = forced(s, j)
                    tok_block[s, j] = int(prop[s]) if f is None else f

        extra = ()
        if self.paged is not None:
            cow: List[Tuple[int, int]] = []
            for s in range(self.slots):
                if self.active[s] is not None:
                    cow += self.paged.ensure_writable(
                        s, int(pos0[s]), int(pos0[s]) + k)
            extra = self._paged_args(cow)
        with obs.trace_annotation("spec_verify"):
            choices, self.state = self.verify_fn(
                self.params, self.state, jnp.asarray(tok_block),
                jnp.asarray(pos0), *extra)
            choices = np.asarray(jax.device_get(choices))
        now = time.perf_counter()
        rec.observe("serving.decode_step_s", now - t0)
        self.stats["steps"] += 1

        decoded = 0
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            plen = len(req.prompt)
            self.stats["spec_proposed"] += sum(
                1 for j in range(1, k + 1) if int(pos0[s]) + j >= plen)
            j = 0
            stop = False
            nxt_pos = int(pos0[s]) + 1
            nxt_tok = int(tok_block[s, 0])
            while True:
                # consume tok_block[s, j] at position pos0+j; the token at
                # pos0+j+1 is either the next forced prompt token or the
                # verifier's (== the undrafted oracle's) emission
                nxt_pos = int(pos0[s]) + j + 1
                if nxt_pos < plen:
                    nxt_tok = int(req.prompt[nxt_pos])
                    req._prompt_cursor = nxt_pos + 1
                else:
                    nxt_tok = int(choices[s, j])
                    if not req.out_tokens and hasattr(req, "_submit_t"):
                        rec.observe("serving.ttft_s", now - req._submit_t,
                                    rid=req.rid)
                    req.out_tokens.append(nxt_tok)
                    self.stats["decoded_tokens"] += 1
                    decoded += 1
                    if (nxt_tok == self.eos_id
                            or len(req.out_tokens) >= req.max_new_tokens):
                        stop = True
                if nxt_pos >= self.max_seq - 1:
                    stop = True
                if stop or j >= k:
                    break
                if int(tok_block[s, j + 1]) != nxt_tok:
                    break   # divergence — nxt_tok is the oracle correction
                if nxt_pos >= plen:
                    self.stats["spec_accepted"] += 1   # a proposal survived
                j += 1
            self.pos[s] = nxt_pos
            self.cur_tok[s] = nxt_tok
            self.prev_tok[s] = int(tok_block[s, j])
            self._maybe_insert_prefix(s)
            if stop:
                req.done = True
                self._release_slot(s)
        if decoded:
            rec.counter("serving.decoded_tokens", decoded)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if (self.queue.empty() and self._pending is None
                    and all(a is None for a in self.active)):
                break
            self.step()
        dt = time.perf_counter() - t0
        rec = self.rec
        rec.gauge("serving.drain_s", dt)
        rec.gauge("serving.tok_per_s",
                  self.stats["decoded_tokens"] / max(dt, 1e-9))
        out = {**self.stats, "wall_s": dt,
               "tok_per_s": self.stats["decoded_tokens"] / max(dt, 1e-9)}
        if self.paged is not None:
            self.paged.check()
            self._check_invariants()
            out["paged"] = dict(self.paged.stats,
                                free_pages=self.paged.free_pages,
                                index_size=self.paged.index_size)
            if self.paged.prefix_enabled:
                hit = (self.stats["prefix_hit_tokens"]
                       / max(self.stats["prompt_tokens"], 1))
                rec.gauge("serving.prefix_hit_rate", hit)
                out["prefix_hit_rate"] = hit
        if self.spec_k:
            acc = (self.stats["spec_accepted"]
                   / max(self.stats["spec_proposed"], 1))
            rec.gauge("serving.spec_accept_rate", acc)
            out["spec_accept_rate"] = acc
        return out
