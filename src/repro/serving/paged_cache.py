"""Paged KV cache: fixed-size blocks, per-slot block tables, prefix reuse.

The dense serving cache reserves ``max_seq`` KV rows per slot, so a
4k-context pool with short requests wastes almost all of its HBM.  This
module manages the paged alternative on the host: device KV lives in a
flat page pool (``params.cache_specs(paged=...)``) and every slot owns an
int32 *block table* mapping logical block ``i`` (positions
``[i*page_size, (i+1)*page_size)``) to a physical page.  Decode reads
through the table (``models/attention.paged_decode_attention``); the
engine passes the table into the jitted step each tick.

Page 0 is the reserved *null page*: table entry 0 means "unmapped", and
masked/inactive-slot writes land there harmlessly.  The allocator hands
out pages ``1..pages-1`` from a free list and refcounts every page:

* a slot mapping a page holds one reference,
* the prefix index holds one reference per cached block.

Copy-on-write: a page with ``ref > 1`` is never written in place.
:meth:`ensure_writable` swaps a fresh page into the writing slot's table
and returns ``(src, dst)`` pairs; the engine turns them into on-device
page copies *inside* the jitted decode step, so COW costs no extra
dispatch.

Prefix reuse hashes prompt tokens at block granularity into a chain
(``h_i = sha1(h_{i-1} || tokens of block i)``); full blocks are keyed by
their chain digest and a partially-filled tail block by
``(digest, tail-token tuple)``, so a hit can end mid-block.  A lookup
walks the reader's own blocks until the first miss, maps the matched
pages into the new slot's table and skips prefill for the shared span.
Entries are LRU-evicted (leaf-first, keeping chains contiguous) when the
pool runs dry.

Admission is reservation-based: :meth:`can_admit` only admits a request
if the free list plus evictable cache pages cover its worst-case block
need *and* every already-active slot's outstanding need — so an admitted
request can never deadlock on allocation mid-decode.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

Key = Tuple  # ("F", digest) for full blocks, ("P", digest, tokens) for tails


@dataclass
class _Entry:
    page: int
    ntok: int                      # tokens this block covers (== page_size
    parent: Optional[Key]          #   for full blocks, < page_size for tails)
    children: Set[Key] = field(default_factory=set)
    lru: int = 0


def _digest(prev: bytes, tokens: np.ndarray) -> bytes:
    return hashlib.sha1(prev + np.asarray(tokens, np.int32).tobytes()).digest()


class PagedKVCache:
    """Host-side page allocator + block tables + prefix index.

    ``pages`` counts physical pages *including* the reserved null page 0,
    matching the device pool's page axis."""

    def __init__(self, *, pages: int, page_size: int, slots: int,
                 max_seq: int, prefix_cache: bool = False):
        if page_size < 1:
            raise ValueError(f"page_size {page_size} must be >= 1")
        if max_seq % page_size != 0:
            raise ValueError(
                f"max_seq {max_seq} must be a multiple of page_size "
                f"{page_size} — equal logical cache length is what makes "
                f"paged decode bitwise-identical to the dense path")
        self.page_size = page_size
        self.pages = pages
        self.slots = slots
        self.max_seq = max_seq
        self.blocks_per_slot = max_seq // page_size
        if pages < self.blocks_per_slot + 1:
            raise ValueError(
                f"pool of {pages} pages cannot hold even one full slot "
                f"({self.blocks_per_slot} blocks + null page)")
        self.prefix_enabled = prefix_cache
        self.ref = np.zeros((pages,), np.int64)
        self.free: List[int] = list(range(pages - 1, 0, -1))  # pop() -> 1
        self.table = np.zeros((slots, self.blocks_per_slot), np.int32)
        # reservation bound per slot: exclusive end position the slot may
        # write up to over its lifetime (0 = slot inactive)
        self.slot_end = np.zeros((slots,), np.int64)
        self._index: Dict[Key, _Entry] = {}
        self._clock = 0
        self.stats = {"alloc": 0, "cow": 0, "evicted": 0,
                      "hit_tokens": 0, "lookup_tokens": 0}

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------
    def _alloc(self) -> int:
        if not self.free:
            self._evict(need=1)
        if not self.free:
            raise RuntimeError(
                "paged KV pool exhausted — admission reservations should "
                "make this unreachable (engine invariant violation)")
        pg = self.free.pop()
        assert self.ref[pg] == 0
        self.ref[pg] = 1
        self.stats["alloc"] += 1
        return pg

    def _unref(self, pg: int):
        self.ref[pg] -= 1
        if self.ref[pg] == 0:
            self.free.append(pg)
        assert self.ref[pg] >= 0

    def ensure_writable(self, slot: int, start_pos: int,
                        end_pos: int) -> List[Tuple[int, int]]:
        """Make blocks covering positions [start_pos, end_pos] exist and be
        exclusively owned by ``slot``; returns (src, dst) page pairs the
        engine must copy on device before the step writes."""
        end_pos = min(end_pos, self.max_seq - 1)
        cow: List[Tuple[int, int]] = []
        for li in range(start_pos // self.page_size,
                        end_pos // self.page_size + 1):
            pg = int(self.table[slot, li])
            if pg == 0:
                self.table[slot, li] = self._alloc()
            elif self.ref[pg] > 1:          # shared: copy-on-write
                new = self._alloc()
                cow.append((pg, new))
                self._unref(pg)
                self.table[slot, li] = new
                self.stats["cow"] += 1
        return cow

    def release(self, slot: int):
        """Return every page the slot maps to the pool (refcount-aware:
        pages shared with the prefix index or other slots stay alive)."""
        for li in range(self.blocks_per_slot):
            pg = int(self.table[slot, li])
            if pg:
                self._unref(pg)
        self.table[slot] = 0
        self.slot_end[slot] = 0

    def mapped(self, slot: int) -> int:
        return int(np.count_nonzero(self.table[slot]))

    # ------------------------------------------------------------------
    # admission reservations
    # ------------------------------------------------------------------
    def _slot_need(self, slot: int) -> int:
        """Worst-case pages slot may still allocate: blocks to reach its
        reserved end, plus one COW page if it maps any shared block."""
        if self.slot_end[slot] == 0:
            return 0
        total = -(-int(self.slot_end[slot]) // self.page_size)
        need = max(0, total - self.mapped(slot))
        if any(self.ref[pg] > 1 for pg in self.table[slot] if pg):
            need += 1
        return need

    def _evictable(self) -> int:
        return sum(1 for e in self._index.values() if self.ref[e.page] == 1)

    def can_admit(self, prompt_len: int, max_new: int, *,
                  shared_pages: int = 0, headroom: int = 0) -> bool:
        """True if the pool can cover this request's worst case on top of
        every active slot's outstanding reservation."""
        end = min(prompt_len + max_new + 1 + headroom, self.max_seq)
        need = -(-end // self.page_size) - shared_pages
        if shared_pages:
            need += 1                      # possible COW of the shared tail
        outstanding = sum(self._slot_need(s) for s in range(self.slots))
        return need + outstanding <= len(self.free) + self._evictable()

    def admit(self, slot: int, prompt_len: int, max_new: int, *,
              headroom: int = 0,
              shared: Optional[List[int]] = None):
        """Record the slot's lifetime reservation and map shared prefix
        pages (each mapping takes a reference)."""
        self.slot_end[slot] = min(prompt_len + max_new + 1 + headroom,
                                  self.max_seq)
        if shared:
            for li, pg in enumerate(shared):
                self.table[slot, li] = pg
                self.ref[pg] += 1

    # ------------------------------------------------------------------
    # prefix index
    # ------------------------------------------------------------------
    def lookup(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: (pages, shared token count).
        Walks full blocks by chain digest, then probes the tail at every
        length — a hit may be shorter or longer than one block."""
        if not self.prefix_enabled:
            return [], 0
        self.stats["lookup_tokens"] += len(tokens)
        bs = self.page_size
        pages: List[int] = []
        span = 0
        h = b""
        while span + bs <= len(tokens):
            h2 = _digest(h, tokens[span:span + bs])
            ent = self._index.get(("F", h2))
            if ent is None:
                break
            self._touch(("F", h2))
            pages.append(ent.page)
            span += bs
            h = h2
        rest = tokens[span:]
        for ln in range(min(len(rest), bs - 1), 0, -1):
            key = ("P", h, tuple(int(t) for t in rest[:ln]))
            ent = self._index.get(key)
            if ent is not None:
                self._touch(key)
                pages.append(ent.page)
                span += ln
                break
        self.stats["hit_tokens"] += span
        return pages, span

    def insert(self, slot: int, tokens: np.ndarray):
        """Register the slot's (fully written) prompt blocks in the index.
        Each newly indexed page gains a cache-held reference; blocks
        already present are left as-is (first writer wins)."""
        if not self.prefix_enabled:
            return
        bs = self.page_size
        h = b""
        parent: Optional[Key] = None
        for li in range(len(tokens) // bs):
            h = _digest(h, tokens[li * bs:(li + 1) * bs])
            parent = self._link(("F", h), int(self.table[slot, li]),
                                bs, parent)
        tail = tokens[(len(tokens) // bs) * bs:]
        if len(tail):
            key = ("P", h, tuple(int(t) for t in tail))
            self._link(key, int(self.table[slot, len(tokens) // bs]),
                       len(tail), parent)

    def _link(self, key: Key, page: int, ntok: int,
              parent: Optional[Key]) -> Key:
        ent = self._index.get(key)
        if ent is None:
            assert page > 0, "prefix insert before the block was written"
            self._clock += 1
            self._index[key] = _Entry(page=page, ntok=ntok, parent=parent,
                                      lru=self._clock)
            self.ref[page] += 1
            if parent is not None:
                self._index[parent].children.add(key)
        else:
            self._touch(key)
        return key

    def _touch(self, key: Key):
        self._clock += 1
        self._index[key].lru = self._clock

    def _evict(self, need: int):
        """Drop LRU leaf entries until ``need`` pages are free (leaf-first
        keeps every remaining chain reachable from block 0)."""
        while len(self.free) < need:
            leaves = [(e.lru, k) for k, e in self._index.items()
                      if not e.children]
            if not leaves:
                return
            _, key = min(leaves)
            ent = self._index.pop(key)
            if ent.parent is not None and ent.parent in self._index:
                self._index[ent.parent].children.discard(key)
            self._unref(ent.page)
            self.stats["evicted"] += 1

    # ------------------------------------------------------------------
    # invariants
    # ------------------------------------------------------------------
    def check(self):
        """Full accounting audit; raises RuntimeError on any leak or
        double-free.  Cheap enough to run at every slot release."""
        counts = np.zeros_like(self.ref)
        for s in range(self.slots):
            for pg in self.table[s]:
                if pg:
                    counts[pg] += 1
        for e in self._index.values():
            counts[e.page] += 1
        free_set = set(self.free)
        if len(free_set) != len(self.free):
            raise RuntimeError("paged cache: duplicate pages in free list")
        if 0 in free_set:
            raise RuntimeError("paged cache: null page 0 entered free list")
        for pg in range(1, self.pages):
            if counts[pg] != self.ref[pg]:
                raise RuntimeError(
                    f"paged cache: page {pg} refcount {self.ref[pg]} != "
                    f"{counts[pg]} holders (leak or double-map)")
            if (self.ref[pg] == 0) != (pg in free_set):
                raise RuntimeError(
                    f"paged cache: page {pg} ref={self.ref[pg]} but "
                    f"{'not ' if pg not in free_set else ''}in free list")
        for key, e in self._index.items():
            if e.parent is not None and e.parent in self._index \
                    and key not in self._index[e.parent].children:
                raise RuntimeError("paged cache: broken chain linkage")

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def index_size(self) -> int:
        return len(self._index)
