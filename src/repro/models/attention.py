"""Pure-JAX chunked attention (the ``ref`` compute path used on CPU and for
roofline lowering).  On real TPU, ``--use-pallas`` swaps in
:mod:`repro.kernels.flash_attention`.

Memory stays bounded via a lax.scan over KV chunks with an online-softmax
carry, so 32k prefill never materializes [b, h, s, s].
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rope(x, positions, theta: float):
    """x [b, s, h, hd]; positions [b, s] (or [s]) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, s, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _scores(q, k, softcap: float):
    """q [b, sq, kv, g, hd]; k [b, ck, kv, hd] -> [b, kv, g, sq, ck] f32."""
    s = jnp.einsum("bqkgh,bckh->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    return s


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, softcap: float = 0.0,
                      q_positions=None, kv_positions=None,
                      chunk: int = 1024, scale: Optional[float] = None):
    """Online-softmax attention scanned over KV chunks.

    q [b, sq, h, hd]; k, v [b, sk, kvh, hd]; h % kvh == 0 (GQA).
    ``q_positions``/``kv_positions`` give absolute positions for masking
    (decode passes an offset query position; padding in the KV cache is
    masked by kv_positions < 0 convention handled by the caller via mask).
    Returns [b, sq, h, hd].
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    q = (q * scale).reshape(b, sq, kvh, g, hd)
    if q_positions is None:
        q_positions = jnp.arange(sq, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(sk, dtype=jnp.int32)[None, :]
    if q_positions.ndim == 1:
        q_positions = q_positions[None, :]
    if kv_positions.ndim == 1:
        kv_positions = kv_positions[None, :]
    q_positions = jnp.broadcast_to(q_positions, (b, sq))
    kv_positions = jnp.broadcast_to(kv_positions, (b, sk))

    # bound the per-chunk score tensor (b,kvh,g,sq,chunk f32) to ~256 MB so
    # long-sequence prefill stays within HBM on the ref path
    cap = max((1 << 26) // max(b * h * sq, 1), 128)
    chunk = min(chunk, sk, cap - cap % 128 if cap >= 256 else 128)
    nchunks = (sk + chunk - 1) // chunk
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)

    kc = k.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        acc, m, l = carry          # [b,kv,g,sq,hd] f32, [b,kv,g,sq], [b,kv,g,sq]
        kb, vb, pb = inp           # [b,chunk,kv,hd], [b,chunk,kv,hd], [b,chunk]
        s = _scores(q, kb, softcap)                      # [b,kv,g,sq,chunk]
        valid = pb[:, None, None, None, :] >= 0
        if causal:
            valid &= (pb[:, None, None, None, :]
                      <= q_positions[:, None, None, :, None])
        if window is not None:
            valid &= (pb[:, None, None, None, :]
                      > q_positions[:, None, None, :, None] - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    (acc, m, l), _ = lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def gather_pages(pages, tables):
    """Materialize a slot-contiguous KV view from a page pool.

    pages [P, page, kvh, hd]; tables [b, nb] int32 -> [b, nb*page, kvh, hd].
    With ``max_seq % page == 0`` the gathered view has exactly the dense
    cache's length, so the masked softmax downstream is bitwise identical
    to the dense path (unmapped entries read the null page and are masked
    to NEG_INF either way)."""
    g = pages[tables]                       # [b, nb, page, kvh, hd]
    b, nb, page, kvh, hd = g.shape
    return g.reshape(b, nb * page, kvh, hd)


def paged_decode_attention(q, k_pages, v_pages, tables, pos, *,
                           softcap: float = 0.0,
                           scale: Optional[float] = None):
    """Single-token decode attention reading through a block table.

    q [b, 1, h, hd]; k_pages/v_pages [P, page, kvh, hd];
    tables [b, nb] physical page per logical block.  The reference path:
    gather pages into the dense layout and reuse :func:`decode_attention`
    unchanged (global attention only — local ring buffers stay dense)."""
    k = gather_pages(k_pages, tables)
    v = gather_pages(v_pages, tables)
    return decode_attention(q, k, v, pos, window=None, softcap=softcap,
                            scale=scale)


def decode_attention_multi(q, k_cache, v_cache, pos, *, softcap: float = 0.0,
                           scale: Optional[float] = None):
    """Multi-token (speculative verify) decode attention over a KV cache.

    q [b, qn, h, hd] carries qn consecutive tokens at absolute positions
    ``pos + j``; cache entry at slot s is visible to query j iff
    s <= pos + j (entries for the block itself were written by the caller
    before attending, mirroring single-token decode's write-then-attend).
    Returns [b, qn, h, hd]."""
    b, qn, h, hd = q.shape
    _, S, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).reshape(b, qn, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_cache.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slots = jnp.arange(S, dtype=jnp.int32)[None, None, :]        # [1, 1, S]
    qpos = pos[:, None] + jnp.arange(qn, dtype=jnp.int32)[None, :]
    valid = slots <= qpos[:, :, None]                            # [b, qn, S]
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", p, v_cache.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, qn, h, hd).astype(q.dtype)


def paged_decode_attention_multi(q, k_pages, v_pages, tables, pos, *,
                                 softcap: float = 0.0,
                                 scale: Optional[float] = None):
    """Multi-token verify attention through a block table (paged cache)."""
    k = gather_pages(k_pages, tables)
    v = gather_pages(v_pages, tables)
    return decode_attention_multi(q, k, v, pos, softcap=softcap, scale=scale)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None,
                     softcap: float = 0.0, scale: Optional[float] = None,
                     ring: bool = False):
    """Single-token decode attention over a KV cache.

    q [b, 1, h, hd]; k_cache/v_cache [b, S, kvh, hd]; pos [b] current absolute
    position (the new token's position; cache entries at slots > pos are
    invalid).  ``ring=True`` means the cache is a circular window buffer of
    size S=window (slot = pos % window) so all slots written so far are valid.
    """
    b, _, h, hd = q.shape
    _, S, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).reshape(b, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slots = jnp.arange(S, dtype=jnp.int32)[None, :]        # [1, S]
    if ring:
        written = jnp.minimum(pos[:, None] + 1, S)
        valid = slots < written
    else:
        valid = slots <= pos[:, None]
        if window is not None:
            valid &= slots > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
