"""Mixture-of-Experts FFN with capacity-based routing.

Two sharding patterns (DESIGN.md §Arch-applicability):

* ``ep``  — experts sharded over the model axis (moonshot: 64/16 = 4 local
  experts).  Activations are replicated over the model axis (Megatron-style),
  so each shard selects the tokens routed to *its* experts, computes them,
  and the combine is a single AllReduce — the same compute→collective block
  structure the Oases schedule overlaps.
* ``tmp`` — every shard holds all experts with the expert FFN width sharded
  (granite-moe: 40 experts, d_ff 512/16 = 32); row-parallel combine AllReduce.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import tmp as tmpc


def capacity(tokens: int, top_k: int, num_experts: int, factor: float) -> int:
    return max(8, math.ceil(tokens * top_k / num_experts * factor))


def route(x2d, router_w, top_k: int):
    """x2d [t, D]; router_w [D, E] -> (weights [t,k], experts [t,k], aux)."""
    logits = jnp.dot(x2d.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, e = lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    E = router_w.shape[1]
    frac_prob = jnp.mean(probs, axis=0)
    assign = jax.nn.one_hot(e[:, 0], E, dtype=jnp.float32)
    frac_tok = jnp.mean(assign, axis=0)
    aux = E * jnp.sum(frac_prob * frac_tok)
    return w, e, aux


def _dispatch_positions(experts_flat, num_experts: int, cap: int):
    """Position of each (token,choice) within its expert's capacity buffer."""
    oh = jax.nn.one_hot(experts_flat, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh                   # rank among same-expert
    posf = jnp.take_along_axis(pos, experts_flat[:, None], axis=1)[:, 0]
    keep = posf < cap
    return posf, keep


def moe_ffn(x, p, *, num_experts: int, top_k: int, cap_factor: float,
            sharding: str, tp_axes: Tuple[str, ...], reduce_fn=None):
    """x [b, s, D] (replicated over tp axes). Returns (delta [b,s,D], aux)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    w, e, aux = route(x2d, p["router"], top_k)
    cap = capacity(t, top_k, num_experts, cap_factor)

    ef = e.reshape(-1)                                   # [t*k]
    wf = w.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    posf, keep = _dispatch_positions(ef, num_experts, cap)

    e_local = p["w1"].shape[0]                           # local expert count
    if sharding == "ep":
        shard = tmpc.axes_index(tp_axes)
        local = (ef // e_local) == shard
        le = ef - shard * e_local
    else:                                                # 'tmp': all experts local
        local = jnp.ones_like(keep)
        le = ef
    sel = keep & local
    le_c = jnp.where(sel, le, 0)
    pos_c = jnp.where(sel, posf, 0)

    # gather tokens into [E_local, C, D]
    buf = jnp.zeros((e_local, cap, d), x.dtype)
    vals = jnp.where(sel[:, None], jnp.take(x2d, tok_idx, axis=0),
                     jnp.zeros((1, d), x.dtype))
    buf = buf.at[le_c, pos_c].add(vals, mode="drop")

    # expert FFN (swiglu), batched over local experts
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"])     # [E_l, C, D]

    # combine back to tokens (weighted)
    gathered = out_buf[le_c, pos_c]                      # [t*k, D]
    gathered = jnp.where(sel[:, None], gathered, 0.0)
    contrib = gathered * wf[:, None].astype(gathered.dtype)
    out = jnp.zeros((t, d), contrib.dtype).at[tok_idx].add(contrib)

    # EP: each shard contributed only its experts -> AllReduce completes it.
    # TMP: each shard computed a d_ff-partial sum   -> AllReduce completes it.
    # (reduce on [b, s, d] so the SP reduce-scatter acts on the seq dim)
    reduce_fn = reduce_fn or (lambda y: tmpc.tmp_reduce(y, tp_axes))
    out = reduce_fn(out.reshape(b, s, d))
    return out.astype(x.dtype), aux
