from repro.models import attention, blocks, lm, moe, params, rglru, ssd

__all__ = ["attention", "blocks", "lm", "moe", "params", "rglru", "ssd"]
