"""Whole-model assembly + jit-able step functions.

The entire forward (and loss) runs inside ONE ``shard_map`` over the
production mesh so every TMP collective is explicit (``jax.lax.psum`` via
:mod:`repro.core.tmp`) and the Oases schedule controls its placement —
faithful to the paper rather than GSPMD-inferred communication.

Gradients: parameters enter the body replicated over their non-sharded mesh
axes; ``copy_to_tmp(w, replicated_axes)`` makes the backward emit the
correct gradient AllReduce over exactly those axes (this is also where the
classic DP-gradient-overlap happens — the psum sits inside backward where
the latency-hiding scheduler can overlap it with remaining compute).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import GLOBAL_ATTN, ArchConfig, TrainHParams
from repro.core import compat
from repro.core import tmp as tmpc
from repro.core.axes import MeshInfo, batch_pspec, mesh_info
from repro.core.remat import maybe_checkpoint
from repro.core.schedule import (TmpCtx, apply_layer, effective_split,
                                 merge_tree, split_tree)
from repro.models import blocks as blk
from repro.models import params as prm


# --------------------------------------------------------------------------
def _positions(b, s, dtype=jnp.int32):
    return jnp.broadcast_to(jnp.arange(s, dtype=dtype)[None, :], (b, s))


def _sp_degraded(what: str, reasons: Sequence[str]):
    """Surface an intentional seq-parallel/seq-shard degradation instead
    of silently dropping the flag (PR 5 rejects unknown schedules at
    construction; numerics-preserving fallbacks warn + emit telemetry)."""
    import warnings
    from repro.obs.recorder import get_recorder
    msg = f"{what} degraded: {'; '.join(reasons)}"
    get_recorder().event("parallelism.degraded", msg, what=what)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _run_encoder(cfg, ctx, params, ctx_embed):
    import dataclasses
    if ctx.seq_parallel:
        # encoder activations are not sequence-sharded (the decoder's cross
        # attention needs the full encoded sequence on every shard) — an
        # intentional, numerics-preserving degradation, surfaced once at
        # trace time rather than silently dropped
        _sp_degraded("seq_parallel", [
            "encoder blocks run full-sequence (the decoder's cross "
            "attention needs the whole encoded sequence on every shard)"])
        ctx = dataclasses.replace(ctx, seq_parallel=False,
                                  seq_shard=1)
    enc = params["encoder"]
    x = ctx_embed + enc["pos_embed"][None, : ctx_embed.shape[1]].astype(
        ctx_embed.dtype)
    layer = blk.encoder_layer_fn(cfg, ctx)

    def body(carry, p):
        return layer(p, carry), None

    x, _ = lax.scan(body, x, enc["blocks"])
    return tmpc.rms_norm(x, enc["final_ln"], cfg.norm_eps)


def _stack_scan(cfg, ctx, hp, params, xs, auxs, *, train=True):
    """Scan over stacked pattern blocks + unrolled tail. xs: list of
    sub-batches. Returns (xs, aux_loss_sum)."""
    n, pat, tail = prm.stack_layout(cfg)
    parts = {k: blk.train_parts(cfg, ctx, k) for k in set(pat) | set(tail)}

    def block_body(carry, layer_params):
        xs_c, aux_c = carry
        for pos, kind in enumerate(pat):
            xs_c, a = apply_layer(parts[kind], layer_params[pos], xs_c, auxs,
                                  hp.schedule)
            aux_c = aux_c + a
        return (xs_c, aux_c), None

    body = block_body
    if train:
        body = maybe_checkpoint(block_body, remat=hp.remat,
                                fine=hp.fine_remat)
    # NOTE: the aux carry is kept rank-1: jax 0.4.x shard_map mis-names
    # rank-0 scan-carry residuals under the fine-remat policy (see
    # core/compat.py); a (1,) carry sidesteps it at zero cost.
    carry = (xs, jnp.zeros((1,), jnp.float32))
    if n:
        carry, _ = lax.scan(body, carry, tuple(params["blocks"]))
    xs, aux = carry
    for i, kind in enumerate(tail):
        if train:
            def tail_body(carry, p, kind=kind):
                xs_c, a = apply_layer(parts[kind], p, carry[0], auxs,
                                      hp.schedule)
                return (xs_c, carry[1] + a), None
            tail_body = maybe_checkpoint(tail_body, remat=hp.remat,
                                         fine=hp.fine_remat)
            (xs, aux), _ = tail_body((xs, aux), params["tail"][i])
        else:
            xs, a = apply_layer(parts[kind], params["tail"][i], xs, auxs,
                                hp.schedule)
            aux = aux + a
    return xs, jnp.sum(aux)


# --------------------------------------------------------------------------
# pipeline-parallel forward (interleaved 1F1B over the 'pipe' mesh axis)
# --------------------------------------------------------------------------
def _pipeline_scan(cfg, ctx, info: MeshInfo, hp, params, x):
    """Run the layer stack as an SPMD pipeline (core/pipeline.py).

    ``x`` [b, s, d] is the embedded batch, replicated over ``pipe`` and
    batch-sharded over the data axes as usual.  It is cut into
    ``hp.microbatch`` microbatches that stream through the stages; each
    stage applies its layer chunk with the unchanged TMP machinery
    (``apply_layer`` + the schedule's sub-batch split), so stage-internal
    collectives overlap exactly as without PP.  Returns ``(x, aux)`` where
    ``x`` is valid on the last stage only (masked downstream)."""
    from repro.core import pipeline as pl

    pp = info.pp
    v = max(hp.virtual_stages, 1)
    n_micro = max(hp.microbatch, 1)
    b, s = x.shape[0], x.shape[1]
    if b % n_micro:
        raise ValueError(
            f"pipeline microbatch count {n_micro} must divide the "
            f"per-shard batch {b} (global batch / dp)")
    mb = b // n_micro
    _, pat, _ = prm.stack_layout(cfg)
    parts = {k: blk.train_parts(cfg, ctx, k) for k in set(pat)}
    positions = _positions(mb, s)

    def stage_fn(c, h):
        # this device's virtual-stage chunk c: leading dims [v, 1(pipe), per]
        chunk = tuple(jax.tree_util.tree_map(lambda t: t[c, 0], bl)
                      for bl in params["blocks"])
        split = effective_split(hp.schedule, hp.split, mb)
        hs = split_tree(h, split)
        auxs = [{"positions": positions[: mb // split]}
                for _ in range(split)]

        def body(carry, layer_params):
            hs_c, a_c = carry
            for pos, kind in enumerate(pat):
                hs_c, a = apply_layer(parts[kind], layer_params[pos], hs_c,
                                      auxs, hp.schedule)
                a_c = a_c + a
            return (hs_c, a_c), None

        body = maybe_checkpoint(body, remat=hp.remat, fine=hp.fine_remat)
        (hs, aux), _ = lax.scan(body, (hs, jnp.zeros((1,), jnp.float32)),
                                chunk)
        return merge_tree(hs) if len(hs) > 1 else hs[0], aux

    x_mb = x.reshape((n_micro, mb) + tuple(x.shape[1:]))
    out, aux = pl.pipeline_apply(stage_fn, x_mb,
                                 pipe_axis=info.pipe_axes[0], pp=pp,
                                 virtual_stages=v)
    # each layer accumulates its (mean-normalized) aux once per microbatch
    # here but once per pass in the non-PP paths — renormalize so the aux
    # term does not grow with the 1F1B microbatch count
    return out.reshape((b,) + tuple(x.shape[1:])), jnp.sum(aux) / n_micro


# --------------------------------------------------------------------------
# planner-mode (mixed per-layer TMP degrees on the factored mesh)
# --------------------------------------------------------------------------
def _grouped_scan(cfg, info, hp, params, x, degrees, schedules=None,
                  seqs=None):
    """Mixed-strategy forward (planner mode): consecutive layers sharing
    ``(degree, schedule)`` execute as one scan group, each under its own
    ``TmpCtx`` and sub-batch split.

    Mixed DEGREES need the factored mesh: activations are replicated over
    all t-axes in Megatron style; the *batch* dim is additionally sharded
    over the t-axes a low-degree group reuses for data parallelism.
    Degree transitions therefore reshard the batch: degree decrease = free
    local slice (``batch_split``), degree increase = AllGather — exactly
    the Eq. 4 edge costs the planner charges.  Mixed SCHEDULES at uniform
    (mesh-following, ``degree=None``) groups run on any mesh: the reshard
    degenerates to a no-op and only the split/overlap structure changes
    between groups — numerically exact either way."""
    cur_axes: tuple = ()

    def reshard(x, new_axes):
        # The batch chunk held under ``cur_axes`` is indexed by the
        # LINEARIZED axes_index over the whole tuple, so partial
        # gathers/splits (only the changed axes) interleave chunks and
        # permute the batch against the labels — gather everything, then
        # re-split over the new tuple.  (Pure splits/gathers from/to the
        # replicated state keep the cheap single-collective form.)
        nonlocal cur_axes
        if new_axes != cur_axes:
            if cur_axes:
                x = tmpc.sp_all_gather(x, cur_axes, 0)
            if new_axes:
                x = tmpc.batch_split(x, new_axes, 0)
            cur_axes = new_axes
        return x

    aux_total = jnp.zeros((1,), jnp.float32)   # rank-1: see _stack_scan NOTE
    for g_params, g in zip(params["groups"],
                           prm.plan_groups(cfg, degrees, schedules, seqs)):
        sched = g.schedule if schedules is not None else hp.schedule
        ctx = TmpCtx(info, degree=g.degree, schedule=sched,
                     use_pallas=hp.use_pallas, layout=hp.tmp_layout,
                     seq_parallel=g.seq > 1, seq_shard=g.seq)
        x = reshard(x, info.extra_dp_axes(g.degree))
        s_full = x.shape[1]
        if g.seq > 1:
            # ring group (DESIGN.md §12): activations enter seq-sharded
            # over the group's model axes and leave gathered — the seq
            # analogue of the batch reshard edges above
            if g.seq != ctx.tp_total:
                raise ValueError(
                    f"layer group seq={g.seq} must equal its model group "
                    f"size ({ctx.tp_total}) — the KV ring spans exactly "
                    f"the group the heads would have sharded over")
            if s_full % g.seq:
                raise ValueError(
                    f"seq_len {s_full} is not divisible by the group's "
                    f"seq={g.seq}")
            x = tmpc.batch_split(x, ctx.tp_axes, 1)
        parts = blk.train_parts(cfg, ctx, g.kind)
        b = x.shape[0]
        split = effective_split(sched, hp.split, b)
        xs = split_tree(x, split)
        auxs = [{"positions": _positions(b // split, s_full)}
                for _ in range(split)]

        def body(carry, p, parts=parts, auxs=auxs, sched=sched):
            xs_c, a_c = carry
            xs_c, a = apply_layer(parts, p, xs_c, auxs, sched)
            return (xs_c, a_c + a), None

        body = maybe_checkpoint(body, remat=hp.remat, fine=hp.fine_remat)
        (xs, aux_total), _ = lax.scan(body, (xs, aux_total), g_params)
        x = merge_tree(xs) if len(xs) > 1 else xs[0]
        if g.seq > 1:
            x = tmpc.sp_all_gather(x, ctx.tp_axes, 1)
    x = reshard(x, ())
    return x, jnp.sum(aux_total)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------
def _normalize_strategy(cfg, hp, degrees, schedules, seqs=None):
    """One normalization of the per-layer strategy inputs:

    * uniform per-layer schedules collapse into ``hp.schedule`` (the
      stacked fast path) when no degrees are pinned;
    * mixed schedules with no pinned degrees promote to the grouped path
      with mesh-following ``degree=None`` groups;
    * per-layer ring-attention ``seqs`` collapse into ``hp.seq_shard``
      when uniform over the whole stack (else they ride the grouped
      path); a uniform ``hp.seq_shard`` over a grouped plan re-expands
      into per-layer seqs;
    * the grouped path always carries an explicit schedule list so the
      spec grouping (models/params.py) and the execution grouping
      (``_grouped_scan``) agree by construction.
    """
    import dataclasses
    if seqs is not None:
        seqs = list(seqs)
        if len(seqs) != cfg.num_layers:
            raise ValueError(
                f"per-layer seqs have {len(seqs)} entries for a "
                f"{cfg.num_layers}-layer model")
        if len(set(seqs)) == 1:
            hp = dataclasses.replace(hp, seq_shard=seqs[0])
            seqs = None
    if schedules is not None:
        schedules = list(schedules)
        if len(schedules) != cfg.num_layers:
            raise ValueError(
                f"per-layer schedules have {len(schedules)} entries for "
                f"a {cfg.num_layers}-layer model")
        if len(set(schedules)) == 1:
            hp = dataclasses.replace(hp, schedule=schedules[0])
            schedules = None
        elif degrees is None:
            degrees = [None] * cfg.num_layers
    if seqs is not None and degrees is None:
        # mixed per-layer seqs always run the grouped path
        degrees = [None] * cfg.num_layers
    if degrees is not None and schedules is None:
        schedules = [hp.schedule] * cfg.num_layers
    if degrees is not None and seqs is None and hp.seq_shard > 1:
        # a uniform seq_shard on a grouped plan becomes per-layer seqs so
        # the spec/execution grouping carries it
        seqs = [hp.seq_shard] * cfg.num_layers
        hp = dataclasses.replace(hp, seq_shard=1)
    return degrees, schedules, seqs, hp



def build_train_loss(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                     global_batch: int, seq_len: int,
                     degrees: Optional[Sequence[int]] = None,
                     schedules: Optional[Sequence[str]] = None,
                     seqs: Optional[Sequence[int]] = None):
    """Returns (loss_fn(params, batch) -> (loss, aux), specs, in_specs).

    ``degrees``/``schedules`` are the per-layer strategy of an executable
    :class:`~repro.core.plan.ParallelPlan`: mixed entries run through the
    grouped scan (consecutive layers sharing ``(degree, schedule)`` form
    one scan group); uniform plans keep the classic stacked layout.  A
    per-layer SCHEDULE list with uniform degrees runs on any mesh (the
    groups all follow the mesh model group); mixed DEGREES need the
    factored mesh as before."""
    info = mesh_info(mesh)
    degrees, schedules, seqs, hp = _normalize_strategy(cfg, hp, degrees,
                                                       schedules, seqs)
    # SP composes with the 1D layout only: in 2D the block entries/exits
    # are already per-axis collectives, not the SP AG/RS pair.  Under PP
    # the stage boundary ships the full-sequence activation, so SP is off.
    base_ctx = TmpCtx(info, layout=hp.tmp_layout)
    twod = base_ctx.is_2d
    blockers = []
    if info.tp <= 1:
        blockers.append("the mesh has no model axes (tp=1)")
    if degrees is not None:
        blockers.append("per-layer strategies run the grouped path "
                        "(groups shard their own sequences)")
    if seq_len % max(info.tp, 1):
        blockers.append(f"seq_len {seq_len} is not divisible by the "
                        f"model group size {info.tp}")
    if twod:
        blockers.append("the 2D layout's block entries/exits are "
                        "per-axis collectives, not the SP AG/RS pair")
    if info.pp > 1:
        blockers.append("pipeline stage boundaries ship full sequences")
    ring = hp.seq_shard > 1 and degrees is None
    if ring:
        # ring attention is a new, memory/layout-changing mode: an
        # unsatisfiable --seq-shard is a hard error, not a silent
        # fallback (satellite of ISSUE 9; cf. PR 5's schedule rejection)
        ring_blockers = list(blockers)
        if info.tp > 1 and hp.seq_shard != base_ctx.tp_total:
            ring_blockers.append(
                f"seq_shard {hp.seq_shard} != model group size "
                f"{base_ctx.tp_total} (the KV ring spans exactly the "
                f"group the heads would have sharded over)")
        if seq_len % hp.seq_shard:
            ring_blockers.append(
                f"seq_len {seq_len} is not divisible by seq_shard "
                f"{hp.seq_shard}")
        if ring_blockers:
            raise ValueError(
                "seq_shard (ring attention) cannot run here: "
                + "; ".join(ring_blockers))
    sp = bool((hp.seq_parallel or ring) and not blockers)
    if hp.seq_parallel and blockers and not ring:
        _sp_degraded("seq_parallel", blockers)
    specs = prm.model_specs(cfg, info, degrees=degrees, max_pos=seq_len,
                            layout=hp.tmp_layout,
                            virtual_stages=hp.virtual_stages,
                            schedules=schedules, seqs=seqs,
                            seq_shard=hp.seq_shard if ring else 1)
    ctx = TmpCtx(info, schedule=hp.schedule, use_pallas=hp.use_pallas,
                 seq_parallel=sp, seq_shard=hp.seq_shard if ring else 1,
                 layout=hp.tmp_layout)
    bspec = batch_pspec(info, global_batch)
    batch_specs = {"tokens": bspec, "labels": bspec}
    if cfg.context_len:
        batch_specs["ctx"] = bspec

    def body(params, batch):
        # NOTE: shard_map's transpose already emits the gradient AllReduce
        # over every axis a parameter's in_spec leaves replicated (incl. the
        # data axes — the classic DP gradient all-reduce, placed inside
        # backward where the latency-hiding scheduler overlaps it).
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        x = tmpc.vocab_parallel_embed(tokens, params["embed"], ctx.tp_axes,
                                      sp_seq_dim=1 if ctx.seq_parallel
                                      else None)
        if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if "pos_embed" in params:
            pe = params["pos_embed"][None, :s].astype(x.dtype)
            x = x + (tmpc.batch_split(pe, ctx.tp_axes, 1)
                     if ctx.seq_parallel else pe)
        enc_out = None
        if cfg.is_encdec:
            enc_out = _run_encoder(cfg, ctx, params, batch["ctx"])
        elif cfg.context_len:
            enc_out = batch["ctx"]

        positions = _positions(b, s)
        if degrees is not None:
            x, aux = _grouped_scan(cfg, info, hp, params, x, degrees,
                                   schedules, seqs)
        elif info.pp > 1:
            x, aux = _pipeline_scan(cfg, ctx, info, hp, params, x)
        else:
            split = effective_split(hp.schedule, hp.split, b)
            xs = split_tree(x, split)
            auxs = []
            for j in range(split):
                a = {"positions": positions[:b // split]}
                if enc_out is not None:
                    a["ctx"] = split_tree(enc_out, split)[j]
                auxs.append(a)
            xs, aux = _stack_scan(cfg, ctx, hp, params, xs, auxs)
            x = merge_tree(xs) if len(xs) > 1 else xs[0]

        x = ctx.gather_seq(x)       # SP: reassemble for the LM-head loss
        x = tmpc.rms_norm(x, params["final_ln"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        loss_sum, count = tmpc.vocab_parallel_xent(
            x, head, labels, ctx.tp_axes, chunk=hp.loss_chunk,
            softcap=cfg.final_softcap)
        # aggregate over every batch-sharded axis; under PP only the last
        # stage holds real outputs — mask, then psum over pipe as well
        agg_axes = info.batch_axes
        if info.pp > 1:
            from repro.core import pipeline as pl
            loss_sum = pl.mask_to_last_stage(loss_sum, info.pipe_axes[0],
                                             info.pp)
            count = pl.mask_to_last_stage(count, info.pipe_axes[0], info.pp)
            agg_axes = pl.pipeline_batch_axes(info)
        loss_sum = tmpc.reduce_from_tmp(loss_sum, agg_axes)
        count = lax.psum(count, agg_axes) if agg_axes else count
        aux = tmpc.reduce_from_tmp(aux / max(cfg.num_layers, 1),
                                   agg_axes) / max(info.dp, 1)
        return loss_sum / count + aux, aux

    in_specs = (prm.pspec_tree(specs), batch_specs)
    sm = compat.shard_map(body, mesh=mesh,
                          in_specs=(in_specs[0],
                                    {k: v for k, v in batch_specs.items()}),
                          out_specs=(P(), P()), check_vma=False)
    return sm, specs, in_specs


def greedy_token(logits_local, tp_axes):
    """Vocab-parallel greedy sampling: [b, V_local] -> [b] global ids."""
    v_local = logits_local.shape[-1]
    off = tmpc.axes_index(tp_axes) * v_local
    val = jnp.max(logits_local, axis=-1)
    idx = jnp.argmax(logits_local, axis=-1).astype(jnp.int32) + off
    if not tp_axes:
        return idx
    vals = lax.all_gather(val, tp_axes)        # [tp, b]
    idxs = lax.all_gather(idx, tp_axes)
    win = jnp.argmax(vals, axis=0)
    return jnp.take_along_axis(idxs, win[None], axis=0)[0]


def _last_logits(cfg, params, x_last, ctx):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(x_last.astype(jnp.float32), head.astype(jnp.float32))
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _decode_embed(cfg, ctx, params, tokens, pos):
    """Shared decode-step preamble: vocab-parallel embed of the current
    token + family scaling + clamped pos-embed gather (one source for the
    plain and pipeline decode bodies)."""
    x = tmpc.vocab_parallel_embed(tokens[:, None], params["embed"],
                                  ctx.tp_axes)
    if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if "pos_embed" in params:
        pe = jnp.take(params["pos_embed"], jnp.minimum(
            pos, params["pos_embed"].shape[0] - 1), axis=0)
        x = x + pe[:, None].astype(x.dtype)
    return x


def _apply_cow(state, pat, tail, cow_src, cow_dst):
    """On-device copy-on-write for paged KV pools: copy page ``src`` over
    page ``dst`` in every GLOBAL_ATTN layer's k/v pool before the step
    writes.  ``cow_src``/``cow_dst`` are fixed-length int32 arrays padded
    with (0, 0) no-ops (page 0 is the reserved null page), so COW costs
    zero extra dispatches and the jitted step shape never changes.  The
    page axis is always -4 ([..., pages, page, kvh, hd]), which covers
    both the flat and the pipeline-restacked layouts."""
    def fix(entry):
        e = dict(entry)
        for key in ("k", "v"):
            leaf = e[key]
            taken = jnp.take(leaf, cow_src, axis=-4)
            idx = (Ellipsis, cow_dst) + (slice(None),) * 3
            e[key] = leaf.at[idx].set(taken)
        return e

    out = dict(state)
    out["blocks"] = [fix(ent) if pat[i] == GLOBAL_ATTN else ent
                     for i, ent in enumerate(state["blocks"])]
    out["tail"] = [fix(ent) if tail[i] == GLOBAL_ATTN else ent
                   for i, ent in enumerate(state.get("tail", []))]
    return out


def _no_pipe(info: MeshInfo, what: str):
    if info.pp > 1:
        raise ValueError(
            f"{what} does not support a 'pipe' mesh axis yet — decode "
            f"streams through pipeline stages (build_decode) but the "
            f"batched prefill path runs on a data x model mesh; drop the "
            f"pipe axis or admit prompts through decode steps (the "
            f"serving engine's default)")


def build_prefill(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                  global_batch: int, seq_len: int):
    """prefill_step(params, batch) -> (next_token [b], state)."""
    info = mesh_info(mesh)
    _no_pipe(info, "prefill")
    specs = prm.model_specs(cfg, info, max_pos=seq_len + 1,
                            layout=hp.tmp_layout)
    ctx = TmpCtx(info, schedule=hp.schedule, use_pallas=hp.use_pallas,
                 layout=hp.tmp_layout)
    bspec = batch_pspec(info, global_batch)
    st_specs = prm.cache_specs(cfg, info, batch=global_batch, seq=seq_len,
                               batch_spec=bspec, layout=hp.tmp_layout)
    batch_specs = {"tokens": bspec}
    if cfg.context_len:
        batch_specs["ctx"] = bspec
    n, pat, tail = prm.stack_layout(cfg)

    def body(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = tmpc.vocab_parallel_embed(tokens, params["embed"], ctx.tp_axes)
        if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if "pos_embed" in params:
            x = x + params["pos_embed"][None, :s].astype(x.dtype)
        enc_out = None
        if cfg.is_encdec:
            enc_out = _run_encoder(cfg, ctx, params, batch["ctx"])
        elif cfg.context_len:
            enc_out = batch["ctx"]
        aux = {"positions": _positions(b, s), "ctx": enc_out}

        fns = {k: blk.prefill_fn(cfg, ctx, k) for k in set(pat) | set(tail)}
        sts: Dict[str, Any] = {"blocks": [], "tail": []}

        def block_body(x, layer_params):
            st_out = []
            for pos, kind in enumerate(pat):
                x, st = fns[kind](layer_params[pos], x, aux)
                st_out.append(st)
            return x, tuple(st_out)

        if n:
            x, stacked = lax.scan(block_body, x, tuple(params["blocks"]))
            sts["blocks"] = list(stacked)
        for i, kind in enumerate(tail):
            x, st = fns[kind](params["tail"][i], x, aux)
            sts["tail"].append(jax.tree_util.tree_map(lambda t: t[None], st))

        x = tmpc.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = _last_logits(cfg, params, x[:, -1], ctx)
        return greedy_token(logits, ctx.tp_axes), sts

    st_out_specs = prm.pspec_tree(st_specs)
    sm = compat.shard_map(
        body, mesh=mesh, in_specs=(prm.pspec_tree(specs), batch_specs),
        out_specs=(bspec, st_out_specs), check_vma=False)
    return sm, specs, st_specs


def build_decode(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                 global_batch: int, seq_len: int, n_micro: int = 0,
                 paged=None):
    """serve_step(params, state, tokens [b], pos [b]) -> (next [b], state).

    Decode runs under the same ``TmpCtx`` schedule machinery as training:
    ``hp.schedule == "fused"`` streams the projection all-reduces as rings
    chunked over the slot batch (the seq dim is 1 at decode — see
    ``TmpCtx._ring_dim``), so the collective transfers hide under the
    matmul tiles even at batch-1 shapes.  On a mesh with a ``pipe`` axis
    the layer stack is stage-sharded and the slot batch streams through the
    stages as ``n_micro`` micro-groups (``core/pipeline.decode_stream``):
    stage ``s`` decodes micro-group ``g`` while stage ``s-1`` decodes
    ``g+1``, with per-stage KV caches staying put on their stage.

    ``paged=(pages, page_size)`` switches GLOBAL_ATTN caches to the page
    pool layout and the step signature to
    ``(params, state, tokens, pos, tables, cow_src, cow_dst)`` — the
    engine passes each slot's block table every tick and schedules
    copy-on-write page copies through the padded cow arrays
    (:mod:`repro.serving.paged_cache`).  The slot batch runs replicated
    in paged mode (the pool is shared across slots, so data axes shard
    requests across engine replicas, not slots within a pool)."""
    info = mesh_info(mesh)
    specs = prm.model_specs(cfg, info, max_pos=seq_len + 8,
                            layout=hp.tmp_layout,
                            virtual_stages=hp.virtual_stages)
    ctx = TmpCtx(info, schedule=hp.schedule, use_pallas=hp.use_pallas,
                 layout=hp.tmp_layout)
    bspec = P() if paged is not None else batch_pspec(info, global_batch)
    st_specs = prm.cache_specs(cfg, info, batch=global_batch, seq=seq_len,
                               batch_spec=bspec, layout=hp.tmp_layout,
                               virtual_stages=hp.virtual_stages, paged=paged)
    n, pat, tail = prm.stack_layout(cfg)
    if info.pp > 1:
        return _build_decode_pp(cfg, mesh, hp, info, ctx, specs, st_specs,
                                bspec, global_batch, n_micro, paged=paged)

    def body(params, state, tokens, pos, *extra):
        aux = {"pos": pos}
        if paged is not None:
            tables, cow_src, cow_dst = extra
            state = _apply_cow(state, pat, tail, cow_src, cow_dst)
            aux["tables"] = tables
        x = _decode_embed(cfg, ctx, params, tokens, pos)
        fns = {k: blk.decode_fn(cfg, ctx, k) for k in set(pat) | set(tail)}

        # KV caches ride in the scan CARRY and are updated with in-place
        # dynamic_update_slice at the layer index — XLA aliases the (donated)
        # input cache straight through the loop, so decode temp memory stays
        # O(one layer), not O(2x full cache).
        def block_body(carry, inp):
            x, st_stack = carry
            layer_params, i = inp
            st_out = []
            for p_, kind in enumerate(pat):
                st_i = jax.tree_util.tree_map(
                    lambda t: lax.dynamic_index_in_dim(t, i, 0, False),
                    st_stack[p_])
                x, st = fns[kind](layer_params[p_], x, st_i, aux)
                st_out.append(st)
            st_stack = tuple(
                jax.tree_util.tree_map(
                    lambda t, s: lax.dynamic_update_index_in_dim(
                        t, s.astype(t.dtype), i, 0), st_stack[p_], st_out[p_])
                for p_ in range(len(pat)))
            return (x, st_stack), None

        new_state: Dict[str, Any] = {"blocks": [], "tail": []}
        if n:
            (x, blocks_st), _ = lax.scan(
                block_body, (x, tuple(state["blocks"])),
                (tuple(params["blocks"]), jnp.arange(n, dtype=jnp.int32)))
            new_state["blocks"] = list(blocks_st)
        for i, kind in enumerate(tail):
            st_i = jax.tree_util.tree_map(lambda t: t[0], state["tail"][i])
            x, st = fns[kind](params["tail"][i], x, st_i, aux)
            new_state["tail"].append(
                jax.tree_util.tree_map(lambda t: t[None], st))

        x = tmpc.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = _last_logits(cfg, params, x[:, 0], ctx)
        return greedy_token(logits, ctx.tp_axes), new_state

    st_ps = prm.pspec_tree(st_specs)
    extra_ps = (P(), P(), P()) if paged is not None else ()
    sm = compat.shard_map(
        body, mesh=mesh,
        in_specs=(prm.pspec_tree(specs), st_ps, bspec, bspec) + extra_ps,
        out_specs=(bspec, st_ps), check_vma=False)
    return sm, specs, st_specs


def _build_decode_pp(cfg, mesh, hp, info, ctx, specs, st_specs, bspec,
                     global_batch, n_micro, paged=None):
    """Pipeline-parallel serve_step: per-stage token micro-step streaming.

    Stage ``s = c*pp + d`` holds layers ``[s*n/S, (s+1)*n/S)`` of the
    ``[v, pp, per]``-stacked params AND their KV caches; only activations
    ride the ``pipe`` ppermute ring.  The final hidden state is valid on
    the last stage — masked and psum-broadcast over ``pipe`` so every
    device samples the identical greedy token (the engine reads one global
    array)."""
    from repro.core import pipeline as pl
    from repro.core.axes import local_batch
    n, pat, _tail = prm.stack_layout(cfg)
    v = max(hp.virtual_stages, 1)
    per = n // (info.pp * v)
    pipe_ax = info.pipe_axes[0]
    # paged mode runs the slot batch replicated (shared page pool), so the
    # stream sees the full batch on every data shard
    b_local = (global_batch if paged is not None
               else local_batch(info, global_batch))
    micro = pl.resolve_decode_micro(b_local, info.pp, v, n_micro)
    mb = b_local // micro

    def body(params, state, tokens, pos, *extra):
        b = tokens.shape[0]
        if paged is not None:
            tables, cow_src, cow_dst = extra
            state = _apply_cow(state, pat, [], cow_src, cow_dst)
        x = _decode_embed(cfg, ctx, params, tokens, pos)
        fns = {k: blk.decode_fn(cfg, ctx, k) for k in set(pat)}

        def stage_fn(c, h, st_c, mc):
            # this device's virtual-stage chunk c: leading dims [v, 1, per]
            chunk = tuple(jax.tree_util.tree_map(lambda t: t[c, 0], bl)
                          for bl in params["blocks"])
            aux = {"pos": lax.dynamic_slice_in_dim(pos, mc * mb, mb,
                                                   axis=0)}
            if paged is not None:
                aux["tables"] = lax.dynamic_slice_in_dim(tables, mc * mb,
                                                         mb, axis=0)

            def block_body(carry, inp):
                xc, st_stack = carry
                layer_params, j = inp
                st_out = []
                for p_, kind in enumerate(pat):
                    st_j = jax.tree_util.tree_map(
                        lambda t: lax.dynamic_index_in_dim(t, j, 0, False),
                        st_stack[p_])
                    xc, stn = fns[kind](layer_params[p_], xc, st_j, aux)
                    st_out.append(stn)
                st_stack = tuple(
                    jax.tree_util.tree_map(
                        lambda t, s: lax.dynamic_update_index_in_dim(
                            t, s.astype(t.dtype), j, 0),
                        st_stack[p_], st_out[p_])
                    for p_ in range(len(pat)))
                return (xc, st_stack), None

            (h, st_c), _ = lax.scan(
                block_body, (h, st_c),
                (chunk, jnp.arange(per, dtype=jnp.int32)))
            return h, st_c

        x_mb = x.reshape((micro, mb) + tuple(x.shape[1:]))
        outs, new_blocks = pl.decode_stream(
            stage_fn, x_mb, tuple(state["blocks"]), pipe_axis=pipe_ax,
            pp=info.pp, virtual_stages=v, paged=paged is not None)
        x = outs.reshape((b,) + tuple(x.shape[1:]))
        x = lax.psum(pl.mask_to_last_stage(x, pipe_ax, info.pp), pipe_ax)
        x = tmpc.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = _last_logits(cfg, params, x[:, 0], ctx)
        return greedy_token(logits, ctx.tp_axes), {"blocks": list(new_blocks),
                                                   "tail": []}

    st_ps = prm.pspec_tree(st_specs)
    extra_ps = (P(), P(), P()) if paged is not None else ()
    sm = compat.shard_map(
        body, mesh=mesh,
        in_specs=(prm.pspec_tree(specs), st_ps, bspec, bspec) + extra_ps,
        out_specs=(bspec, st_ps), check_vma=False)
    return sm, specs, st_specs


def build_verify(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                 global_batch: int, seq_len: int, paged=None):
    """verify_step(params, state, tokens [b, qn], pos [b])
    -> (choices [b, qn], state): the speculative-decoding target forward.

    One batched pass writes KV for all ``qn`` draft tokens (positions
    ``pos..pos+qn-1``), attends causally within the block and returns the
    target's greedy choice *after* each token — ``choices[:, j]`` is what
    undrafted decode would have emitted given ``tokens[:, :j+1]``, so the
    engine's longest-agreeing-run acceptance is token-identical to the
    oracle.  Collective latency is paid once per ``qn`` tokens instead of
    per token: the amortization :func:`costmodel.decode_step_time` models
    with ``spec_k``.

    With ``paged=(pages, page_size)`` the step takes the same
    ``(tables, cow_src, cow_dst)`` trailing args as paged
    :func:`build_decode`.  Requires an all-GLOBAL_ATTN layer pattern and
    no ``pipe`` mesh axis (drafting across stage boundaries would stall
    the decode stream it is meant to fill)."""
    info = mesh_info(mesh)
    if info.pp > 1:
        raise ValueError(
            "speculative verification does not support a 'pipe' mesh axis "
            "yet — serve spec-decode on a data x model (TMP/2D) mesh, or "
            "drop --draft/--spec-k on pipeline meshes")
    n, pat, tail = prm.stack_layout(cfg)
    other = sorted((set(pat) | set(tail)) - {GLOBAL_ATTN})
    if other:
        raise ValueError(
            f"speculative decoding requires an all-global-attention "
            f"layer pattern; {cfg.name} mixes in {other} (ring-buffer "
            f"and recurrent states cannot absorb multi-token jumps)")
    specs = prm.model_specs(cfg, info, max_pos=seq_len + 8,
                            layout=hp.tmp_layout)
    ctx = TmpCtx(info, schedule=hp.schedule, use_pallas=hp.use_pallas,
                 layout=hp.tmp_layout)
    bspec = P() if paged is not None else batch_pspec(info, global_batch)
    st_specs = prm.cache_specs(cfg, info, batch=global_batch, seq=seq_len,
                               batch_spec=bspec, layout=hp.tmp_layout,
                               paged=paged)

    def body(params, state, tokens, pos, *extra):
        b, qn = tokens.shape
        aux = {"pos": pos}
        if paged is not None:
            tables, cow_src, cow_dst = extra
            state = _apply_cow(state, pat, tail, cow_src, cow_dst)
            aux["tables"] = tables
        x = tmpc.vocab_parallel_embed(tokens, params["embed"], ctx.tp_axes)
        if cfg.name.startswith("gemma") or cfg.name.startswith(
                "recurrentgemma"):
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if "pos_embed" in params:
            positions = pos[:, None] + jnp.arange(qn, dtype=jnp.int32)[None]
            pe = jnp.take(params["pos_embed"], jnp.minimum(
                positions, params["pos_embed"].shape[0] - 1), axis=0)
            x = x + pe.astype(x.dtype)
        fns = {k: blk.verify_fn(cfg, ctx, k) for k in set(pat) | set(tail)}

        def block_body(carry, inp):
            xc, st_stack = carry
            layer_params, i = inp
            st_out = []
            for p_, kind in enumerate(pat):
                st_i = jax.tree_util.tree_map(
                    lambda t: lax.dynamic_index_in_dim(t, i, 0, False),
                    st_stack[p_])
                xc, st = fns[kind](layer_params[p_], xc, st_i, aux)
                st_out.append(st)
            st_stack = tuple(
                jax.tree_util.tree_map(
                    lambda t, s: lax.dynamic_update_index_in_dim(
                        t, s.astype(t.dtype), i, 0), st_stack[p_], st_out[p_])
                for p_ in range(len(pat)))
            return (xc, st_stack), None

        new_state: Dict[str, Any] = {"blocks": [], "tail": []}
        if n:
            (x, blocks_st), _ = lax.scan(
                block_body, (x, tuple(state["blocks"])),
                (tuple(params["blocks"]), jnp.arange(n, dtype=jnp.int32)))
            new_state["blocks"] = list(blocks_st)
        for i, kind in enumerate(tail):
            st_i = jax.tree_util.tree_map(lambda t: t[0], state["tail"][i])
            x, st = fns[kind](params["tail"][i], x, st_i, aux)
            new_state["tail"].append(
                jax.tree_util.tree_map(lambda t: t[None], st))

        x = tmpc.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = _last_logits(cfg, params, x.reshape(b * qn, -1), ctx)
        return greedy_token(logits, ctx.tp_axes).reshape(b, qn), new_state

    st_ps = prm.pspec_tree(st_specs)
    extra_ps = (P(), P(), P()) if paged is not None else ()
    sm = compat.shard_map(
        body, mesh=mesh,
        in_specs=(prm.pspec_tree(specs), st_ps, bspec, bspec) + extra_ps,
        out_specs=(bspec, st_ps), check_vma=False)
    return sm, specs, st_specs
