"""Parameter/state *spec trees*: one source of truth for

* ``init_params``            — real arrays (smoke tests, examples),
* ``abstract_params``        — ShapeDtypeStruct + NamedSharding (dry-run),
* ``pspec_tree``             — shard_map in_specs,
* the planner's memory model.

Shapes stored here are **global** (pre-sharding).  Stacked layer groups carry
a leading ``[n_repeat]`` dim for lax.scan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ArchConfig, CROSS_ATTN, GLOBAL_ATTN,
                                LOCAL_ATTN, RGLRU, SSD)
from repro.core.axes import MeshInfo, deg_total

# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    pspec: P
    dtype: Any = jnp.bfloat16
    scale: float = 0.02          # init stddev; 0 -> zeros, -1 -> ones-ish


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


# --------------------------------------------------------------------------
@dataclass(frozen=True)
class AttnPlan:
    sharded: bool          # q/o projections sharded over tp axes
    h_local: int           # q heads per shard
    kv_sharded: bool       # kv projections sharded over tp axes
    kv_weight_heads: int   # kv heads in the (global) weight layout
    kv_slice: int          # kv heads each shard keeps after slicing


def attn_plan(cfg: ArchConfig, tp: int) -> AttnPlan:
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if tp <= 1 or H % tp != 0:
        return AttnPlan(False, H, False, KV, KV)
    h_local = H // tp
    if KV % tp == 0:
        return AttnPlan(True, h_local, True, KV, KV // tp)
    # kv replicated: every shard computes all KV heads and slices what its
    # contiguous q-head range needs.  Valid when either the whole q-block
    # lives inside one kv group (slice=1, any offset) or the block spans
    # whole groups (h_local % group == 0, start automatically aligned).
    group = H // KV
    if group % h_local == 0:
        kv_slice = 1
    elif h_local % group == 0:
        kv_slice = h_local // group
    else:
        kv_slice = KV   # fallback: keep all KV heads (non-aligned ratios)
    return AttnPlan(True, h_local, False, KV, kv_slice)


def ssd_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads, cfg.ssm_state


# --------------------------------------------------------------------------
# per-layer-kind parameter specs
# --------------------------------------------------------------------------
def info_xy(info: MeshInfo, degree, layout: str = "auto"):
    """(x_axes, y_axes, dx, dy) — the layer's width- vs contraction-sharding
    axes and their sizes.  ``layout='1d'`` flattens everything into x."""
    if layout == "1d":
        x_ax: Tuple[str, ...] = info.tp_axes(deg_total(degree))
        y_ax: Tuple[str, ...] = ()
    else:
        x_ax, y_ax = info.xy_axes(degree)
    s = dict(info.mesh.shape)
    dx = math.prod(s[a] for a in x_ax) if x_ax else 1
    dy = math.prod(s[a] for a in y_ax) if y_ax else 1
    return x_ax, y_ax, dx, dy


def _attn_specs(cfg, info: MeshInfo, degree, *, prefix="", layout="auto"):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    plan = attn_plan(cfg, dx)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.dtype
    # 2D: the contraction (d_model) dim shards over y.  The exit weight's
    # *output* columns may only shard over y when the row-matmul path runs
    # (x-sharded heads, or dx == 1 where the psum_x degenerates).
    d_sh = y_ax if (dy > 1 and d % dy == 0) else None
    o_d_sh = d_sh if (plan.sharded or dx == 1) else None
    q_sh = P(d_sh, x_ax if plan.sharded else None)
    kv_sh = P(d_sh, x_ax if plan.kv_sharded else None)
    o_sh = P(x_ax if plan.sharded else None, o_d_sh)
    out = {
        prefix + "wq": Spec((d, cfg.num_heads * hd), q_sh, dt),
        prefix + "wk": Spec((d, cfg.num_kv_heads * hd), kv_sh, dt),
        prefix + "wv": Spec((d, cfg.num_kv_heads * hd), kv_sh, dt),
        prefix + "wo": Spec((cfg.num_heads * hd, d), o_sh, dt,
                            scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    return out


def _mlp_specs(cfg, info, degree, layout="auto"):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    f_sh = x_ax if (dx > 1 and f % dx == 0) else ()
    d_sh = y_ax if (dy > 1 and d % dy == 0) else ()
    out_sh = d_sh if (f_sh or dx == 1) else ()
    return {
        "wg": Spec((d, f), P(d_sh or None, f_sh or None), dt),
        "wu": Spec((d, f), P(d_sh or None, f_sh or None), dt),
        "wd": Spec((f, d), P(f_sh or None, out_sh or None), dt,
                   scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _moe_specs(cfg, info, degree):
    moe = cfg.moe
    tp_ax = info.tp_axes(degree)
    tp = info_tp(info, degree)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    E = moe.num_experts
    if moe.sharding == "ep" and tp > 1 and E % tp == 0:
        e_sh, f_sh = P(tp_ax, None, None), P(tp_ax, None, None)
        w2_sh = P(tp_ax, None, None)
    else:  # tmp: shard expert d_ff
        fx = tp_ax if (tp > 1 and f % tp == 0) else None
        e_sh = f_sh = P(None, None, fx)
        w2_sh = P(None, fx, None)
    return {
        "router": Spec((d, E), P(None, None), jnp.float32),
        "w1": Spec((E, d, f), e_sh, dt),
        "w3": Spec((E, d, f), f_sh, dt),
        "w2": Spec((E, f, d), w2_sh, dt,
                   scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _rglru_specs(cfg, info, degree, layout="auto"):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    w = cfg.rglru_width or cfg.d_model
    sh = x_ax if (dx > 1 and w % dx == 0) else ()
    d, dt = cfg.d_model, cfg.dtype
    d_sh = y_ax if (dy > 1 and d % dy == 0) else ()
    out_sh = d_sh if (sh or dx == 1) else ()
    vec = P(sh or None)
    return {
        "w_in_x": Spec((d, w), P(d_sh or None, sh or None), dt),
        "w_in_g": Spec((d, w), P(d_sh or None, sh or None), dt),
        "conv": Spec((4, w), P(None, sh or None), dt),
        "w_a": Spec((w,), vec, jnp.float32),
        "b_a": Spec((w,), vec, jnp.float32, scale=0.0),
        "w_x": Spec((w,), vec, jnp.float32),
        "b_x": Spec((w,), vec, jnp.float32, scale=0.0),
        "a_param": Spec((w,), vec, jnp.float32, scale=-1.0),
        "w_out": Spec((w, d), P(sh or None, out_sh or None), dt,
                      scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _ssd_specs(cfg, info, degree, layout="auto"):
    # mamba2-130m: replicated mixer (see DESIGN.md §Arch-applicability);
    # 2D still shards in_proj's contraction rows over y (the entry proj
    # AllReduces the partials), the rest stays replicated.
    _, y_ax, _, dy = info_xy(info, degree, layout)
    d_inner, nheads, n = ssd_dims(cfg)
    d, dt = cfg.d_model, cfg.dtype
    d_sh = y_ax if (dy > 1 and d % dy == 0) else None
    in_dim = 2 * d_inner + 2 * n + nheads
    return {
        "in_proj": Spec((d, in_dim), P(d_sh, None), dt),
        "conv": Spec((cfg.ssm_conv, d_inner + 2 * n), P(None, None), dt),
        "A_log": Spec((nheads,), P(None), jnp.float32, scale=-1.0),
        "Dskip": Spec((nheads,), P(None), jnp.float32, scale=-1.0),
        "dt_bias": Spec((nheads,), P(None), jnp.float32, scale=0.0),
        "norm_g": Spec((d_inner,), P(None), jnp.float32, scale=0.0),
        "out_proj": Spec((d_inner, d), P(None, None), dt,
                         scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def info_tp(info: MeshInfo, degree) -> int:
    ax = info.tp_axes(degree)
    s = dict(info.mesh.shape)
    return math.prod(s[a] for a in ax) if ax else 1


def layer_specs(cfg: ArchConfig, kind: str, info: MeshInfo,
                degree=None, *, causal=True,
                layout: str = "auto") -> Dict[str, Spec]:
    d, dt = cfg.d_model, cfg.dtype
    out: Dict[str, Any] = {"ln": Spec((d,), P(None), jnp.float32, scale=0.0)}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
        out.update(_attn_specs(cfg, info, degree, layout=layout))
        if kind == CROSS_ATTN:
            out["c_ln"] = Spec((d,), P(None), jnp.float32, scale=0.0)
            out.update(_attn_specs(cfg, info, degree, prefix="c_",
                                   layout=layout))
            out["c_gate"] = Spec((1,), P(None), jnp.float32, scale=0.0)
    elif kind == RGLRU:
        out.update(_rglru_specs(cfg, info, degree, layout=layout))
    elif kind == SSD:
        out.update(_ssd_specs(cfg, info, degree, layout=layout))
    else:
        raise ValueError(kind)
    if kind != SSD and cfg.d_ff:
        out["ln2"] = Spec((d,), P(None), jnp.float32, scale=0.0)
        if cfg.moe is not None:
            # MoE stays 1D over the flattened model group (expert/e_ff
            # sharding composes with the combined axes, not per-axis rings)
            out.update(_moe_specs(cfg, info, degree))
        else:
            out.update(_mlp_specs(cfg, info, degree, layout=layout))
        if cfg.post_norms:
            out["pn1"] = Spec((d,), P(None), jnp.float32, scale=0.0)
            out["pn2"] = Spec((d,), P(None), jnp.float32, scale=0.0)
    return out


def _stack(specs: Dict[str, Spec], n: int) -> Dict[str, Spec]:
    return tree_map_specs(
        lambda s: Spec((n,) + s.shape, P(*((None,) + tuple(s.pspec))),
                       s.dtype, s.scale), specs)


def _stack_pipeline(specs: Dict[str, Spec], n: int, pp: int,
                    v: int) -> Dict[str, Spec]:
    """Pipeline-mode stacking: ``[n] -> [v, pp, n/(pp*v)]`` with only the
    ``pp`` dim sharded over the ``pipe`` axis, so device ``d`` holds its
    ``v`` strided virtual-stage chunks ``{d, pp+d, ...}`` and the row-major
    flatten stays the canonical layer order (checkpoints move between PP
    and non-PP meshes by pure reshape — see core/pipeline.py)."""
    per = n // (pp * v)
    return tree_map_specs(
        lambda s: Spec((v, pp, per) + s.shape,
                       P(*((None, "pipe", None) + tuple(s.pspec))),
                       s.dtype, s.scale), specs)


# --------------------------------------------------------------------------
# whole-model specs
# --------------------------------------------------------------------------
def stack_layout(cfg: ArchConfig) -> Tuple[int, Sequence[str], Sequence[str]]:
    """(n_scan_blocks, pattern, tail_kinds)."""
    pat = cfg.layer_pattern
    n = cfg.num_layers // len(pat)
    tail = [pat[i % len(pat)] for i in range(n * len(pat), cfg.num_layers)]
    return n, pat, tail


def model_specs(cfg: ArchConfig, info: MeshInfo, *,
                degrees: Optional[Sequence] = None,
                max_pos: int = 0, layout: str = "auto",
                virtual_stages: int = 1) -> Dict[str, Any]:
    """degrees: optional per-layer TMP degrees (planner mode; factored
    mesh); each entry may be an int (1D) or an ``(dx, dy)`` tuple (2D).

    Uniform mode (degrees=None) stacks `n` repeats of the pattern for scan;
    planner mode groups consecutive same-degree layers (see lm.py).  On a
    mesh with a ``pipe`` axis the stacks restructure to the stage-sharded
    ``[v, pp, n/S]`` layout (``virtual_stages`` = interleaving depth).
    Embedding/head stay vocab-sharded over the *combined* model group in
    every layout and replicated over ``pipe``.
    """
    tp_ax = info.tp_axes(None)
    d, dt = cfg.d_model, cfg.dtype
    vp = cfg.padded_vocab()
    out: Dict[str, Any] = {
        "embed": Spec((vp, d), P(tp_ax or None, None), dt),
        "final_ln": Spec((d,), P(None), jnp.float32, scale=0.0),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, vp), P(None, tp_ax or None), dt)
    if cfg.name.startswith("whisper"):
        out["pos_embed"] = Spec((max(max_pos, 2048), d), P(None, None), dt)

    if degrees is None:
        n, pat, tail = stack_layout(cfg)
        if info.pp > 1:
            from repro.core.pipeline import validate_stage_layout
            v = max(virtual_stages, 1)
            validate_stage_layout(cfg, n, len(tail), info.pp, v)
            out["blocks"] = [
                _stack_pipeline(layer_specs(cfg, k, info, layout=layout),
                                n, info.pp, v)
                for k in pat]
            out["tail"] = []
        else:
            out["blocks"] = [
                _stack(layer_specs(cfg, k, info, layout=layout), n)
                for k in pat] if n else []
            out["tail"] = [layer_specs(cfg, k, info, layout=layout)
                           for k in tail]
    else:
        if info.pp > 1:
            raise ValueError(
                "per-layer planner degrees do not compose with pipeline "
                "parallelism yet — use a uniform TMP degree per stage "
                "(drop degrees= or the 'pipe' mesh axis)")
        assert info.factored and len(degrees) == cfg.num_layers
        out["groups"] = [
            _stack(layer_specs(cfg, kind, info, deg, layout=layout), n)
            for (kind, deg, n) in plan_groups(cfg, degrees)]

    if cfg.is_encdec:
        n_enc = cfg.encoder_layers
        enc_layer = layer_specs(cfg, GLOBAL_ATTN, info, layout=layout)
        out["encoder"] = {
            "pos_embed": Spec((cfg.context_len, d), P(None, None), dt),
            "blocks": _stack(enc_layer, n_enc),
            "final_ln": Spec((d,), P(None), jnp.float32, scale=0.0),
        }
    return out


def plan_groups(cfg: ArchConfig, degrees: Sequence[int]):
    """Group consecutive (same kind, same degree) layers: [(kind, degree, n)]."""
    pat = cfg.layer_pattern
    groups = []
    i = 0
    while i < cfg.num_layers:
        j = i
        while (j < cfg.num_layers and degrees[j] == degrees[i]
               and pat[j % len(pat)] == pat[i % len(pat)]):
            j += 1
        groups.append((pat[i % len(pat)], degrees[i], j - i))
        i = j
    return groups


# --------------------------------------------------------------------------
# decode/prefill state (KV caches, recurrent states) specs
# --------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, info: MeshInfo, *, batch: int, seq: int,
                batch_spec, layout: str = "auto",
                virtual_stages: int = 1) -> Dict[str, Any]:
    """State tree for serve_step.  Global shapes; kv-head dim sharded when
    the attention plan shards it (replicated+sliced layouts store
    tp*kv_slice).  2D: heads shard over the x-axes only (dx).

    On a mesh with a ``pipe`` axis the stacked cache restructures to the
    stage-sharded ``[v, pp, n/S, ...]`` layout mirroring
    :func:`_stack_pipeline` — each stage owns exactly the cache of the
    layers it holds, so decode state memory shards 1/pp alongside the
    weights (the serving analogue of the Eq. 6 weight-memory row)."""
    tp_ax, _, tp, _ = info_xy(info, None, layout)
    plan = attn_plan(cfg, tp)
    hd = cfg.resolved_head_dim
    dt = cfg.dtype
    bsp = batch_spec[0] if len(batch_spec) else None

    if plan.kv_sharded:
        kv_heads, kv_sh = cfg.num_kv_heads, tp_ax
    elif plan.sharded:
        kv_heads, kv_sh = tp * plan.kv_slice, tp_ax   # duplicated storage
    else:
        kv_heads, kv_sh = cfg.num_kv_heads, None

    def kv(n, s):
        return {
            "k": Spec((n, batch, s, kv_heads, hd), P(None, bsp, None, kv_sh, None), dt),
            "v": Spec((n, batch, s, kv_heads, hd), P(None, bsp, None, kv_sh, None), dt),
        }

    n, pat, tail = stack_layout(cfg)
    d_inner, nheads, nstate = ssd_dims(cfg)
    w = cfg.rglru_width or cfg.d_model

    def state_for(kind, count):
        if kind == GLOBAL_ATTN:
            return kv(count, seq)
        if kind == LOCAL_ATTN:
            return kv(count, min(seq, cfg.window))
        if kind == CROSS_ATTN:
            st = kv(count, seq)
            st["c_k"] = Spec((count, batch, cfg.context_len, kv_heads, hd),
                             P(None, bsp, None, kv_sh, None), dt)
            st["c_v"] = Spec((count, batch, cfg.context_len, kv_heads, hd),
                             P(None, bsp, None, kv_sh, None), dt)
            return st
        if kind == RGLRU:
            wl_sh = tp_ax if (tp > 1 and w % tp == 0) else None
            return {
                "h": Spec((count, batch, w), P(None, bsp, wl_sh), jnp.float32),
                "conv": Spec((count, batch, 3, w), P(None, bsp, None, wl_sh), dt),
            }
        if kind == SSD:
            return {
                "S": Spec((count, batch, nheads, cfg.ssm_headdim, nstate),
                          P(None, bsp, None, None, None), jnp.float32),
                "conv": Spec((count, batch, cfg.ssm_conv - 1, d_inner + 2 * nstate),
                             P(None, bsp, None, None), dt),
            }
        raise ValueError(kind)

    if info.pp > 1:
        from repro.core.pipeline import validate_stage_layout
        v = max(virtual_stages, 1)
        per = validate_stage_layout(cfg, n, len(tail), info.pp, v)

        def restack(tree):
            return tree_map_specs(
                lambda s: Spec((v, info.pp, per) + s.shape[1:],
                               P(*((None, "pipe", None)
                                   + tuple(s.pspec)[1:])),
                               s.dtype, s.scale), tree)

        return {"blocks": [restack(state_for(k, n)) for k in pat],
                "tail": []}
    out: Dict[str, Any] = {
        "blocks": [state_for(k, n) for k in pat] if n else [],
        "tail": [state_for(k, 1) for k in tail],
    }
    return out


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------
def pspec_tree(specs):
    return tree_map_specs(lambda s: s.pspec, specs)


def shardings_tree(specs, mesh):
    return tree_map_specs(lambda s: NamedSharding(mesh, s.pspec), specs)


def abstract_params(specs, mesh):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, s.pspec)), specs)


def init_params(specs, key):
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.scale == 0.0:
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.scale == -1.0:
            # "ones-ish": used for gate/decay params needing negative init
            out.append(jnp.full(s.shape, -1.0 if s.dtype == jnp.float32 else 1.0,
                                s.dtype))
        else:
            out.append(
                (jax.random.normal(k, s.shape, jnp.float32) * s.scale)
                .astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def zeros_state(specs):
    return tree_map_specs(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def param_bytes(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in leaves)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)
