"""Parameter/state *spec trees*: one source of truth for

* ``init_params``            — real arrays (smoke tests, examples),
* ``abstract_params``        — ShapeDtypeStruct + NamedSharding (dry-run),
* ``pspec_tree``             — shard_map in_specs,
* the planner's memory model.

Shapes stored here are **global** (pre-sharding).  Stacked layer groups carry
a leading ``[n_repeat]`` dim for lax.scan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ArchConfig, CROSS_ATTN, GLOBAL_ATTN,
                                LOCAL_ATTN, RGLRU, SSD)
from repro.core.axes import MeshInfo, deg_total

# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    pspec: P
    dtype: Any = jnp.bfloat16
    scale: float = 0.02          # init stddev; 0 -> zeros, -1 -> ones-ish


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


# --------------------------------------------------------------------------
@dataclass(frozen=True)
class AttnPlan:
    sharded: bool          # q/o projections sharded over tp axes
    h_local: int           # q heads per shard
    kv_sharded: bool       # kv projections sharded over tp axes
    kv_weight_heads: int   # kv heads in the (global) weight layout
    kv_slice: int          # kv heads each shard keeps after slicing


def attn_plan(cfg: ArchConfig, tp: int) -> AttnPlan:
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if tp <= 1 or H % tp != 0:
        return AttnPlan(False, H, False, KV, KV)
    h_local = H // tp
    if KV % tp == 0:
        return AttnPlan(True, h_local, True, KV, KV // tp)
    # kv replicated: every shard computes all KV heads and slices what its
    # contiguous q-head range needs.  Valid when either the whole q-block
    # lives inside one kv group (slice=1, any offset) or the block spans
    # whole groups (h_local % group == 0, start automatically aligned).
    group = H // KV
    if group % h_local == 0:
        kv_slice = 1
    elif h_local % group == 0:
        kv_slice = h_local // group
    else:
        kv_slice = KV   # fallback: keep all KV heads (non-aligned ratios)
    return AttnPlan(True, h_local, False, KV, kv_slice)


def ssd_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads, cfg.ssm_state


# --------------------------------------------------------------------------
# per-layer-kind parameter specs
# --------------------------------------------------------------------------
def info_xy(info: MeshInfo, degree, layout: str = "auto"):
    """(x_axes, y_axes, dx, dy) — the layer's width- vs contraction-sharding
    axes and their sizes.  ``layout='1d'`` flattens everything into x."""
    if layout == "1d":
        x_ax: Tuple[str, ...] = info.tp_axes(deg_total(degree))
        y_ax: Tuple[str, ...] = ()
    else:
        x_ax, y_ax = info.xy_axes(degree)
    s = dict(info.mesh.shape)
    dx = math.prod(s[a] for a in x_ax) if x_ax else 1
    dy = math.prod(s[a] for a in y_ax) if y_ax else 1
    return x_ax, y_ax, dx, dy


def _attn_specs(cfg, info: MeshInfo, degree, *, prefix="", layout="auto",
                seq_shard: int = 1):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    plan = attn_plan(cfg, dx)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.dtype
    if seq_shard > 1:
        # ring attention (DESIGN.md §12): the sequence shards over the
        # model group instead of the heads, so every device holds the
        # FULL attention weights (the planner charges the replication
        # against the activation/KV savings)
        rep = P(None, None)
        return {
            prefix + "wq": Spec((d, cfg.num_heads * hd), rep, dt),
            prefix + "wk": Spec((d, cfg.num_kv_heads * hd), rep, dt),
            prefix + "wv": Spec((d, cfg.num_kv_heads * hd), rep, dt),
            prefix + "wo": Spec((cfg.num_heads * hd, d), rep, dt,
                                scale=0.02 / math.sqrt(2 * cfg.num_layers)),
        }
    # 2D: the contraction (d_model) dim shards over y.  The exit weight's
    # *output* columns may only shard over y when the row-matmul path runs
    # (x-sharded heads, or dx == 1 where the psum_x degenerates).
    d_sh = y_ax if (dy > 1 and d % dy == 0) else None
    o_d_sh = d_sh if (plan.sharded or dx == 1) else None
    q_sh = P(d_sh, x_ax if plan.sharded else None)
    kv_sh = P(d_sh, x_ax if plan.kv_sharded else None)
    o_sh = P(x_ax if plan.sharded else None, o_d_sh)
    out = {
        prefix + "wq": Spec((d, cfg.num_heads * hd), q_sh, dt),
        prefix + "wk": Spec((d, cfg.num_kv_heads * hd), kv_sh, dt),
        prefix + "wv": Spec((d, cfg.num_kv_heads * hd), kv_sh, dt),
        prefix + "wo": Spec((cfg.num_heads * hd, d), o_sh, dt,
                            scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    return out


def _mlp_specs(cfg, info, degree, layout="auto"):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    f_sh = x_ax if (dx > 1 and f % dx == 0) else ()
    d_sh = y_ax if (dy > 1 and d % dy == 0) else ()
    out_sh = d_sh if (f_sh or dx == 1) else ()
    return {
        "wg": Spec((d, f), P(d_sh or None, f_sh or None), dt),
        "wu": Spec((d, f), P(d_sh or None, f_sh or None), dt),
        "wd": Spec((f, d), P(f_sh or None, out_sh or None), dt,
                   scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _moe_specs(cfg, info, degree):
    moe = cfg.moe
    tp_ax = info.tp_axes(degree)
    tp = info_tp(info, degree)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    E = moe.num_experts
    if moe.sharding == "ep" and tp > 1 and E % tp == 0:
        e_sh, f_sh = P(tp_ax, None, None), P(tp_ax, None, None)
        w2_sh = P(tp_ax, None, None)
    else:  # tmp: shard expert d_ff
        fx = tp_ax if (tp > 1 and f % tp == 0) else None
        e_sh = f_sh = P(None, None, fx)
        w2_sh = P(None, fx, None)
    return {
        "router": Spec((d, E), P(None, None), jnp.float32),
        "w1": Spec((E, d, f), e_sh, dt),
        "w3": Spec((E, d, f), f_sh, dt),
        "w2": Spec((E, f, d), w2_sh, dt,
                   scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _rglru_specs(cfg, info, degree, layout="auto"):
    x_ax, y_ax, dx, dy = info_xy(info, degree, layout)
    w = cfg.rglru_width or cfg.d_model
    sh = x_ax if (dx > 1 and w % dx == 0) else ()
    d, dt = cfg.d_model, cfg.dtype
    d_sh = y_ax if (dy > 1 and d % dy == 0) else ()
    out_sh = d_sh if (sh or dx == 1) else ()
    vec = P(sh or None)
    return {
        "w_in_x": Spec((d, w), P(d_sh or None, sh or None), dt),
        "w_in_g": Spec((d, w), P(d_sh or None, sh or None), dt),
        "conv": Spec((4, w), P(None, sh or None), dt),
        "w_a": Spec((w,), vec, jnp.float32),
        "b_a": Spec((w,), vec, jnp.float32, scale=0.0),
        "w_x": Spec((w,), vec, jnp.float32),
        "b_x": Spec((w,), vec, jnp.float32, scale=0.0),
        "a_param": Spec((w,), vec, jnp.float32, scale=-1.0),
        "w_out": Spec((w, d), P(sh or None, out_sh or None), dt,
                      scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _ssd_specs(cfg, info, degree, layout="auto"):
    # mamba2-130m: replicated mixer (see DESIGN.md §Arch-applicability);
    # 2D still shards in_proj's contraction rows over y (the entry proj
    # AllReduces the partials), the rest stays replicated.
    _, y_ax, _, dy = info_xy(info, degree, layout)
    d_inner, nheads, n = ssd_dims(cfg)
    d, dt = cfg.d_model, cfg.dtype
    d_sh = y_ax if (dy > 1 and d % dy == 0) else None
    in_dim = 2 * d_inner + 2 * n + nheads
    return {
        "in_proj": Spec((d, in_dim), P(d_sh, None), dt),
        "conv": Spec((cfg.ssm_conv, d_inner + 2 * n), P(None, None), dt),
        "A_log": Spec((nheads,), P(None), jnp.float32, scale=-1.0),
        "Dskip": Spec((nheads,), P(None), jnp.float32, scale=-1.0),
        "dt_bias": Spec((nheads,), P(None), jnp.float32, scale=0.0),
        "norm_g": Spec((d_inner,), P(None), jnp.float32, scale=0.0),
        "out_proj": Spec((d_inner, d), P(None, None), dt,
                         scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def info_tp(info: MeshInfo, degree) -> int:
    ax = info.tp_axes(degree)
    s = dict(info.mesh.shape)
    return math.prod(s[a] for a in ax) if ax else 1


def layer_specs(cfg: ArchConfig, kind: str, info: MeshInfo,
                degree=None, *, causal=True,
                layout: str = "auto",
                seq_shard: int = 1) -> Dict[str, Spec]:
    d, dt = cfg.d_model, cfg.dtype
    out: Dict[str, Any] = {"ln": Spec((d,), P(None), jnp.float32, scale=0.0)}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
        # ring mode replicates only the SELF-attention projections; a
        # cross layer's projections stay head-sharded (the cross part
        # gathers the sequence and runs the classic path)
        out.update(_attn_specs(
            cfg, info, degree, layout=layout,
            seq_shard=seq_shard if kind != CROSS_ATTN else 1))
        if kind == CROSS_ATTN:
            out["c_ln"] = Spec((d,), P(None), jnp.float32, scale=0.0)
            out.update(_attn_specs(cfg, info, degree, prefix="c_",
                                   layout=layout))
            out["c_gate"] = Spec((1,), P(None), jnp.float32, scale=0.0)
    elif kind == RGLRU:
        out.update(_rglru_specs(cfg, info, degree, layout=layout))
    elif kind == SSD:
        out.update(_ssd_specs(cfg, info, degree, layout=layout))
    else:
        raise ValueError(kind)
    if kind != SSD and cfg.d_ff:
        out["ln2"] = Spec((d,), P(None), jnp.float32, scale=0.0)
        if cfg.moe is not None:
            # MoE stays 1D over the flattened model group (expert/e_ff
            # sharding composes with the combined axes, not per-axis rings)
            out.update(_moe_specs(cfg, info, degree))
        else:
            out.update(_mlp_specs(cfg, info, degree, layout=layout))
        if cfg.post_norms:
            out["pn1"] = Spec((d,), P(None), jnp.float32, scale=0.0)
            out["pn2"] = Spec((d,), P(None), jnp.float32, scale=0.0)
    return out


def _stack(specs: Dict[str, Spec], n: int) -> Dict[str, Spec]:
    return tree_map_specs(
        lambda s: Spec((n,) + s.shape, P(*((None,) + tuple(s.pspec))),
                       s.dtype, s.scale), specs)


def _stack_pipeline(specs: Dict[str, Spec], n: int, pp: int,
                    v: int) -> Dict[str, Spec]:
    """Pipeline-mode stacking: ``[n] -> [v, pp, n/(pp*v)]`` with only the
    ``pp`` dim sharded over the ``pipe`` axis, so device ``d`` holds its
    ``v`` strided virtual-stage chunks ``{d, pp+d, ...}`` and the row-major
    flatten stays the canonical layer order (checkpoints move between PP
    and non-PP meshes by pure reshape — see core/pipeline.py)."""
    per = n // (pp * v)
    return tree_map_specs(
        lambda s: Spec((v, pp, per) + s.shape,
                       P(*((None, "pipe", None) + tuple(s.pspec))),
                       s.dtype, s.scale), specs)


# --------------------------------------------------------------------------
# whole-model specs
# --------------------------------------------------------------------------
def stack_layout(cfg: ArchConfig) -> Tuple[int, Sequence[str], Sequence[str]]:
    """(n_scan_blocks, pattern, tail_kinds)."""
    pat = cfg.layer_pattern
    n = cfg.num_layers // len(pat)
    tail = [pat[i % len(pat)] for i in range(n * len(pat), cfg.num_layers)]
    return n, pat, tail


def model_specs(cfg: ArchConfig, info: MeshInfo, *,
                degrees: Optional[Sequence] = None,
                max_pos: int = 0, layout: str = "auto",
                virtual_stages: int = 1,
                schedules: Optional[Sequence[str]] = None,
                seqs: Optional[Sequence[int]] = None,
                seq_shard: int = 1) -> Dict[str, Any]:
    """degrees: optional per-layer TMP degrees (planner mode); each entry
    may be an int (1D), an ``(dx, dy)`` tuple (2D), or ``None`` (follow
    the whole mesh model group — how a mixed-SCHEDULE plan with uniform
    degrees runs on a plain mesh).  ``schedules``: optional per-layer
    schedule names — they do not change any pspec, but grouping must
    break wherever the schedule changes so the spec groups line up with
    the execution groups (lm.py::_grouped_scan).

    Uniform mode (degrees=None) stacks `n` repeats of the pattern for scan;
    planner mode groups consecutive same-(degree, schedule) layers.  On a
    mesh with a ``pipe`` axis the stacks restructure to the stage-sharded
    ``[v, pp, n/S]`` layout (``virtual_stages`` = interleaving depth).
    Embedding/head stay vocab-sharded over the *combined* model group in
    every layout and replicated over ``pipe``.
    """
    tp_ax = info.tp_axes(None)
    d, dt = cfg.d_model, cfg.dtype
    vp = cfg.padded_vocab()
    out: Dict[str, Any] = {
        "embed": Spec((vp, d), P(tp_ax or None, None), dt),
        "final_ln": Spec((d,), P(None), jnp.float32, scale=0.0),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, vp), P(None, tp_ax or None), dt)
    if cfg.name.startswith("whisper"):
        out["pos_embed"] = Spec((max(max_pos, 2048), d), P(None, None), dt)

    if degrees is None:
        n, pat, tail = stack_layout(cfg)
        if info.pp > 1:
            from repro.core.pipeline import validate_stage_layout
            v = max(virtual_stages, 1)
            validate_stage_layout(cfg, n, len(tail), info.pp, v)
            out["blocks"] = [
                _stack_pipeline(layer_specs(cfg, k, info, layout=layout),
                                n, info.pp, v)
                for k in pat]
            out["tail"] = []
        else:
            out["blocks"] = [
                _stack(layer_specs(cfg, k, info, layout=layout,
                                   seq_shard=seq_shard), n)
                for k in pat] if n else []
            out["tail"] = [layer_specs(cfg, k, info, layout=layout,
                                       seq_shard=seq_shard)
                           for k in tail]
    else:
        if info.pp > 1:
            raise ValueError(
                "per-layer planner strategies do not compose with "
                "pipeline parallelism yet — use a uniform strategy per "
                "stage (drop degrees=/schedules= or the 'pipe' mesh axis)")
        assert len(degrees) == cfg.num_layers
        if not info.factored:
            bad = [d for d in degrees
                   if d is not None and deg_total(d) != info.tp]
            if bad:
                raise ValueError(
                    f"per-layer degrees {sorted(set(map(str, bad)))} "
                    f"differ from the mesh model group ({info.tp}) — "
                    f"mixed degrees need the factored mesh "
                    f"(launch/mesh.py::make_factored_mesh); on a plain "
                    f"mesh only per-layer SCHEDULES may vary")
        out["groups"] = [
            _stack(layer_specs(cfg, g.kind, info, g.degree, layout=layout,
                               seq_shard=g.seq),
                   g.count)
            for g in plan_groups(cfg, degrees, schedules, seqs)]

    if cfg.is_encdec:
        n_enc = cfg.encoder_layers
        enc_layer = layer_specs(cfg, GLOBAL_ATTN, info, layout=layout)
        out["encoder"] = {
            "pos_embed": Spec((cfg.context_len, d), P(None, None), dt),
            "blocks": _stack(enc_layer, n_enc),
            "final_ln": Spec((d,), P(None), jnp.float32, scale=0.0),
        }
    return out


@dataclass(frozen=True)
class PlanGroup:
    """One scan group of the grouped (planner-mode) layout: ``count``
    consecutive layers sharing (kind, degree, schedule, seq)."""
    kind: str
    degree: Any              # None | int | (dx, dy)
    schedule: str
    count: int
    seq: int = 1             # ring-attention seq shards (DESIGN.md §12)


def plan_groups(cfg: ArchConfig, degrees: Sequence,
                schedules: Optional[Sequence[str]] = None,
                seqs: Optional[Sequence[int]] = None):
    """Group consecutive layers sharing (kind, degree, schedule, seq) into
    scan groups: the executable unit of a per-layer :class:`ParallelPlan`.
    A schedule or seq-shard change breaks the group even at equal degree
    (each group runs under its own ``TmpCtx``/sub-batch split)."""
    pat = cfg.layer_pattern
    scheds = list(schedules) if schedules is not None \
        else [None] * cfg.num_layers
    sq = list(seqs) if seqs is not None else [1] * cfg.num_layers
    groups = []
    i = 0
    while i < cfg.num_layers:
        j = i
        while (j < cfg.num_layers and degrees[j] == degrees[i]
               and scheds[j] == scheds[i] and sq[j] == sq[i]
               and pat[j % len(pat)] == pat[i % len(pat)]):
            j += 1
        groups.append(PlanGroup(pat[i % len(pat)], degrees[i],
                                scheds[i] or "oases", j - i, sq[i]))
        i = j
    return groups


# --------------------------------------------------------------------------
# decode/prefill state (KV caches, recurrent states) specs
# --------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, info: MeshInfo, *, batch: int, seq: int,
                batch_spec, layout: str = "auto",
                virtual_stages: int = 1, paged=None) -> Dict[str, Any]:
    """State tree for serve_step.  Global shapes; kv-head dim sharded when
    the attention plan shards it (replicated+sliced layouts store
    tp*kv_slice).  2D: heads shard over the x-axes only (dx).

    On a mesh with a ``pipe`` axis the stacked cache restructures to the
    stage-sharded ``[v, pp, n/S, ...]`` layout mirroring
    :func:`_stack_pipeline` — each stage owns exactly the cache of the
    layers it holds, so decode state memory shards 1/pp alongside the
    weights (the serving analogue of the Eq. 6 weight-memory row).

    ``paged=(pages, page_size)`` swaps GLOBAL_ATTN k/v from the dense
    per-slot ``[n, batch, seq, kvh, hd]`` layout to a shared page pool
    ``[n, pages, page_size, kvh, hd]`` addressed through a per-slot block
    table (``serving/paged_cache.py``) — slots no longer reserve
    ``max_seq`` each, so HBM scales with tokens actually resident.  The
    pool has no batch dim: the engine runs the slot batch replicated over
    data axes in paged mode (data parallelism shards *requests across
    engine replicas*, not slots within one pool).  Local/recurrent/cross
    states keep their dense layouts."""
    tp_ax, _, tp, _ = info_xy(info, None, layout)
    plan = attn_plan(cfg, tp)
    hd = cfg.resolved_head_dim
    dt = cfg.dtype
    bsp = batch_spec[0] if len(batch_spec) else None

    if plan.kv_sharded:
        kv_heads, kv_sh = cfg.num_kv_heads, tp_ax
    elif plan.sharded:
        kv_heads, kv_sh = tp * plan.kv_slice, tp_ax   # duplicated storage
    else:
        kv_heads, kv_sh = cfg.num_kv_heads, None

    def kv(n, s):
        return {
            "k": Spec((n, batch, s, kv_heads, hd), P(None, bsp, None, kv_sh, None), dt),
            "v": Spec((n, batch, s, kv_heads, hd), P(None, bsp, None, kv_sh, None), dt),
        }

    def kv_paged(n):
        pages, page_size = paged
        return {
            "k": Spec((n, pages, page_size, kv_heads, hd),
                      P(None, None, None, kv_sh, None), dt),
            "v": Spec((n, pages, page_size, kv_heads, hd),
                      P(None, None, None, kv_sh, None), dt),
        }

    n, pat, tail = stack_layout(cfg)
    d_inner, nheads, nstate = ssd_dims(cfg)
    w = cfg.rglru_width or cfg.d_model

    def state_for(kind, count):
        if kind == GLOBAL_ATTN:
            return kv_paged(count) if paged is not None else kv(count, seq)
        if kind == LOCAL_ATTN:
            return kv(count, min(seq, cfg.window))
        if kind == CROSS_ATTN:
            st = kv(count, seq)
            st["c_k"] = Spec((count, batch, cfg.context_len, kv_heads, hd),
                             P(None, bsp, None, kv_sh, None), dt)
            st["c_v"] = Spec((count, batch, cfg.context_len, kv_heads, hd),
                             P(None, bsp, None, kv_sh, None), dt)
            return st
        if kind == RGLRU:
            wl_sh = tp_ax if (tp > 1 and w % tp == 0) else None
            return {
                "h": Spec((count, batch, w), P(None, bsp, wl_sh), jnp.float32),
                "conv": Spec((count, batch, 3, w), P(None, bsp, None, wl_sh), dt),
            }
        if kind == SSD:
            return {
                "S": Spec((count, batch, nheads, cfg.ssm_headdim, nstate),
                          P(None, bsp, None, None, None), jnp.float32),
                "conv": Spec((count, batch, cfg.ssm_conv - 1, d_inner + 2 * nstate),
                             P(None, bsp, None, None), dt),
            }
        raise ValueError(kind)

    if info.pp > 1:
        from repro.core.pipeline import validate_stage_layout
        v = max(virtual_stages, 1)
        per = validate_stage_layout(cfg, n, len(tail), info.pp, v)

        def restack(tree):
            return tree_map_specs(
                lambda s: Spec((v, info.pp, per) + s.shape[1:],
                               P(*((None, "pipe", None)
                                   + tuple(s.pspec)[1:])),
                               s.dtype, s.scale), tree)

        return {"blocks": [restack(state_for(k, n)) for k in pat],
                "tail": []}
    out: Dict[str, Any] = {
        "blocks": [state_for(k, n) for k in pat] if n else [],
        "tail": [state_for(k, 1) for k in tail],
    }
    return out


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------
def pspec_tree(specs):
    return tree_map_specs(lambda s: s.pspec, specs)


def shardings_tree(specs, mesh):
    return tree_map_specs(lambda s: NamedSharding(mesh, s.pspec), specs)


def abstract_params(specs, mesh):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, s.pspec)), specs)


def init_params(specs, key):
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.scale == 0.0:
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.scale == -1.0:
            # "ones-ish": used for gate/decay params needing negative init
            out.append(jnp.full(s.shape, -1.0 if s.dtype == jnp.float32 else 1.0,
                                s.dtype))
        else:
            out.append(
                (jax.random.normal(k, s.shape, jnp.float32) * s.scale)
                .astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def zeros_state(specs):
    return tree_map_specs(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def param_bytes(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in leaves)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


# --------------------------------------------------------------------------
# cross-plan checkpoint relayout (elastic resume across ParallelPlan changes)
# --------------------------------------------------------------------------
# A checkpoint's layer parameters live in one of three layouts, all of
# whose global per-layer shapes agree (degree/schedule only move pspecs):
#   * stacked:  ['blocks'][pos] leaves [n_rep, ...] + ['tail'][i] leaves,
#   * pipeline: ['blocks'][pos] leaves [v, pp, n_rep/S, ...] (row-major
#               flatten = canonical layer order — core/pipeline.py),
#   * grouped:  ['groups'][g] leaves [count_g, ...] (planner mode; groups
#               follow plan_groups of the plan's per-layer strategies).
# These helpers decompose a FLAT {keystr: np.ndarray} view (the checkpoint
# manifest's native form) into canonical per-layer dicts and repack them
# into any target layout, so elastic restarts cross plan changes —
# including mixed-schedule -> global-schedule transitions — by pure
# numpy restacking (checkpoint/store.py + runtime/trainer.py).
_LAYER_KEY_RE = None


def _layer_key(key: str):
    global _LAYER_KEY_RE
    if _LAYER_KEY_RE is None:
        import re
        _LAYER_KEY_RE = re.compile(
            r"^\['(blocks|tail|groups)'\]\[(\d+)\](.*)$")
    m = _LAYER_KEY_RE.match(key)
    return (m.group(1), int(m.group(2)), m.group(3)) if m else None


def split_layer_flat(cfg: ArchConfig, flat: Dict[str, np.ndarray], *,
                     degrees: Optional[Sequence] = None,
                     schedules: Optional[Sequence[str]] = None,
                     seqs: Optional[Sequence[int]] = None,
                     pp: int = 1, virtual_stages: int = 1):
    """Decompose a flat params-like dict into ``(static, per_layer)``:
    ``static`` keeps the non-layer leaves verbatim; ``per_layer[l]`` maps
    each layer leaf's name suffix (e.g. ``"['wq']"``) to layer ``l``'s
    array in canonical layer order."""
    static: Dict[str, np.ndarray] = {}
    by_slot: Dict[Tuple[str, int], Dict[str, np.ndarray]] = {}
    for key, arr in flat.items():
        lk = _layer_key(key)
        if lk is None:
            static[key] = arr
        else:
            coll, idx, name = lk
            by_slot.setdefault((coll, idx), {})[name] = arr
    per_layer: list = [dict() for _ in range(cfg.num_layers)]
    if degrees is not None:
        groups = plan_groups(cfg, degrees, schedules, seqs)
        base = 0
        for g, grp in enumerate(groups):
            leaves = by_slot.get(("groups", g), {})
            for name, arr in leaves.items():
                if arr.shape[0] != grp.count:
                    raise ValueError(
                        f"group {g} leaf {name} has leading dim "
                        f"{arr.shape[0]}, plan group expects {grp.count}")
                for o in range(grp.count):
                    per_layer[base + o][name] = arr[o]
            base += grp.count
        if base != cfg.num_layers:
            raise ValueError(
                f"plan groups cover {base} layers, config has "
                f"{cfg.num_layers}")
    else:
        n, pat, tail = stack_layout(cfg)
        for (coll, idx), leaves in sorted(by_slot.items()):
            if coll == "groups":
                raise ValueError(
                    "checkpoint holds grouped (planner-mode) layers but "
                    "no per-layer plan was recorded — cannot recover the "
                    "layer order")
            if coll == "blocks":
                # the [v, pp, per] stage stacking exists only under a
                # 'pipe' mesh axis — interleaving depth without PP
                # (pp=1, v>1) stays on the flat [n] layout
                stage_stacked = max(pp, 1) > 1
                for name, arr in leaves.items():
                    # pipeline stacking [v, pp, n/S, ...] row-major
                    # flattens to the canonical [n, ...] layer order
                    a = arr.reshape((n,) + arr.shape[3:]) if stage_stacked \
                        else arr
                    for r in range(n):
                        per_layer[r * len(pat) + idx][name] = a[r]
            else:                                    # tail
                for name, arr in leaves.items():
                    per_layer[n * len(pat) + idx][name] = arr
    return static, per_layer


def pack_layer_flat(cfg: ArchConfig, static: Dict[str, np.ndarray],
                    per_layer, *,
                    degrees: Optional[Sequence] = None,
                    schedules: Optional[Sequence[str]] = None,
                    seqs: Optional[Sequence[int]] = None,
                    pp: int = 1,
                    virtual_stages: int = 1) -> Dict[str, np.ndarray]:
    """Inverse of :func:`split_layer_flat`: repack canonical per-layer
    dicts into the target layout's flat keystr view."""
    flat = dict(static)
    if degrees is not None:
        base = 0
        for g, grp in enumerate(plan_groups(cfg, degrees, schedules, seqs)):
            for name in per_layer[base]:
                flat[f"['groups'][{g}]{name}"] = np.stack(
                    [per_layer[base + o][name] for o in range(grp.count)])
            base += grp.count
    else:
        n, pat, tail = stack_layout(cfg)
        v = max(virtual_stages, 1)
        for p in range(len(pat)):
            if not n:
                break
            for name in per_layer[p]:
                arr = np.stack([per_layer[r * len(pat) + p][name]
                                for r in range(n)])
                if pp > 1:
                    arr = arr.reshape((v, pp, n // (pp * v)) + arr.shape[1:])
                flat[f"['blocks'][{p}]{name}"] = arr
        for t in range(len(tail)):
            for name, arr in per_layer[n * len(pat) + t].items():
                flat[f"['tail'][{t}]{name}"] = arr
    return flat


def tree_to_flat(tree) -> Dict[str, np.ndarray]:
    """Flat {keystr: host array} view of a params-like tree (the
    checkpoint manifest's native form)."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(jax.device_get(v))
            for kp, v in leaves}


def tree_from_flat(specs_or_like, flat: Dict[str, np.ndarray]):
    """Materialize a tree with the structure of ``specs_or_like`` (a Spec
    tree or any params-like tree) from a flat {keystr: array} dict."""
    is_leaf = (lambda x: is_spec(x)) if any(
        is_spec(leaf) for leaf in jax.tree_util.tree_leaves(
            specs_or_like, is_leaf=is_spec)) else None
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        specs_or_like, is_leaf=is_leaf)
    vals = []
    for kp, _ in leaves:
        key = jax.tree_util.keystr(kp)
        if key not in flat:
            raise KeyError(
                f"relayout missing leaf {key} — source and target plans "
                f"describe different models")
        vals.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, vals)


def relayout_flat(cfg: ArchConfig, flat: Dict[str, np.ndarray],
                  src: Dict, dst: Dict) -> Dict[str, np.ndarray]:
    """Re-stack a flat params-like dict from the ``src`` plan layout into
    the ``dst`` plan layout.  ``src``/``dst`` describe each side's
    grouping: ``{"degrees", "schedules", "pp", "virtual_stages"}`` (all
    optional; degrees=None means the stacked layout)."""
    static, per_layer = split_layer_flat(
        cfg, flat, degrees=src.get("degrees"),
        schedules=src.get("schedules"), seqs=src.get("seqs"),
        pp=src.get("pp", 1),
        virtual_stages=src.get("virtual_stages", 1))
    return pack_layer_flat(
        cfg, static, per_layer, degrees=dst.get("degrees"),
        schedules=dst.get("schedules"), seqs=dst.get("seqs"),
        pp=dst.get("pp", 1),
        virtual_stages=dst.get("virtual_stages", 1))
