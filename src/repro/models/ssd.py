"""Mamba2 SSD (state-space duality) — pure-JAX chunked reference path.

Implements the chunked algorithm of the Mamba2 paper (intra-chunk quadratic
attention-like term + inter-chunk linear state recurrence), with ngroups=1.
Exact w.r.t. the sequential recurrence (tested in tests/test_ssd.py).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_chunked(x, dt, A_log, B, C, D, *, chunk: int = 128, h0=None):
    """x [b, s, h, p]; dt [b, s, h] (post-softplus discretization step);
    A_log [h]; B, C [b, s, n]; D [h] skip.  Returns (y [b,s,h,p], state
    [b, h, p, n]).

    Recurrence per head:  S_t = exp(-exp(A_log) * dt_t) * S_{t-1}
                                + dt_t * x_t ⊗ B_t
                          y_t = S_t · C_t + D * x_t
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} must divide by chunk {chunk}"
    nc = s // chunk

    xf = x.astype(jnp.float32)
    a = -jnp.exp(A_log.astype(jnp.float32))                 # [h], a < 0
    dta = dt.astype(jnp.float32) * a[None, None, :]         # [b, s, h] log-decay
    dtx = xf * dt.astype(jnp.float32)[..., None]            # [b, s, h, p]
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    # chunk views
    def r(t, extra):
        return t.reshape((b, nc, chunk) + extra)

    dta_c = r(dta, (h,))
    x_c = r(dtx, (h, p))
    B_c = r(Bf, (n,))
    C_c = r(Cf, (n,))

    la = jnp.cumsum(dta_c, axis=2)                          # [b,nc,Q,h] cumlog
    la_last = la[:, :, -1:, :]                              # chunk total decay

    # intra-chunk (masked quadratic): y_ij = C_i·B_j * exp(la_i - la_j), j<=i
    seg = jnp.exp(la[:, :, :, None, :] - la[:, :, None, :, :])  # [b,nc,i,j,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)            # [b,nc,i,j]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, seg, x_c)

    # chunk states: S_c = sum_j exp(la_last - la_j) * B_j ⊗ x_j
    decay_to_end = jnp.exp(la_last - la)                    # [b,nc,Q,h]
    S = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, B_c, x_c)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(la_last[:, :, 0, :])              # [b,nc,h]

    def step(carry, inp):
        Sc, dc = inp                                        # [b,h,p,n], [b,h]
        new = carry * dc[..., None, None] + Sc
        return new, carry                                   # emit state *before* chunk

    init = (h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros((b, h, p, n), jnp.float32))
    final, prev_states = lax.scan(
        step, init, (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # [b,nc,h,p,n]

    # inter-chunk contribution: y_i += exp(la_i) * C_i · S_prev
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp",
                         jnp.exp(la), C_c, prev_states)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), final


def ssd_sequential(x, dt, A_log, B, C, D, h0=None):
    """O(s) sequential oracle for testing."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    a = -jnp.exp(A_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * a[None, :])                   # [b,h]
        S = S * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    init = (h0.astype(jnp.float32) if h0 is not None
            else jnp.zeros((b, h, p, n), jnp.float32))
    xs = (xf.transpose(1, 0, 2, 3), dt.astype(jnp.float32).transpose(1, 0, 2),
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    S, ys = lax.scan(step, init, xs)
    y = ys.transpose(1, 0, 2, 3) + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), S


def ssd_step(x, dt, A_log, B, C, D, S):
    """Single decode step.  x [b,h,p]; dt [b,h]; B, C [b,n]; S [b,h,p,n]."""
    a = -jnp.exp(A_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    decay = jnp.exp(dt.astype(jnp.float32) * a[None, :])
    S = S * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xf * dt.astype(jnp.float32)[..., None],
        B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, C.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), S
