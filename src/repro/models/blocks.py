"""Layer assembly: each layer kind exposes

* ``train_parts``  — residual part functions ``part(p, x, aux) -> (delta, aux_loss)``
  ending in a TMP collective where sharded (the unit the Oases schedule
  interleaves across sub-batches),
* ``prefill``      — ``fn(p, x, aux) -> (x, state)`` full-sequence + cache build,
* ``decode``       — ``fn(p, x, state, aux) -> (x, state)`` single-token step.

``aux`` carries {'positions': [b,s], 'pos': [b] (decode), 'ctx': [b,L,D]}.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ArchConfig, CROSS_ATTN, GLOBAL_ATTN,
                                LOCAL_ATTN, RGLRU, SSD)
from repro.core import tmp as tmpc
from repro.core.schedule import TmpCtx
from repro.models import rglru as rglru_m
from repro.models import ssd as ssd_m
from repro.models.attention import (chunked_attention, decode_attention,
                                    decode_attention_multi,
                                    paged_decode_attention,
                                    paged_decode_attention_multi, rope)
from repro.models.params import attn_plan, ssd_dims

ZERO = jnp.float32(0.0)


def _norm(x, scale, eps):
    return tmpc.rms_norm(x, scale, eps)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _qkv(cfg, ctx: TmpCtx, p, h, positions, prefix="", use_rope=True):
    """Project h -> (q [b,s,hl,hd], k, v [b,s,kvs,hd]) local views.
    Pass p[prefix+'wq'] = None to skip the q projection (cross-attn kv).

    Heads shard over the x-axes (``ctx.tp`` = dx); in the 2D layout the
    projections' contraction (d_model) dim additionally shards over y —
    ``ctx.proj`` slices h's matching chunk and AllReduces the partials.
    """
    plan = attn_plan(cfg, ctx.tp)
    hd = cfg.resolved_head_dim
    b, s, _ = h.shape
    wq = p.get(prefix + "wq")
    q = (ctx.proj(h, wq).reshape(b, s, plan.h_local, hd)
         if wq is not None else None)
    wk, wv = p[prefix + "wk"], p[prefix + "wv"]
    if plan.sharded and not plan.kv_sharded \
            and plan.kv_slice < cfg.num_kv_heads:
        # kv weights replicated over x: slice the kv-head group this
        # shard's q needs (rows may still be y-sharded — slice h to match)
        group = cfg.num_heads // cfg.num_kv_heads
        r = tmpc.axes_index(ctx.x_axes)
        start = (r * plan.h_local) // group
        hy, partial = ctx.contract_slice(h, wk.shape[0])
        wk = lax.dynamic_slice_in_dim(
            wk.reshape(wk.shape[0], cfg.num_kv_heads, hd), start,
            plan.kv_slice, axis=1)
        wv = lax.dynamic_slice_in_dim(
            wv.reshape(wv.shape[0], cfg.num_kv_heads, hd), start,
            plan.kv_slice, axis=1)
        k = ctx.contract_reduce(jnp.einsum("bsd,dkh->bskh", hy, wk), partial)
        v = ctx.contract_reduce(jnp.einsum("bsd,dkh->bskh", hy, wv), partial)
    else:
        k = ctx.proj(h, wk).reshape(b, s, -1, hd)
        v = ctx.proj(h, wv).reshape(b, s, -1, hd)
        if plan.sharded and plan.kv_slice == cfg.num_kv_heads \
                and cfg.num_kv_heads != cfg.num_heads \
                and plan.h_local % cfg.num_kv_heads != 0:
            # non-aligned GQA fallback: gather each local q head's kv head
            # (local MHA view) — hit only by non-power-of-two head ratios
            group = cfg.num_heads // cfg.num_kv_heads
            r = tmpc.axes_index(ctx.x_axes)
            idx = (r * plan.h_local
                   + jnp.arange(plan.h_local, dtype=jnp.int32)) // group
            k = jnp.take(k, idx, axis=2)
            v = jnp.take(v, idx, axis=2)
    if use_rope:
        if q is not None:
            q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v, plan


def _attn_out(cfg, ctx: TmpCtx, p, attn, plan, prefix=""):
    b, s = attn.shape[:2]
    flat = attn.reshape(b, s, plan.h_local * cfg.resolved_head_dim)
    w = p[prefix + "wo"]
    if plan.sharded or w.shape[-1] != cfg.d_model:
        return ctx.row_matmul(flat, w, full_out=cfg.d_model)
    return jnp.dot(flat, w)


def make_attn_part(cfg: ArchConfig, ctx: TmpCtx, kind: str) -> Callable:
    # decoder self-attn is causal; the encoder path calls
    # encoder_layer_fn (causal=False) instead.
    window = cfg.window if kind == LOCAL_ATTN else None

    if ctx.seq_shard > 1 and kind in (GLOBAL_ATTN, LOCAL_ATTN):
        from jax.ad_checkpoint import checkpoint_name
        from repro.kernels.ring_attention import ring_attention

        def ring_part(p, x, aux):
            # ring attention (DESIGN.md §12): x stays sequence-sharded
            # through the mixer.  Weights are replicated (full heads per
            # device — the shard_map boundary psums their seq-partial
            # grads, same convention as the norm scales) and the KV
            # shards circulate around the TMP ring inside the kernel.
            h = _norm(x, p["ln"], cfg.norm_eps)
            b, s_loc, _ = h.shape
            hd = cfg.resolved_head_dim
            pos = lax.dynamic_slice_in_dim(
                aux["positions"], tmpc.axes_index(ctx.tp_axes) * s_loc,
                s_loc, axis=1)
            q = rope(jnp.dot(h, p["wq"]).reshape(
                b, s_loc, cfg.num_heads, hd), pos, cfg.rope_theta)
            k = rope(jnp.dot(h, p["wk"]).reshape(
                b, s_loc, cfg.num_kv_heads, hd), pos, cfg.rope_theta)
            v = jnp.dot(h, p["wv"]).reshape(b, s_loc, cfg.num_kv_heads, hd)
            o = ring_attention(q, k, v, axes=ctx.tp_axes, causal=True,
                               window=window, softcap=cfg.attn_softcap,
                               q_positions=pos, kv_positions=pos,
                               use_pallas=ctx.use_pallas)
            o = checkpoint_name(o, tmpc.COLLECTIVE_NAME)
            delta = jnp.dot(o.reshape(b, s_loc, cfg.num_heads * hd),
                            p["wo"])
            if cfg.post_norms:
                delta = _norm(delta, p["pn1"], cfg.norm_eps)
            return delta, ZERO

        return ring_part

    def part(p, x, aux):
        h = ctx.gather_seq(_norm(x, p["ln"], cfg.norm_eps))
        q, k, v, plan = _qkv(cfg, ctx, p, h, aux["positions"])
        o = chunked_attention(q, k, v, causal=True, window=window,
                              softcap=cfg.attn_softcap,
                              q_positions=aux["positions"],
                              kv_positions=aux["positions"])
        delta = _attn_out(cfg, ctx, p, o, plan)
        if not plan.sharded:
            delta = ctx.shard_seq(delta)
        if cfg.post_norms:
            delta = _norm(delta, p["pn1"], cfg.norm_eps)
        return delta, ZERO

    return part


def make_cross_part(cfg: ArchConfig, ctx: TmpCtx) -> Callable:
    def part(p, x, aux):
        h = ctx.gather_seq(_norm(x, p["c_ln"], cfg.norm_eps))
        cctx = aux["ctx"]
        plan = attn_plan(cfg, ctx.tp)
        hd = cfg.resolved_head_dim
        b, s, _ = h.shape
        q = ctx.proj(h, p["c_wq"]).reshape(b, s, plan.h_local, hd)
        _, ck, cv, _ = _qkv(cfg, ctx, {"wk": p["c_wk"], "wv": p["c_wv"]},
                            cctx, None, use_rope=False)
        o = chunked_attention(q, ck, cv, causal=False, softcap=0.0)
        delta = _attn_out(cfg, ctx, {"wo": p["c_wo"]}, o, plan)
        if not plan.sharded:
            delta = ctx.shard_seq(delta)
        gate = jnp.tanh(p["c_gate"].astype(delta.dtype))
        return delta * gate, ZERO

    return part


# --------------------------------------------------------------------------
# FFN / MoE
# --------------------------------------------------------------------------
def make_mlp_part(cfg: ArchConfig, ctx: TmpCtx) -> Callable:
    if cfg.moe is not None:
        from repro.models.moe import moe_ffn

        def part(p, x, aux):
            h = ctx.gather_seq(_norm(x, p["ln2"], cfg.norm_eps))
            moe_p = {k: p[k] for k in ("router", "w1", "w3", "w2")}
            delta, aux_l = moe_ffn(
                h, moe_p, num_experts=cfg.moe.num_experts,
                top_k=cfg.moe.top_k, cap_factor=cfg.moe.capacity_factor,
                sharding=cfg.moe.sharding, tp_axes=ctx.tp_axes,
                reduce_fn=ctx.reduce)
            return delta, aux_l * cfg.moe.router_aux_weight

        return part

    def part(p, x, aux):
        # fused+SP: one all-gather ring feeds both up-projections
        g, u = ctx.gather_matmul(_norm(x, p["ln2"], cfg.norm_eps),
                                 (p["wg"], p["wu"]))
        a = jax.nn.silu(g) * u
        # sharded rows (column-parallel width) or sharded output columns
        # (2D) -> the row-parallel exit path; else a plain local dot
        wd = p["wd"]
        if wd.shape[0] != cfg.d_ff or wd.shape[-1] != cfg.d_model:
            delta = ctx.row_matmul(a, wd, full_out=cfg.d_model)
        else:
            delta = ctx.shard_seq(jnp.dot(a, wd))
        if cfg.post_norms:
            delta = _norm(delta, p["pn2"], cfg.norm_eps)
        return delta, ZERO

    return part


# --------------------------------------------------------------------------
# RG-LRU block
# --------------------------------------------------------------------------
def _rglru_gates(p):
    return {k: p[k] for k in ("w_a", "b_a", "w_x", "b_x", "a_param")}


def make_rglru_part(cfg: ArchConfig, ctx: TmpCtx) -> Callable:
    def part(p, x, aux):
        xb, gb = ctx.gather_matmul(_norm(x, p["ln"], cfg.norm_eps),
                                   (p["w_in_x"], p["w_in_g"]))
        xc, _ = rglru_m.depthwise_conv1d(xb, p["conv"])
        y, _ = rglru_m.rglru_scan(xc, _rglru_gates(p))
        o = jax.nn.gelu(gb) * y
        w = cfg.rglru_width or cfg.d_model
        wo = p["w_out"]
        if wo.shape[0] != w or wo.shape[-1] != cfg.d_model:
            delta = ctx.row_matmul(o, wo, full_out=cfg.d_model)
        else:
            delta = ctx.shard_seq(jnp.dot(o, wo))
        return delta, ZERO

    return part


# --------------------------------------------------------------------------
# SSD (mamba2) block — replicated mixer
# --------------------------------------------------------------------------
def _ssd_split(cfg, z_xbc_dt):
    d_inner, nheads, n = ssd_dims(cfg)
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner:2 * d_inner + 2 * n]
    dt = z_xbc_dt[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt, (d_inner, nheads, n)


def make_ssd_part(cfg: ArchConfig, ctx: TmpCtx) -> Callable:
    def part(p, x, aux):
        (proj,) = ctx.gather_matmul(_norm(x, p["ln"], cfg.norm_eps),
                                    (p["in_proj"],))
        z, xbc, dtp, (d_inner, nheads, n) = _ssd_split(cfg, proj)
        xbc, _ = rglru_m.depthwise_conv1d(xbc, p["conv"])
        xbc = jax.nn.silu(xbc)
        xs = xbc[..., :d_inner]
        B = xbc[..., d_inner:d_inner + n]
        C = xbc[..., d_inner + n:]
        b, s, _ = proj.shape         # proj is seq-gathered in SP mode
        xh = xs.reshape(b, s, nheads, cfg.ssm_headdim)
        dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
        y, _ = ssd_m.ssd_chunked(xh, dt, p["A_log"], B, C, p["Dskip"],
                                 chunk=min(128, s))
        y = y.reshape(b, s, d_inner)
        y = tmpc.rms_norm(y, p["norm_g"], cfg.norm_eps) * jax.nn.silu(
            z.astype(y.dtype))
        return ctx.shard_seq(jnp.dot(y, p["out_proj"])), ZERO

    return part


# --------------------------------------------------------------------------
def train_parts(cfg: ArchConfig, ctx: TmpCtx, kind: str) -> List[Callable]:
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        return [make_attn_part(cfg, ctx, kind), make_mlp_part(cfg, ctx)]
    if kind == CROSS_ATTN:
        return [make_attn_part(cfg, ctx, kind), make_cross_part(cfg, ctx),
                make_mlp_part(cfg, ctx)]
    if kind == RGLRU:
        return [make_rglru_part(cfg, ctx), make_mlp_part(cfg, ctx)]
    if kind == SSD:
        return [make_ssd_part(cfg, ctx)]
    raise ValueError(kind)


# ==========================================================================
# prefill (full sequence, builds cache) and decode (single token)
# ==========================================================================
def _update_linear_cache(cache, new, pos):
    """cache [b,S,kv,hd]; new [b,s,kv,hd]; pos scalar start (prefill)."""
    return lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, axis=1)


def prefill_fn(cfg: ArchConfig, ctx: TmpCtx, kind: str) -> Callable:
    parts_mlp = (make_mlp_part(cfg, ctx)
                 if (kind != SSD and cfg.d_ff) else None)
    window = cfg.window if kind == LOCAL_ATTN else None

    def fn(p, x, aux):
        st: Dict[str, Any] = {}
        if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
            h = _norm(x, p["ln"], cfg.norm_eps)
            q, k, v, plan = _qkv(cfg, ctx, p, h, aux["positions"])
            o = chunked_attention(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn_softcap,
                                  q_positions=aux["positions"],
                                  kv_positions=aux["positions"])
            delta = _attn_out(cfg, ctx, p, o, plan)
            if cfg.post_norms:
                delta = _norm(delta, p["pn1"], cfg.norm_eps)
            x = x + delta
            if window is not None and k.shape[1] > window:
                # keep the trailing window in ring order (slot = pos % window)
                s = k.shape[1]
                roll = s % window
                k, v = k[:, s - window:], v[:, s - window:]
                k = jnp.roll(k, roll, axis=1)
                v = jnp.roll(v, roll, axis=1)
            st["k"], st["v"] = k, v
            if kind == CROSS_ATTN:
                cctx = aux["ctx"]
                _, ck, cv, _ = _qkv(cfg, ctx, {"wk": p["c_wk"], "wv": p["c_wv"]},
                                    cctx, None, use_rope=False)
                st["c_k"], st["c_v"] = ck, cv
                hc = _norm(x, p["c_ln"], cfg.norm_eps)
                b, s, _ = hc.shape
                qd = ctx.proj(hc, p["c_wq"]).reshape(
                    b, s, plan.h_local, cfg.resolved_head_dim)
                oc = chunked_attention(qd, ck, cv, causal=False)
                dc = _attn_out(cfg, ctx, {"wo": p["c_wo"]}, oc, plan)
                x = x + dc * jnp.tanh(p["c_gate"].astype(dc.dtype))
        elif kind == RGLRU:
            h = _norm(x, p["ln"], cfg.norm_eps)
            xb = ctx.proj(h, p["w_in_x"])
            gb = ctx.proj(h, p["w_in_g"])
            xc, conv_st = rglru_m.depthwise_conv1d(xb, p["conv"])
            y, h_last = rglru_m.rglru_scan(xc, _rglru_gates(p))
            o = jax.nn.gelu(gb) * y
            w = cfg.rglru_width or cfg.d_model
            wo_ = p["w_out"]
            if wo_.shape[0] != w or wo_.shape[-1] != cfg.d_model:
                delta = ctx.row_matmul(o, wo_, full_out=cfg.d_model)
            else:
                delta = jnp.dot(o, wo_)
            x = x + delta
            st["h"], st["conv"] = h_last, conv_st
        elif kind == SSD:
            h = _norm(x, p["ln"], cfg.norm_eps)
            z, xbc, dtp, (d_inner, nheads, n) = _ssd_split(
                cfg, ctx.proj(h, p["in_proj"]))
            xbc_c, conv_st = rglru_m.depthwise_conv1d(xbc, p["conv"])
            xbc_c = jax.nn.silu(xbc_c)
            xs_, B, C = (xbc_c[..., :d_inner], xbc_c[..., d_inner:d_inner + n],
                         xbc_c[..., d_inner + n:])
            b, s, _ = x.shape
            dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
            y, S = ssd_m.ssd_chunked(
                xs_.reshape(b, s, nheads, cfg.ssm_headdim), dt, p["A_log"],
                B, C, p["Dskip"], chunk=min(128, s))
            y = y.reshape(b, s, d_inner)
            y = tmpc.rms_norm(y, p["norm_g"], cfg.norm_eps) * jax.nn.silu(
                z.astype(y.dtype))
            x = x + jnp.dot(y, p["out_proj"])
            st["S"], st["conv"] = S, conv_st
        else:
            raise ValueError(kind)
        if parts_mlp is not None:
            d, _ = parts_mlp(p, x, aux)
            x = x + d
        return x, st

    return fn


def decode_fn(cfg: ArchConfig, ctx: TmpCtx, kind: str) -> Callable:
    parts_mlp = (make_mlp_part(cfg, ctx)
                 if (kind != SSD and cfg.d_ff) else None)
    is_local = kind == LOCAL_ATTN
    hd = cfg.resolved_head_dim

    def fn(p, x, st, aux):
        pos = aux["pos"]                       # [b] int32 current position
        b = x.shape[0]
        if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
            h = _norm(x, p["ln"], cfg.norm_eps)
            q, k, v, plan = _qkv(cfg, ctx, p, h, pos[:, None])
            bidx = jnp.arange(b, dtype=jnp.int32)
            st = dict(st)
            if kind == GLOBAL_ATTN and "tables" in aux:
                # paged cache: st["k"]/["v"] are page pools
                # [pages, page, kvh, hd]; the slot's block table maps its
                # current logical block to a physical page.  Inactive
                # slots carry all-zero tables and write the null page.
                tables = aux["tables"]                 # [b, nb] int32
                page = st["k"].shape[1]
                phys = tables[bidx, pos // page]
                off = pos % page
                st["k"] = st["k"].at[phys, off].set(
                    k[:, 0].astype(st["k"].dtype))
                st["v"] = st["v"].at[phys, off].set(
                    v[:, 0].astype(st["v"].dtype))
                if ctx.use_pallas and jax.default_backend() == "tpu":
                    from repro.kernels.flash_attention import \
                        paged_flash_decode
                    o = paged_flash_decode(q, st["k"], st["v"], tables, pos,
                                           softcap=cfg.attn_softcap)
                else:
                    o = paged_decode_attention(q, st["k"], st["v"], tables,
                                               pos, softcap=cfg.attn_softcap)
            else:
                S = st["k"].shape[1]
                slot = (pos % S) if is_local else pos
                st["k"] = st["k"].at[bidx, slot].set(
                    k[:, 0].astype(st["k"].dtype))
                st["v"] = st["v"].at[bidx, slot].set(
                    v[:, 0].astype(st["v"].dtype))
                o = decode_attention(q, st["k"], st["v"], pos,
                                     window=cfg.window if is_local else None,
                                     softcap=cfg.attn_softcap, ring=is_local)
            delta = _attn_out(cfg, ctx, p, o, plan)
            if cfg.post_norms:
                delta = _norm(delta, p["pn1"], cfg.norm_eps)
            x = x + delta
            if kind == CROSS_ATTN:
                hc = _norm(x, p["c_ln"], cfg.norm_eps)
                qd = ctx.proj(hc, p["c_wq"]).reshape(b, 1, plan.h_local, hd)
                Lc = st["c_k"].shape[1]
                oc = decode_attention(qd, st["c_k"], st["c_v"],
                                      jnp.full((b,), Lc - 1, jnp.int32))
                dc = _attn_out(cfg, ctx, {"wo": p["c_wo"]}, oc, plan)
                x = x + dc * jnp.tanh(p["c_gate"].astype(dc.dtype))
        elif kind == RGLRU:
            h = _norm(x, p["ln"], cfg.norm_eps)
            xb = ctx.proj(h, p["w_in_x"])
            gb = ctx.proj(h, p["w_in_g"])
            hist = jnp.concatenate([st["conv"], xb], axis=1)   # [b, k, W]
            y_c = jnp.einsum("bkw,kw->bw", hist, p["conv"])[:, None]
            y, h_new = rglru_m.rglru_step(y_c, _rglru_gates(p), st["h"])
            o = jax.nn.gelu(gb) * y
            w = cfg.rglru_width or cfg.d_model
            wo_ = p["w_out"]
            if wo_.shape[0] != w or wo_.shape[-1] != cfg.d_model:
                delta = ctx.row_matmul(o, wo_, full_out=cfg.d_model)
            else:
                delta = jnp.dot(o, wo_)
            x = x + delta
            st = {"h": h_new, "conv": hist[:, 1:]}
        elif kind == SSD:
            h = _norm(x, p["ln"], cfg.norm_eps)
            z, xbc, dtp, (d_inner, nheads, n) = _ssd_split(
                cfg, ctx.proj(h, p["in_proj"]))
            hist = jnp.concatenate([st["conv"], xbc], axis=1)  # [b, k, .]
            xbc_c = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv"]))
            xs_, B, C = (xbc_c[..., :d_inner], xbc_c[..., d_inner:d_inner + n],
                         xbc_c[..., d_inner + n:])
            dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"])
            y, S = ssd_m.ssd_step(
                xs_.reshape(b, nheads, cfg.ssm_headdim), dt, p["A_log"],
                B, C, p["Dskip"], st["S"])
            y = y.reshape(b, 1, d_inner)
            y = tmpc.rms_norm(y, p["norm_g"], cfg.norm_eps) * jax.nn.silu(
                z.astype(y.dtype))
            x = x + jnp.dot(y, p["out_proj"])
            st = {"S": S, "conv": hist[:, 1:]}
        else:
            raise ValueError(kind)
        if parts_mlp is not None:
            d, _ = parts_mlp(p, x, aux)
            x = x + d
        return x, st

    return fn


def verify_fn(cfg: ArchConfig, ctx: TmpCtx, kind: str) -> Callable:
    """Multi-token decode step for speculative verification.

    Like :func:`decode_fn` but ``x`` carries ``qn`` consecutive draft
    tokens at absolute positions ``pos + j``; the layer writes all ``qn``
    KV entries and attends causally within the block (write-then-attend,
    same convention as single-token decode, so verifying a draft of 1 is
    the plain decode step).  Only GLOBAL_ATTN layers support this:
    skipping ahead through ring buffers or recurrent states would need
    their intermediate states, which is exactly what verification avoids
    recomputing."""
    if kind != GLOBAL_ATTN:
        raise NotImplementedError(
            f"speculative verification supports global-attention layers "
            f"only (got {kind}) — local-window ring buffers and recurrent "
            f"states cannot absorb multi-token jumps")
    parts_mlp = make_mlp_part(cfg, ctx) if cfg.d_ff else None

    def fn(p, x, st, aux):
        pos = aux["pos"]                       # [b]; token j sits at pos+j
        b, qn, _ = x.shape
        bidx = jnp.arange(b, dtype=jnp.int32)
        positions = pos[:, None] + jnp.arange(qn, dtype=jnp.int32)[None, :]
        h = _norm(x, p["ln"], cfg.norm_eps)
        q, k, v, plan = _qkv(cfg, ctx, p, h, positions)
        st = dict(st)
        if "tables" in aux:
            tables = aux["tables"]
            page = st["k"].shape[1]
            lim = tables.shape[1] * page
            clamped = jnp.minimum(positions, lim - 1)
            phys = tables[bidx[:, None], clamped // page]       # [b, qn]
            st["k"] = st["k"].at[phys, clamped % page].set(
                k.astype(st["k"].dtype))
            st["v"] = st["v"].at[phys, clamped % page].set(
                v.astype(st["v"].dtype))
            o = paged_decode_attention_multi(q, st["k"], st["v"], tables,
                                             pos, softcap=cfg.attn_softcap)
        else:
            S = st["k"].shape[1]
            slots = jnp.minimum(positions, S - 1)
            st["k"] = st["k"].at[bidx[:, None], slots].set(
                k.astype(st["k"].dtype))
            st["v"] = st["v"].at[bidx[:, None], slots].set(
                v.astype(st["v"].dtype))
            o = decode_attention_multi(q, st["k"], st["v"], pos,
                                       softcap=cfg.attn_softcap)
        delta = _attn_out(cfg, ctx, p, o, plan)
        if cfg.post_norms:
            delta = _norm(delta, p["pn1"], cfg.norm_eps)
        x = x + delta
        if parts_mlp is not None:
            d, _ = parts_mlp(p, x, aux)
            x = x + d
        return x, st

    return fn


# --------------------------------------------------------------------------
# encoder (whisper) — bidirectional self-attn blocks, sequential
# --------------------------------------------------------------------------
def encoder_layer_fn(cfg: ArchConfig, ctx: TmpCtx) -> Callable:
    mlp = make_mlp_part(cfg, ctx)

    def fn(p, x):
        h = _norm(x, p["ln"], cfg.norm_eps)
        q, k, v, plan = _qkv(cfg, ctx, p, h, None, use_rope=False)
        o = chunked_attention(q, k, v, causal=False)
        x = x + _attn_out(cfg, ctx, p, o, plan)
        d, _ = mlp(p, x, None)
        return x + d

    return fn
