"""RG-LRU recurrence (Griffin / RecurrentGemma) — pure-JAX reference path.

Diagonal input/recurrence gates (per-channel), as in the Griffin paper's
block-diagonal limit; see DESIGN.md §Arch-applicability.  Training uses an
associative scan (log-depth); decode is a single recurrence step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

C_CONST = 8.0


def depthwise_conv1d(x, w, state=None):
    """Causal depthwise temporal conv.  x [b, s, W]; w [k, W].

    ``state`` [b, k-1, W] carries the last k-1 inputs for decode; returns
    (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y, new_state


def _gates(x, p):
    """x [b, s, W] -> (log_a [b,s,W] f32, gated input [b,s,W] f32)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(xf * p["w_x"] + p["b_x"])
    log_a = -C_CONST * jax.nn.softplus(p["a_param"]) * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * xf)
    return log_a, gated


def rglru_scan(x, p, h0=None):
    """Full-sequence RG-LRU.  x [b, s, W] (conv'd branch); returns (y, h_last).

    h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t), a_t = exp(log_a_t).
    """
    log_a, gated = _gates(x, p)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_sc, h = lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        # fold an initial state through: h_t += (prod a_1..t) * h0
        h = h + a_sc * h0[:, None, :].astype(jnp.float32)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(x, p, h_prev):
    """Single decode step.  x [b, 1, W]; h_prev [b, W] f32."""
    log_a, gated = _gates(x, p)
    a = jnp.exp(log_a[:, 0])
    h = a * h_prev + gated[:, 0]
    return h[:, None, :].astype(x.dtype), h
