"""Megatron-style TMP primitives with Oases semantics, for use inside
``shard_map`` bodies.

``tmp_reduce`` is the TMP AllReduce (Megatron g): a *raw* ``lax.psum`` whose
output is tagged ``checkpoint_name(.., COLLECTIVE_NAME)``.  Combined with
the ``save_only_these_names`` remat policy in :mod:`repro.core.remat`, the
saved residual set is exactly the collective outputs, so rematerialization
never re-executes a TMP collective — the paper's fine-grained recomputation
(§3.2, justified by Eq. 1: ∂y/∂x_i = 1 makes the forward AllReduce output a
sufficient residual) realized as a JAX remat policy.

Gradient convention: ``shard_map``'s transpose uses partial cotangents
(see ``reduce_from_tmp``), under which no Megatron-f operator is needed and
``psum`` transposes to ``psum``.  The sequence-parallel (SP) pair
``sp_all_gather``/``sp_reduce_scatter`` and the slice ``batch_split`` are
custom-VJPs consistent with that convention.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.ad_checkpoint import checkpoint_name

from repro.core.compat import axis_size as _axis_size

COLLECTIVE_NAME = "oases_collective"
Axes = Tuple[str, ...]


# --------------------------------------------------------------------------
# core collectives
# --------------------------------------------------------------------------
# NOTE: there is intentionally no ``copy_to_tmp`` (Megatron f).  Under
# shard_map's partial-cotangent convention an identity-fwd/psum-bwd operator
# at column-parallel inputs would double-count: the boundary transpose
# already psums parameter gradients over their replicated axes, and
# activation cotangents are *supposed* to stay partial inside the region.


def reduce_from_tmp(x, axes: Axes):
    """AllReduce forward (Megatron g) — deliberately a *raw* ``lax.psum``.

    Backward: ``shard_map``'s transpose uses the partial-cotangent convention
    (cotangents of replicated tensors are per-shard partial sums; the
    shard_map boundary inserts the final psum for parameters), under which
    ``psum`` transposes to ``psum``.  The per-layer collective count is
    identical to Megatron's f/g pair — 2 AllReduces forward, 2 backward —
    attached to g instead of f.  Eq. (1) (∂y/∂x_i = 1) is what makes the
    *forward* AllReduce's output a sufficient residual: with the fine-grained
    remat policy saving it (see tmp_reduce), the rematerialized subgraph
    contains no collective at all.

    Kept as a plain primitive (NOT custom_vjp) so the remat policy can see
    through it — a custom_vjp call is opaque to ``save_only_these_names`` and
    would be replayed during recomputation, defeating §3.2.
    """
    return lax.psum(x, axes) if axes else x


def tmp_reduce(x, axes: Axes, name: str = COLLECTIVE_NAME):
    """AllReduce + name the output for the fine-grained remat policy."""
    # named_scope: trace-time only — tags the psum in HLO metadata so the
    # reduce phase is attributable in XLA profiles (repro.obs.tracing)
    with jax.named_scope("tmp.reduce"):
        return checkpoint_name(reduce_from_tmp(x, axes), name)


# --------------------------------------------------------------------------
# sequence-parallel variants (beyond-paper: Megatron-SP AG/RS comm scheme)
# --------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def sp_all_gather(x, axes: Axes, dim: int):
    with jax.named_scope("tmp.sp_all_gather"):
        return lax.all_gather(x, axes, axis=dim, tiled=True) if axes else x


def _spag_fwd(x, axes, dim):
    return sp_all_gather(x, axes, dim), None


def _spag_bwd(axes, dim, _, g):
    return (lax.psum_scatter(g, axes, scatter_dimension=dim, tiled=True)
            if axes else g,)


sp_all_gather.defvjp(_spag_fwd, _spag_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def sp_reduce_scatter(x, axes: Axes, dim: int):
    with jax.named_scope("tmp.sp_reduce_scatter"):
        return (lax.psum_scatter(x, axes, scatter_dimension=dim, tiled=True)
                if axes else x)


def _sprs_fwd(x, axes, dim):
    return sp_reduce_scatter(x, axes, dim), None


def _sprs_bwd(axes, dim, _, g):
    return (lax.all_gather(g, axes, axis=dim, tiled=True) if axes else g,)


sp_reduce_scatter.defvjp(_sprs_fwd, _sprs_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def batch_split(x, axes: Axes, dim: int):
    """Keep this shard's chunk of dim (planner-mode degree-down reshard).

    Forward is a free local slice; backward is the AllGather that reassembles
    the full-batch gradient (each shard holds a disjoint chunk, and the
    pre-split tensor was replicated over ``axes``)."""
    if not axes:
        return x
    import math
    sz = math.prod(_axis_size(a) for a in axes)
    chunk = x.shape[dim] // sz
    return lax.dynamic_slice_in_dim(x, axes_index(axes) * chunk, chunk,
                                    axis=dim)


def _bs_fwd(x, axes, dim):
    return batch_split(x, axes, dim), None


def _bs_bwd(axes, dim, _, g):
    # Partial-cotangent convention: the pre-split tensor was REPLICATED over
    # ``axes``, so each shard returns only its own chunk's cotangent placed
    # at its offset (zeros elsewhere); the shard-sum reassembles the full
    # gradient.  (An all_gather here would overcount by |axes| once the
    # shard_map boundary psums replicated-parameter grads.)
    if not axes:
        return (g,)
    import math
    sz = math.prod(_axis_size(a) for a in axes)
    chunk = g.shape[dim]
    full_shape = g.shape[:dim] + (chunk * sz,) + g.shape[dim + 1:]
    zeros = jnp.zeros(full_shape, g.dtype)
    return (lax.dynamic_update_slice_in_dim(
        zeros, g, axes_index(axes) * chunk, axis=dim),)


batch_split.defvjp(_bs_fwd, _bs_bwd)


# --------------------------------------------------------------------------
# axis index helpers (SPMD-traced)
# --------------------------------------------------------------------------
def axes_index(axes: Axes):
    """Linearized index of this shard within the given (ordered) axes."""
    if not axes:
        return jnp.int32(0)
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * _axis_size(a) + lax.axis_index(a)
    return idx


def axes_size(axes: Axes) -> int:
    import math
    return math.prod(_axis_size(a) for a in axes) if axes else 1


# --------------------------------------------------------------------------
# the "pass barrier" used to emulate Merak's recompute/backward barriers
# --------------------------------------------------------------------------
@jax.custom_vjp
def pass_barrier(x):
    """Identity forward; optimization_barrier on the gradient.  Emulates the
    inter-pass barriers of layer-granularity recomputation schedules (Merak)
    so the A/B vs the barrier-free Oases cross-pass schedule is visible in
    the emitted HLO."""
    return x


def _pb_fwd(x):
    return x, None


def _pb_bwd(_, g):
    return (lax.optimization_barrier(g),)


pass_barrier.defvjp(_pb_fwd, _pb_bwd)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


# --------------------------------------------------------------------------
# vocab-parallel embedding + cross-entropy (Megatron-style, chunked)
# --------------------------------------------------------------------------
def vocab_parallel_embed(tokens, embed_local, axes: Axes, *,
                         sp_seq_dim=None):
    """tokens [..] int32 (replicated over tp); embed_local [V/tp, D].
    ``sp_seq_dim``: sequence-parallel mode — the completing collective is a
    reduce-scatter along that dim instead of an AllReduce."""
    v_local = embed_local.shape[0]
    offset = axes_index(axes) * v_local
    local_tok = tokens - offset
    in_shard = (local_tok >= 0) & (local_tok < v_local)
    local_tok = jnp.clip(local_tok, 0, v_local - 1)
    out = jnp.take(embed_local, local_tok, axis=0)
    out = jnp.where(in_shard[..., None], out, jnp.zeros_like(out))
    if sp_seq_dim is not None and axes:
        return checkpoint_name(sp_reduce_scatter(out, axes, sp_seq_dim),
                               COLLECTIVE_NAME)
    return tmp_reduce(out, axes)


def _xent_chunk(x, head_local, labels, axes: Axes, softcap: float):
    """x [t, D]; head_local [D, V/tp]; labels [t] -> (sum_nll[t])."""
    logits = jnp.dot(x.astype(jnp.float32), head_local.astype(jnp.float32))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    v_local = logits.shape[-1]
    offset = axes_index(axes) * v_local
    # stable log-sum-exp across vocab shards (max is stability-only, so the
    # pmax sees only a stopped-gradient constant — pmax has no JVP rule)
    m_local = lax.stop_gradient(jnp.max(logits, axis=-1))
    m = lax.pmax(m_local, axes) if axes else m_local
    z_local = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
    z = reduce_from_tmp(z_local, axes)
    local_lab = labels - offset
    in_shard = (local_lab >= 0) & (local_lab < v_local)
    local_lab = jnp.clip(local_lab, 0, v_local - 1)
    lab_logit_local = jnp.take_along_axis(
        logits, local_lab[..., None], axis=-1)[..., 0]
    lab_logit_local = jnp.where(in_shard, lab_logit_local, 0.0)
    lab_logit = reduce_from_tmp(lab_logit_local, axes)
    return jnp.log(z) + m - lab_logit


def vocab_parallel_xent(x, head_local, labels, axes: Axes, *,
                        chunk: int = 512, softcap: float = 0.0,
                        mask=None):
    """Chunked vocab-parallel cross entropy.

    Never materializes [tokens, V]; each seq chunk's logits live only inside a
    rematerialized scan step (beyond-paper memory optimization — the paper's
    models cap at V=50k where the full logit tensor still fits).

    x [b, s, D]; head_local [D, V/tp]; labels [b, s] -> (loss_sum, count).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    mf = mask.reshape(t) if mask is not None else jnp.ones((t,), jnp.float32)
    chunk = min(chunk, t)
    n = t // chunk
    rem = t - n * chunk

    # rank-1 carry: jax 0.4.x shard_map mis-names rank-0 scan-carry
    # residuals under remat (see core/compat.py) — (1,) sidesteps it.
    @jax.checkpoint
    def step(carry, inp):
        xc, lc, mc = inp
        nll = _xent_chunk(xc, head_local, lc, axes, softcap)
        return carry + jnp.sum(nll * mc), None

    init = jnp.zeros((1,), jnp.float32)
    if n:
        xs = (xf[:n * chunk].reshape(n, chunk, d),
              lf[:n * chunk].reshape(n, chunk),
              mf[:n * chunk].reshape(n, chunk))
        init, _ = lax.scan(step, init, xs)
    if rem:
        nll = _xent_chunk(xf[n * chunk:], head_local, lf[n * chunk:], axes,
                          softcap)
        init = init + jnp.sum(nll * mf[n * chunk:])
    return jnp.sum(init), jnp.sum(mf)
