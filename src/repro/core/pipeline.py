"""SPMD pipeline parallelism over a ``pipe`` mesh axis (interleaved 1F1B).

The layer stack is cut into ``S = pp * v`` stages: ``pp`` physical stages
(one per pipe-mesh coordinate) times ``v`` *virtual* stages per device
(Megatron-style interleaving; ``v = 1`` degenerates to the classic
GPipe/1F1B fill-drain loop).  Stage ``s`` runs on device ``s mod pp`` as
that device's chunk ``c = s // pp`` — the strided placement that shrinks
the pipeline bubble from ``(pp-1)/m`` to ``(pp-1)/(v*m)`` of the work.

Everything runs inside the model's single ``shard_map``:

* microbatches are injected at (device 0, chunk 0), flow stage-to-stage via
  a circular ``lax.ppermute`` (shift +1 with wrap), and are collected at
  (device pp-1, chunk v-1);
* on the wrap (device pp-1 -> device 0) the per-device chunk buffers roll
  ``c -> c+1``, so a tensor that finished chunk ``c`` on the last device
  continues as chunk ``c+1`` on device 0 — the circular schedule;
* each device's buffers hold at most one in-flight microbatch per chunk;
  slots outside the fill/drain window process zeros whose outputs are
  masked (never reach the loss), so their gradient contribution is exactly
  zero.

Because the forward is a plain traced loop, ``jax.grad`` transposes it into
the *reverse* pipeline automatically — ``ppermute`` transposes to the
inverted permutation — and the cross-pass interleaving of forward
microbatch ``j+1`` with backward microbatch ``j`` is admitted as program
structure, exactly like the TMP schedules (DESIGN.md §2): gradient
accumulation across microbatches is folded into the schedule rather than an
outer loop.  Stage-internal TMP collectives (all schedules, including the
fused collective-matmul rings) are untouched: they run over the model axes,
orthogonal to ``pipe``.

Parameter layout: stacked layer groups are stored ``[v, pp, n/S, ...]``
with only the ``pp`` dim sharded (over ``pipe``).  The row-major flatten of
``(c, d, j)`` is the canonical layer order — stage ``s = c*pp + d`` holds
layers ``[s*n/S, (s+1)*n/S)`` — so a pure reshape moves checkpoints between
PP and non-PP meshes (the elastic re-mesh path, ``checkpoint/store.py``).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.axes import MeshInfo


def validate_stage_layout(cfg, n_blocks: int, n_tail: int, pp: int,
                          virtual_stages: int = 1) -> int:
    """Check the layer stack divides into ``pp * v`` equal stages; returns
    the per-stage scan length.  Raises a friendly ValueError otherwise."""
    v = max(virtual_stages, 1)
    if pp < 1:
        raise ValueError(f"pipeline degree must be >= 1, got {pp}")
    if getattr(cfg, "is_encdec", False) or getattr(cfg, "context_len", 0):
        raise ValueError(
            f"pipeline parallelism does not support encoder-decoder / "
            f"cross-attention architectures yet ({cfg.name}): the encoder "
            f"and context stream are not stage-partitioned — drop the "
            f"'pipe' mesh axis for this model")
    if n_tail:
        raise ValueError(
            f"pipeline parallelism requires num_layers divisible by the "
            f"layer pattern (no tail layers); {cfg.name} has "
            f"{cfg.num_layers} layers over a {len(cfg.layer_pattern)}-kind "
            f"pattern leaving {n_tail} tail layer(s)")
    stages = pp * v
    if n_blocks % stages:
        raise ValueError(
            f"cannot cut {n_blocks} layer group(s) of {cfg.name} into "
            f"pp={pp} x v={v} = {stages} equal pipeline stages; pick pp/"
            f"virtual_stages dividing {n_blocks} or adjust num_layers")
    return n_blocks // stages


def _resolve_divisor(local_batch: int, cap: int, requested: int,
                     what: str) -> int:
    """Shared micro-count resolution: the requested value must divide the
    per-shard batch (raises otherwise); auto (0) takes the largest divisor
    up to ``cap``."""
    local = max(local_batch, 1)
    if requested:
        if requested < 1 or local % requested:
            raise ValueError(
                f"{what} {requested} must be a positive divisor of the "
                f"per-shard batch {local}")
        return requested
    n = min(local, max(cap, 1))
    while n > 1 and local % n:
        n -= 1
    return max(n, 1)


def resolve_microbatch(local_batch: int, pp: int, virtual_stages: int = 1,
                       requested: int = 0) -> int:
    """Pipeline microbatch count: the requested value (validated), else the
    largest divisor of the per-shard batch up to ``2 * pp * v`` — enough
    microbatches in flight to keep the bubble below ~1/(2v), without
    shrinking each microbatch past usefulness."""
    return _resolve_divisor(local_batch, 2 * pp * max(virtual_stages, 1),
                            requested, "pipeline microbatch count")


def bubble_fraction(pp: int, n_micro: int, virtual_stages: int = 1) -> float:
    """Idle fraction of the interleaved 1F1B schedule:
    (pp-1) / (pp-1 + v*m)."""
    if pp <= 1:
        return 0.0
    v = max(virtual_stages, 1)
    return (pp - 1) / (pp - 1 + v * max(n_micro, 1))


def mask_to_last_stage(val, pipe_axis: str, pp: int):
    """Zero ``val`` everywhere except the final pipeline stage (whose shard
    holds the real model output); combine with a psum over ``pipe``."""
    last = lax.axis_index(pipe_axis) == pp - 1
    return jnp.where(last, val, jnp.zeros_like(val))


def pipeline_apply(stage_fn: Callable, x_micro, *, pipe_axis: str, pp: int,
                   virtual_stages: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Drive the circular interleaved pipeline schedule.

    ``stage_fn(c, x) -> (y, aux)`` runs this device's virtual-stage chunk
    ``c`` on microbatch tensor ``x``; ``aux`` is a rank-1 ``(1,)`` f32
    accumulator (auxiliary losses).  ``x_micro`` is ``[n_micro, mb, ...]``,
    identical on every pipe shard (batch-sharded over the data axes only).

    Returns ``(out [n_micro, mb, ...], aux [1])`` where ``out`` holds the
    fully-processed microbatches on the LAST stage's shards (other shards
    carry zeros-derived garbage — mask with :func:`mask_to_last_stage`
    before the loss) and ``aux`` holds this shard's stages' masked
    contributions (psum over ``pipe`` + batch axes to total).

    The time loop is a ``lax.scan`` over the tick index, so trace/compile
    size is constant in the microbatch count (only the ``v`` chunk calls
    unroll); differentiating the scan yields the reverse pipeline.
    """
    v = max(virtual_stages, 1)
    stages = pp * v
    n_micro = int(x_micro.shape[0])
    d_idx = lax.axis_index(pipe_axis)
    perm = [(i, (i + 1) % pp) for i in range(pp)]

    def tick(carry, t):
        buf, aux_total = carry
        # stage (0, chunk 0) ingests microbatch t during the fill window;
        # other devices keep their in-flight state
        inject = lax.dynamic_index_in_dim(
            x_micro, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
        buf = buf.at[0].set(jnp.where((t < n_micro) & (d_idx == 0),
                                      inject, buf[0]))
        new_chunks = []
        for c in range(v):
            y, aux_c = stage_fn(c, buf[c])
            # slot (c, d) holds microbatch m = t - stage_index; outside the
            # window it processed zeros — drop its aux contribution
            m = t - (c * pp + d_idx)
            valid = (m >= 0) & (m < n_micro)
            aux_total = aux_total + jnp.where(valid, aux_c,
                                              jnp.zeros_like(aux_c))
            new_chunks.append(y)
        buf = jnp.stack(new_chunks)
        # (device pp-1, chunk v-1) finishes microbatch t-(S-1) this tick —
        # emit it before the shift (garbage during fill; sliced off below)
        out_t = buf[v - 1]
        # advance one stage: shift along the pipe ring; on the wrap into
        # device 0 the tensor moves to the next virtual chunk (the finished
        # chunk v-1 output was collected above; chunk 0 frees for injection)
        buf = lax.ppermute(buf, pipe_axis, perm)
        rolled = jnp.concatenate(
            [jnp.zeros_like(buf[:1]), buf[:-1]], axis=0) if v > 1 \
            else jnp.zeros_like(buf)
        buf = jnp.where(d_idx == 0, rolled, buf)
        return (buf, aux_total), out_t

    buf0 = jnp.zeros((v,) + tuple(x_micro.shape[1:]), x_micro.dtype)
    aux0 = jnp.zeros((1,), jnp.float32)
    (_, aux_total), ys = lax.scan(
        tick, (buf0, aux0), jnp.arange(n_micro + stages - 1,
                                       dtype=jnp.int32))
    return ys[stages - 1:], aux_total


def decode_stream(stage_fn: Callable, x_micro, state, *, pipe_axis: str,
                  pp: int, virtual_stages: int = 1, paged: bool = False
                  ) -> Tuple[jax.Array, object]:
    """Stream decode micro-steps through the pipeline stages.

    The serving analogue of :func:`pipeline_apply`: the slot batch of one
    decode step is cut into ``n_micro`` micro-groups that flow through the
    stages tick by tick, so stage ``s`` decodes micro-group ``g`` while
    stage ``s-1`` decodes micro-group ``g+1`` — every stage is busy in the
    steady state instead of waiting for the full stack to traverse.  Unlike
    training there is no backward pass and the per-stage KV caches are
    *stateful*: they stay put on their stage (only activations ride the
    ``ppermute`` ring) and are updated in place for the micro-group a slot
    currently holds.

    ``x_micro``  — ``[n_micro, mb, ...]`` micro-grouped token activations,
    identical on every pipe shard.
    ``state``    — pytree of per-stage caches, local leaves
    ``[v, 1(pipe), per_stage, batch, ...]`` (models/params.cache_specs
    pipeline stacking); the batch dim (axis 3) spans all micro-groups.
    ``stage_fn(c, h, st_c, m)`` — run this device's virtual chunk ``c`` on
    micro-group tensor ``h`` with its cache slice ``st_c`` (leaves
    ``[per_stage, mb, ...]``, batch rows of micro-group ``m``); returns
    ``(y, st_c_new)``.

    Out-of-window slots process zeros/stale buffers whose outputs are
    discarded and whose cache writes are masked off — the cache is only
    ever written by the tick that legitimately owns micro-group ``m`` at
    that stage, which is what keeps sharded decode token-identical to the
    single-device oracle.  Returns ``(out [n_micro, mb, ...], state)``
    where ``out`` is valid on the last stage's shards (combine with
    :func:`mask_to_last_stage` + a psum over ``pipe`` to broadcast).

    ``paged=True``: cache leaves are page *pools*
    ``[v, 1, per_stage, pages, ...]`` with no batch axis — every
    micro-group reads/writes the shared pool through its own block-table
    rows, so the stage gets the full pool and an out-of-window tick's
    pool update is discarded wholesale (its gather/scatter targeted live
    pages of the clipped micro-group).
    """
    v = max(virtual_stages, 1)
    stages = pp * v
    n_micro = int(x_micro.shape[0])
    mb = int(x_micro.shape[1])
    d_idx = lax.axis_index(pipe_axis)
    perm = [(i, (i + 1) % pp) for i in range(pp)]
    tmap = jax.tree_util.tree_map

    def slice_state(st, c, start):
        if paged:
            return tmap(lambda leaf: leaf[c, 0], st)
        return tmap(lambda leaf: lax.dynamic_slice_in_dim(
            leaf[c, 0], start, mb, axis=1), st)

    def write_state(st, c, start, new, valid):
        def upd(leaf, nl):
            cur = leaf[c, 0]
            if paged:
                return leaf.at[c, 0].set(
                    jnp.where(valid, nl.astype(leaf.dtype), cur))
            nxt = lax.dynamic_update_slice_in_dim(
                cur, nl.astype(leaf.dtype), start, axis=1)
            return leaf.at[c, 0].set(jnp.where(valid, nxt, cur))
        return tmap(upd, st, new)

    def tick(carry, t):
        buf, st = carry
        inject = lax.dynamic_index_in_dim(
            x_micro, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
        buf = buf.at[0].set(jnp.where((t < n_micro) & (d_idx == 0),
                                      inject, buf[0]))
        new_chunks = []
        for c in range(v):
            m = t - (c * pp + d_idx)
            valid = (m >= 0) & (m < n_micro)
            mc = jnp.clip(m, 0, n_micro - 1)
            start = mc * mb
            y, st_new = stage_fn(c, buf[c], slice_state(st, c, start), mc)
            st = write_state(st, c, start, st_new, valid)
            new_chunks.append(y)
        buf = jnp.stack(new_chunks)
        out_t = buf[v - 1]
        buf = lax.ppermute(buf, pipe_axis, perm)
        rolled = jnp.concatenate(
            [jnp.zeros_like(buf[:1]), buf[:-1]], axis=0) if v > 1 \
            else jnp.zeros_like(buf)
        buf = jnp.where(d_idx == 0, rolled, buf)
        return (buf, st), out_t

    buf0 = jnp.zeros((v,) + tuple(x_micro.shape[1:]), x_micro.dtype)
    (_, state), ys = lax.scan(
        tick, (buf0, state), jnp.arange(n_micro + stages - 1,
                                        dtype=jnp.int32))
    return ys[stages - 1:], state


def resolve_decode_micro(local_batch: int, pp: int, virtual_stages: int = 1,
                         requested: int = 0) -> int:
    """Decode micro-group count: the requested value (validated), else the
    largest divisor of the slot batch up to ``pp * v`` — exactly enough
    in-flight micro-groups to fill the pipe.  More would re-stream each
    stage's (memory-bound) weights extra times per engine step; fewer
    leaves stages idle."""
    return _resolve_divisor(local_batch, pp * max(virtual_stages, 1),
                            requested, "decode micro-group count")


def pipeline_batch_axes(info: MeshInfo) -> Tuple[str, ...]:
    """Axes a pipeline-parallel loss must aggregate over: the batch axes
    plus ``pipe`` (each stage contributes its masked slice)."""
    return info.batch_axes + info.pipe_axes
