"""JAX version compatibility shims.

The codebase targets the current jax API (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``,
``pltpu.CompilerParams``); CI and several deployment targets still run
jax 0.4.x where those live under older names.  Everything version-dependent
is funneled through this module so call sites stay on the modern spelling.

Covered:

* ``make_mesh``       — ``axis_types=`` kwarg appeared after 0.4.x; older
                        jax has no axis types, so the kwarg is dropped.
* ``shard_map``       — ``jax.shard_map(..., check_vma=)`` vs
                        ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
* ``set_mesh``        — ``jax.set_mesh`` vs entering the ``Mesh`` context
                        manager directly (sufficient for explicit-mesh
                        ``shard_map`` callees).
* ``tpu_compiler_params`` — ``pltpu.CompilerParams`` was called
                        ``pltpu.TPUCompilerParams`` on 0.4.x.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Optional, Sequence

import jax


@functools.lru_cache(maxsize=1)
def _make_mesh_supports_axis_types() -> bool:
    import inspect
    return "axis_types" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Optional[Sequence[Any]] = None,
              devices=None):
    """``jax.make_mesh`` accepting (and dropping, pre-AxisType jax) the
    ``axis_types`` kwarg.  Support is probed by signature, not by catching
    TypeError — a malformed ``axis_types`` on modern jax must surface, not
    silently degrade to default axis types."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _make_mesh_supports_axis_types():
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where AxisType exists, else None."""
    try:
        from jax.sharding import AxisType
        return (AxisType.Auto,) * n
    except ImportError:
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Modern ``jax.shard_map`` signature, falling back to
    ``jax.experimental.shard_map`` (where ``check_vma`` was ``check_rep``).

    Known 0.4.x caveat the model code works around: under ``grad`` +
    scan + a remat policy, a RANK-0 scan-carry residual crossing the
    shard_map boundary gets mis-assigned full axis names and crashes with
    ``_SpecError`` (fixed in later jax).  All scalar scan carries inside
    shard_map bodies are therefore kept rank-1 ``(1,)`` (see
    ``models/lm.py`` and ``core/tmp.py``).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def set_mesh(mesh):
    """``jax.set_mesh`` context; on older jax the ``Mesh`` object itself is
    the context manager that installs it as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext()


def axis_size(a) -> int:
    """Static mesh-axis size; ``lax.psum(1, a)`` constant-folds to the axis
    size on jax versions without ``lax.axis_size``."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(a)
    return lax.psum(1, a)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
