"""Recomputation policies (paper §3.2).

* coarse  — Megatron/Merak default: save only block inputs; recomputation
  re-executes everything **including the TMP collectives**.
* fine    — Oases fine-grained recomputation: additionally save every TMP
  collective *output* (they are tagged ``checkpoint_name(.., COLLECTIVE_NAME)``
  in :func:`repro.core.tmp.tmp_reduce`).  The rematerialized subgraph then
  contains zero TMP collectives — Eq. (1) says their gradient contribution is
  identity, and the forward values are residuals, so the AllReduce is dead
  code in recompute.  ``tests/test_remat.py`` asserts this on real HLO.
"""
from __future__ import annotations

import jax

from repro.core.tmp import COLLECTIVE_NAME


def remat_policy(fine: bool):
    if fine:
        return jax.checkpoint_policies.save_only_these_names(COLLECTIVE_NAME)
    return jax.checkpoint_policies.nothing_saveable


def maybe_checkpoint(fn, *, remat: bool, fine: bool, prevent_cse: bool = True):
    if not remat:
        return fn
    return jax.checkpoint(fn, policy=remat_policy(fine),
                          prevent_cse=prevent_cse)
