"""Mesh-axis bookkeeping.

Three mesh flavours exist:

* uniform meshes — ``('data','model')`` / ``('pod','data','model')`` — used for
  the 40 baseline dry-run cells (TMP degree = |model| everywhere),
* uniform 2D meshes — ``('data','model_x','model_y')`` — the hybrid-partition
  layout: weight *width* (heads / d_ff) shards over ``model_x`` while the
  *contraction* dim (d_model) shards over ``model_y`` (à la the 2D method of
  arXiv:2104.05343).  On commodity servers ``model_x`` maps to the fast
  intra-node lanes and ``model_y`` to the thin inter-node NIC, and
* the planner (factored) mesh — ``('data','t1','t2','t3','t4')`` — where the
  16-way model axis is split into binary sub-axes so a per-layer TMP degree
  ``n = 2^k`` is "shard over the first k t-axes, data-parallel over the rest"
  (paper §4.2: partitioning schemes limited to powers of two).  A 2D degree
  ``(dx, dy)`` on this mesh takes the first ``log2 dx`` t-axes as x and the
  next ``log2 dy`` as y, so the planner can mix 1D and 2D layers freely.

A per-layer TMP **degree** is either an ``int`` (1D) or an ``(dx, dy)``
tuple (2D); every axis-algebra entry point accepts both.

Any of these meshes may additionally carry a leading ``pipe`` axis
(:mod:`repro.core.pipeline`): layer-stack stages shard over it, the batch
and every TMP collective ignore it, and stage boundaries talk point-to-point
via ``ppermute``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple, Union

from jax.sharding import Mesh, PartitionSpec as P

T_AXES: Tuple[str, ...] = ("t1", "t2", "t3", "t4")
X_AXIS = "model_x"
Y_AXIS = "model_y"
PIPE_AXIS = "pipe"

Degree = Union[int, Tuple[int, int], None]


def deg_total(degree: Degree) -> Optional[int]:
    """Total TMP group size of a degree (None passes through)."""
    if isinstance(degree, (tuple, list)):
        return int(degree[0]) * int(degree[1])
    return degree


def deg_xy(degree: Degree) -> Tuple[Optional[int], int]:
    """(dx, dy) view of a degree; an int degree is (n, 1)."""
    if isinstance(degree, (tuple, list)):
        return int(degree[0]), int(degree[1])
    return degree, 1


def _log2_exact(n: int, what: str) -> int:
    k = int(math.log2(n)) if n > 0 else -1
    if n <= 0 or 2 ** k != n:
        raise ValueError(f"{what} must be a power of two, got {n}")
    return k


@dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    batch_axes: Tuple[str, ...]   # ('pod','data') ∩ mesh axes
    model_axes: Tuple[str, ...]   # ('model',) or a prefix-factorable T_AXES
    pipe_axes: Tuple[str, ...] = ()   # ('pipe',) when pipeline-parallel

    @property
    def tp(self) -> int:
        s = dict(self.mesh.shape)
        return math.prod(s[a] for a in self.model_axes) if self.model_axes else 1

    @property
    def pp(self) -> int:
        """Pipeline-parallel degree (number of physical stages)."""
        s = dict(self.mesh.shape)
        return math.prod(s[a] for a in self.pipe_axes) if self.pipe_axes else 1

    @property
    def dp(self) -> int:
        s = dict(self.mesh.shape)
        return math.prod(s[a] for a in self.batch_axes) if self.batch_axes else 1

    @property
    def factored(self) -> bool:
        return bool(self.model_axes) and self.model_axes[0] in T_AXES

    @property
    def twod(self) -> bool:
        """Mesh carries an explicit 2D model layout (a ``model_y`` axis)."""
        return Y_AXIS in self.model_axes

    # ---- per-degree axis algebra (planner / factored mesh only) ----
    def tp_axes(self, degree: Degree = None) -> Tuple[str, ...]:
        """Model axes carrying TMP sharding for a layer of given degree.

        A 2D ``(dx, dy)`` degree returns the x- and y-axes concatenated —
        the combined group used for vocab sharding, batch-axis algebra and
        anything else that is layout-agnostic.
        """
        if isinstance(degree, (tuple, list)):
            ax, ay = self.xy_axes(degree)
            return ax + ay
        if degree is None or degree == self.tp:
            return self.model_axes
        if not self.factored:
            raise ValueError(
                f"degree {degree} != mesh tp {self.tp} requires the factored mesh")
        if degree == 1:
            return ()
        k = _log2_exact(degree, "TMP degree")
        if degree > self.tp:
            raise ValueError(f"TMP degree must be a power of two <= {self.tp}")
        return self.model_axes[:k]

    def xy_axes(self, degree: Degree = None
                ) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        """Split a layer's model axes into ``(x_axes, y_axes)``.

        x carries the width (head / d_ff) sharding, y the contraction-dim
        (d_model) sharding of the 2D hybrid layout.  Int degrees (and plain
        1D meshes) put everything in x; a mesh with an explicit ``model_y``
        axis splits there; tuple degrees on the factored mesh take binary
        sub-axis prefixes.
        """
        if isinstance(degree, (tuple, list)):
            dx, dy = int(degree[0]), int(degree[1])
            if dy == 1:
                return self.tp_axes(dx), ()
            if self.twod:
                s = dict(self.mesh.shape)
                sx = math.prod(s[a] for a in self.model_axes if a != Y_AXIS) \
                    if len(self.model_axes) > 1 else 1
                sy = s.get(Y_AXIS, 1)
                if (dx, dy) != (sx, sy):
                    raise ValueError(
                        f"2D degree {(dx, dy)} != mesh layout ({sx}, {sy})")
                return (tuple(a for a in self.model_axes if a != Y_AXIS),
                        (Y_AXIS,))
            if not self.factored:
                raise ValueError(
                    "per-layer 2D degrees need the factored or "
                    "model_x/model_y mesh")
            kx = _log2_exact(dx, "2D degree dx")
            ky = _log2_exact(dy, "2D degree dy")
            if kx + ky > len(self.model_axes):
                raise ValueError(
                    f"2D degree {(dx, dy)} exceeds mesh tp {self.tp}")
            return self.model_axes[:kx], self.model_axes[kx:kx + ky]
        axes = self.tp_axes(degree)
        return (tuple(a for a in axes if a != Y_AXIS),
                tuple(a for a in axes if a == Y_AXIS))

    def extra_dp_axes(self, degree: Degree = None) -> Tuple[str, ...]:
        """Model axes a lower-degree layer reuses as extra data parallelism."""
        used = self.tp_axes(degree)
        return tuple(a for a in self.model_axes if a not in used)

    def all_batch_axes(self, degree: Degree = None) -> Tuple[str, ...]:
        return self.batch_axes + self.extra_dp_axes(degree)

    def axes_not_in(self, pspec: P) -> Tuple[str, ...]:
        """Mesh axes a tensor with this PartitionSpec is *replicated* over.

        Used to derive the gradient all-reduce group of each parameter.
        """
        used = set()
        for entry in pspec:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                used.update(entry)
            else:
                used.add(entry)
        return tuple(a for a in self.mesh.axis_names if a not in used)


def mesh_info(mesh: Mesh) -> MeshInfo:
    names = tuple(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in names)
    pipe = tuple(a for a in (PIPE_AXIS,) if a in names)
    if "model" in names:
        model: Tuple[str, ...] = ("model",)
    elif X_AXIS in names or Y_AXIS in names:
        model = tuple(a for a in (X_AXIS, Y_AXIS) if a in names)
    else:
        model = tuple(a for a in T_AXES if a in names)
    return MeshInfo(mesh=mesh, batch_axes=batch, model_axes=model,
                    pipe_axes=pipe)


def batch_pspec(info: MeshInfo, global_batch: int,
                degree: Degree = None) -> P:
    """Sharding of the batch dim; falls back gracefully when not divisible
    (e.g. long_500k has global_batch=1 -> replicated batch)."""
    axes = []
    s = dict(info.mesh.shape)
    rem = global_batch
    for a in info.all_batch_axes(degree):
        if rem % s[a] == 0:
            axes.append(a)
            rem //= s[a]
    return P(tuple(axes) if axes else None)


def local_batch(info: MeshInfo, global_batch: int,
                degree: Degree = None) -> int:
    spec = batch_pspec(info, global_batch, degree)
    s = dict(info.mesh.shape)
    div = 1
    entry = spec[0] if len(spec) else None
    if entry:
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            div *= s[a]
    return global_batch // div
