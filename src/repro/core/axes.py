"""Mesh-axis bookkeeping.

Two mesh flavours exist:

* uniform meshes — ``('data','model')`` / ``('pod','data','model')`` — used for
  the 40 baseline dry-run cells (TMP degree = |model| everywhere), and
* the planner (factored) mesh — ``('data','t1','t2','t3','t4')`` — where the
  16-way model axis is split into binary sub-axes so a per-layer TMP degree
  ``n = 2^k`` is "shard over the first k t-axes, data-parallel over the rest"
  (paper §4.2: partitioning schemes limited to powers of two).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

T_AXES: Tuple[str, ...] = ("t1", "t2", "t3", "t4")


@dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh
    batch_axes: Tuple[str, ...]   # ('pod','data') ∩ mesh axes
    model_axes: Tuple[str, ...]   # ('model',) or a prefix-factorable T_AXES

    @property
    def tp(self) -> int:
        s = dict(self.mesh.shape)
        return math.prod(s[a] for a in self.model_axes) if self.model_axes else 1

    @property
    def dp(self) -> int:
        s = dict(self.mesh.shape)
        return math.prod(s[a] for a in self.batch_axes) if self.batch_axes else 1

    @property
    def factored(self) -> bool:
        return self.model_axes and self.model_axes[0] != "model"

    # ---- per-degree axis algebra (planner / factored mesh only) ----
    def tp_axes(self, degree: Optional[int] = None) -> Tuple[str, ...]:
        """Model axes carrying TMP sharding for a layer of given degree."""
        if degree is None or degree == self.tp:
            return self.model_axes
        if not self.factored:
            raise ValueError(
                f"degree {degree} != mesh tp {self.tp} requires the factored mesh")
        if degree == 1:
            return ()
        k = int(math.log2(degree))
        if 2 ** k != degree or degree > self.tp:
            raise ValueError(f"TMP degree must be a power of two <= {self.tp}")
        return self.model_axes[:k]

    def extra_dp_axes(self, degree: Optional[int] = None) -> Tuple[str, ...]:
        """Model axes a lower-degree layer reuses as extra data parallelism."""
        used = self.tp_axes(degree)
        return tuple(a for a in self.model_axes if a not in used)

    def all_batch_axes(self, degree: Optional[int] = None) -> Tuple[str, ...]:
        return self.batch_axes + self.extra_dp_axes(degree)

    def axes_not_in(self, pspec: P) -> Tuple[str, ...]:
        """Mesh axes a tensor with this PartitionSpec is *replicated* over.

        Used to derive the gradient all-reduce group of each parameter.
        """
        used = set()
        for entry in pspec:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                used.update(entry)
            else:
                used.add(entry)
        return tuple(a for a in self.mesh.axis_names if a not in used)


def mesh_info(mesh: Mesh) -> MeshInfo:
    names = tuple(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in names)
    if "model" in names:
        model: Tuple[str, ...] = ("model",)
    else:
        model = tuple(a for a in T_AXES if a in names)
    return MeshInfo(mesh=mesh, batch_axes=batch, model_axes=model)


def batch_pspec(info: MeshInfo, global_batch: int,
                degree: Optional[int] = None) -> P:
    """Sharding of the batch dim; falls back gracefully when not divisible
    (e.g. long_500k has global_batch=1 -> replicated batch)."""
    axes = []
    s = dict(info.mesh.shape)
    rem = global_batch
    for a in info.all_batch_axes(degree):
        if rem % s[a] == 0:
            axes.append(a)
            rem //= s[a]
    return P(tuple(axes) if axes else None)


def local_batch(info: MeshInfo, global_batch: int,
                degree: Optional[int] = None) -> int:
    spec = batch_pspec(info, global_batch, degree)
    s = dict(info.mesh.shape)
    div = 1
    entry = spec[0] if len(spec) else None
    if entry:
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            div *= s[a]
    return global_batch // div
