"""First-class executable parallelism plans.

A :class:`ParallelPlan` is the single serializable object that carries a
run's parallelism decisions end to end: the planner emits one, the
launchers desugar legacy flags into one (``launch/mesh.py``), the trainer
and serving engine execute one, and the checkpoint manifest records one so
elastic restarts can validate/reshard across plan changes.

The paper's search space (§4, Table 6) is *per layer*: each layer carries
its own ``(degree, schedule)`` strategy, where ``degree`` is a TMP degree
(``None`` = follow the whole mesh model group, an ``int`` = 1D ring, an
``(dx, dy)`` tuple = 2D hybrid) and ``schedule`` names one of the overlap
schedules of :data:`repro.core.schedule.SCHEDULES`.  Consecutive layers
sharing a strategy execute as one scan group (``models/lm.py``), so a
uniform plan degenerates to the classic stacked layout.

Everything here is pure-Python (no jax import) so plans can be built,
validated and round-tripped anywhere — including inside the planner's ILP
and the checkpoint manifest reader.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

# mirror of repro.core.schedule.SCHEDULES (kept here so configs/base.py and
# this module stay import-cycle-free; tests/test_plan.py pins the two equal)
SCHEDULES = ("megatron", "wang", "merak", "oases", "fused")
TMP_LAYOUTS = ("auto", "1d", "2d")

Degree = Any    # None | int | (dx, dy)


def validate_schedule(name: str, *, what: str = "schedule") -> str:
    """Friendly schedule-name validation: an unknown string used to fall
    silently through the ``effective_split``/``TmpCtx`` branches to
    megatron-like behaviour — now it fails at construction, naming the
    valid set."""
    if name not in SCHEDULES:
        raise ValueError(
            f"unknown {what} {name!r}: valid schedules are "
            f"{', '.join(SCHEDULES)} (see core/schedule.py)")
    return name


def _canon_degree(d: Degree, *, what: str = "degree") -> Degree:
    """Canonicalize/validate one per-layer degree: None, a positive
    power-of-two int, or an (dx, dy) tuple of such ints."""
    def _pow2(n) -> int:
        if not isinstance(n, int) or isinstance(n, bool) or n <= 0 \
                or n & (n - 1):
            raise ValueError(
                f"bad {what} {d!r}: TMP degrees must be positive powers "
                f"of two (paper §4.2), None (follow the mesh), or "
                f"(dx, dy) tuples of such ints")
        return n

    if d is None:
        return None
    if isinstance(d, (tuple, list)):
        if len(d) != 2:
            raise ValueError(
                f"bad {what} {d!r}: a 2D degree is exactly (dx, dy)")
        dx, dy = _pow2(d[0]), _pow2(d[1])
        return dx if dy == 1 else (dx, dy)
    return _pow2(d)


@dataclass(frozen=True)
class LayerStrategy:
    """One layer's ``(degree, schedule, seq)`` strategy.

    ``seq`` is the ring-attention sequence-shard factor (DESIGN.md §12):
    1 = classic head-sharded TMP; > 1 = the layer keeps activations
    sequence-sharded through attention with replicated attention weights
    and a KV ring over the layer's model group.  At runtime ``seq`` must
    equal the layer's effective TMP group size (checked in models/lm.py —
    the ring spans exactly the group the heads would have sharded over).
    """
    degree: Degree = None
    schedule: str = "oases"
    seq: int = 1

    def __post_init__(self):
        object.__setattr__(self, "degree", _canon_degree(self.degree))
        validate_schedule(self.schedule, what="layer schedule")
        q = self.seq
        if not isinstance(q, int) or isinstance(q, bool) or q < 1 \
                or q & (q - 1):
            raise ValueError(
                f"bad layer seq {q!r}: ring-attention seq shards must be "
                f"a positive power-of-two int (1 = off)")
        if q > 1 and isinstance(self.degree, tuple):
            raise ValueError(
                f"seq={q} does not compose with a 2D degree "
                f"{self.degree!r}: the KV ring is a 1D ring over the "
                f"layer's model group")


# JSON field names = dataclass field names; anything else is rejected.
@dataclass(frozen=True)
class ParallelPlan:
    """Frozen, JSON-serializable parallelism plan.

    ``layers`` is the per-layer strategy list (its length must match the
    model's ``num_layers`` — checked against a config by
    :meth:`validate_for`).  ``mesh_shape``/``mesh_axes`` optionally pin
    the device mesh the plan was made for (``()`` = resolve at launch);
    the remaining fields are the knobs that used to travel as loose
    arguments through the trainer/serving/launch stack.
    """
    layers: Tuple[LayerStrategy, ...]
    mesh_shape: Tuple[int, ...] = ()
    mesh_axes: Tuple[str, ...] = ()
    tmp_layout: str = "auto"
    pp: int = 1
    virtual_stages: int = 1
    split: int = 2
    microbatch: int = 0
    decode_micro: int = 0
    zero1: bool = True
    grad_compress: bool = False
    seq_parallel: bool = False
    seq_shard: int = 1

    def __post_init__(self):
        layers = tuple(
            ls if isinstance(ls, LayerStrategy) else LayerStrategy(*ls)
            for ls in self.layers)
        if not layers:
            raise ValueError("a ParallelPlan needs at least one layer "
                             "strategy")
        object.__setattr__(self, "layers", layers)
        object.__setattr__(self, "mesh_shape",
                           tuple(int(s) for s in self.mesh_shape))
        object.__setattr__(self, "mesh_axes",
                           tuple(str(a) for a in self.mesh_axes))
        if len(self.mesh_shape) != len(self.mesh_axes):
            raise ValueError(
                f"mesh_shape {self.mesh_shape} and mesh_axes "
                f"{self.mesh_axes} must have matching lengths")
        if any(s <= 0 for s in self.mesh_shape):
            raise ValueError(f"bad mesh_shape {self.mesh_shape}: "
                             f"components must be positive")
        if self.tmp_layout not in TMP_LAYOUTS:
            raise ValueError(
                f"unknown tmp_layout {self.tmp_layout!r}: valid layouts "
                f"are {', '.join(TMP_LAYOUTS)}")
        for field, lo in (("pp", 1), ("virtual_stages", 1), ("split", 1),
                          ("microbatch", 0), ("decode_micro", 0),
                          ("seq_shard", 1)):
            v = getattr(self, field)
            if not isinstance(v, int) or isinstance(v, bool) or v < lo:
                raise ValueError(f"bad {field} {v!r}: expected int >= {lo}")
        if self.seq_shard & (self.seq_shard - 1):
            raise ValueError(f"bad seq_shard {self.seq_shard!r}: expected "
                             f"a power of two")
        if self.pp > 1 and (self.seq_shard > 1 or self.has_seq_layers):
            raise ValueError(
                "ring-attention sequence sharding does not compose with "
                "pipeline parallelism yet (stage boundaries ship full "
                "sequences)")
        if self.pp > 1 and self.is_mixed:
            raise ValueError(
                "per-layer mixed (degree, schedule) strategies do not "
                "compose with pipeline parallelism yet — a pp > 1 plan "
                "must use one uniform strategy (stage-internal TMP is "
                "uniform per stage)")

    # ---- views -----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def schedules(self) -> Tuple[str, ...]:
        return tuple(ls.schedule for ls in self.layers)

    @property
    def degrees(self) -> Tuple[Degree, ...]:
        return tuple(ls.degree for ls in self.layers)

    @property
    def seqs(self) -> Tuple[int, ...]:
        return tuple(ls.seq for ls in self.layers)

    @property
    def has_seq_layers(self) -> bool:
        return any(ls.seq > 1 for ls in self.layers)

    @property
    def planned_seqs(self) -> Optional[Tuple[int, ...]]:
        """Per-layer ring-attention seq shards when any layer pins one;
        None for an all-head-sharded plan."""
        return self.seqs if self.has_seq_layers else None

    @property
    def is_mixed(self) -> bool:
        """True when any two layers differ in (degree, schedule, seq)."""
        return len({(ls.degree, ls.schedule, ls.seq)
                    for ls in self.layers}) > 1

    @property
    def uniform_schedule(self) -> Optional[str]:
        s = {ls.schedule for ls in self.layers}
        return next(iter(s)) if len(s) == 1 else None

    @property
    def primary_schedule(self) -> str:
        """The schedule a single-schedule consumer (decode, hp.schedule)
        should run: the uniform schedule, else 'fused' if any layer is
        fused (the only schedule that changes decode's collectives), else
        the first layer's.  All schedules are numerically identical, so
        this only affects overlap, never tokens."""
        u = self.uniform_schedule
        if u is not None:
            return u
        return "fused" if "fused" in self.schedules \
            else self.layers[0].schedule

    @property
    def planned_degrees(self) -> Optional[Tuple[Degree, ...]]:
        """Per-layer degrees when any layer pins one; None for a fully
        mesh-following plan (the uniform stacked layout)."""
        if all(ls.degree is None for ls in self.layers):
            return None
        return self.degrees

    def grouping_signature(self) -> Tuple:
        """What determines the parameter-tree layout this plan trains
        under: grouped (mixed strategies / pinned degrees) vs stacked,
        and the stage stacking.  Checkpoint restores compare signatures
        to decide whether a cross-plan relayout is needed
        (models/params.py::relayout_flat)."""
        if self.is_mixed or self.planned_degrees is not None \
                or self.has_seq_layers:
            if self.has_seq_layers:
                return ("grouped", tuple((ls.degree, ls.schedule, ls.seq)
                                         for ls in self.layers))
            # seq-free plans keep the historical 2-tuple entries so old
            # checkpoint manifests keep matching
            return ("grouped", tuple((ls.degree, ls.schedule)
                                     for ls in self.layers))
        if self.seq_shard > 1:
            return ("stacked", self.pp, 1, self.seq_shard)
        return ("stacked", self.pp, self.virtual_stages if self.pp > 1
                else 1)

    def summary(self) -> str:
        runs: list = []
        for ls in self.layers:
            key = (ls.degree, ls.schedule, ls.seq)
            if runs and runs[-1][0] == key:
                runs[-1][1] += 1
            else:
                runs.append([key, 1])

        def _deg(d):
            if d is None:
                return "mesh"
            if isinstance(d, tuple):
                return f"{d[0]}x{d[1]}"
            return str(d)

        body = " + ".join(
            f"[{_deg(d)}/{s}{f'/seq{q}' if q > 1 else ''}]*{n}"
            for (d, s, q), n in runs)
        if self.seq_shard > 1:
            body += f" seq_shard={self.seq_shard}"
        pp = f" pp={self.pp}x{self.virtual_stages}v" if self.pp > 1 else ""
        mesh = (f" mesh={'x'.join(map(str, self.mesh_shape))}"
                if self.mesh_shape else "")
        return f"plan<{body}{pp}{mesh}>"

    # ---- hparams bridge --------------------------------------------------
    def apply(self, hp):
        """Project this plan onto a TrainHParams (the runtime carrier of
        non-parallelism knobs): schedule/layout/split/microbatch/... come
        from the plan, everything else (lr, remat, steps) from ``hp``."""
        return dataclasses.replace(
            hp, schedule=self.primary_schedule, tmp_layout=self.tmp_layout,
            split=self.split, microbatch=self.microbatch,
            virtual_stages=self.virtual_stages, zero1=self.zero1,
            grad_compress=self.grad_compress,
            seq_parallel=self.seq_parallel, seq_shard=self.seq_shard)

    @classmethod
    def from_hparams(cls, hp, num_layers: int, *,
                     degrees: Optional[Sequence[Degree]] = None,
                     schedules: Optional[Sequence[str]] = None,
                     seqs: Optional[Sequence[int]] = None,
                     mesh_shape: Sequence[int] = (),
                     mesh_axes: Sequence[str] = (),
                     pp: int = 1,
                     decode_micro: int = 0) -> "ParallelPlan":
        """Desugar legacy (hp, degrees) threading into a plan — the one
        place the scattered knobs become a ParallelPlan."""
        for what, per in (("degrees", degrees), ("schedules", schedules),
                          ("seqs", seqs)):
            if per is not None and len(per) != num_layers:
                raise ValueError(
                    f"per-layer {what} have {len(per)} entries for a "
                    f"{num_layers}-layer model")
        degs = list(degrees) if degrees is not None else [None] * num_layers
        scheds = (list(schedules) if schedules is not None
                  else [hp.schedule] * num_layers)
        sqs = list(seqs) if seqs is not None else [1] * num_layers
        return cls(
            layers=tuple(LayerStrategy(d, s, q)
                         for d, s, q in zip(degs, scheds, sqs)),
            mesh_shape=tuple(mesh_shape), mesh_axes=tuple(mesh_axes),
            tmp_layout=hp.tmp_layout, pp=max(pp, 1),
            virtual_stages=max(hp.virtual_stages, 1),
            split=max(hp.split, 1), microbatch=hp.microbatch,
            decode_micro=decode_micro, zero1=hp.zero1,
            grad_compress=hp.grad_compress, seq_parallel=hp.seq_parallel,
            seq_shard=getattr(hp, "seq_shard", 1))

    def validate_for(self, cfg) -> "ParallelPlan":
        """Check the plan against an ArchConfig (layer count)."""
        if len(self.layers) != cfg.num_layers:
            raise ValueError(
                f"plan has {len(self.layers)} layer strategies but "
                f"{cfg.name} has {cfg.num_layers} layers")
        return self

    # ---- JSON ------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self)}
        # layers serialize as [degree, schedule] and only grow the third
        # element when a layer is seq-sharded, so seq-free plan files stay
        # byte-identical to what older readers expect
        d["layers"] = [
            [list(ls.degree) if isinstance(ls.degree, tuple)
             else ls.degree, ls.schedule] + ([ls.seq] if ls.seq > 1 else [])
            for ls in self.layers]
        if self.seq_shard == 1:
            d.pop("seq_shard")
        d["mesh_shape"] = list(self.mesh_shape)
        d["mesh_axes"] = list(self.mesh_axes)
        return d

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ParallelPlan":
        if not isinstance(d, dict):
            raise ValueError(
                f"a plan payload must be a JSON object, got "
                f"{type(d).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown plan field(s) {sorted(unknown)}: known fields "
                f"are {sorted(known)} (is this file really a "
                f"ParallelPlan JSON?)")
        if "layers" not in d:
            raise ValueError("plan payload missing required field "
                             "'layers'")
        kw = dict(d)
        layers = kw.pop("layers")
        if not isinstance(layers, (list, tuple)):
            raise ValueError(f"plan 'layers' must be a list, got "
                             f"{type(layers).__name__}")
        parsed = []
        for i, ls in enumerate(layers):
            if isinstance(ls, dict):
                extra = set(ls) - {"degree", "schedule", "seq"}
                if extra:
                    raise ValueError(
                        f"layer {i}: unknown strategy field(s) "
                        f"{sorted(extra)}")
                parsed.append(LayerStrategy(ls.get("degree"),
                                            ls.get("schedule", "oases"),
                                            ls.get("seq", 1)))
            elif isinstance(ls, (list, tuple)) and len(ls) in (2, 3):
                try:
                    parsed.append(LayerStrategy(
                        tuple(ls[0]) if isinstance(ls[0], list) else ls[0],
                        ls[1], ls[2] if len(ls) == 3 else 1))
                except (ValueError, TypeError) as e:
                    raise ValueError(f"layer {i}: {e}") from None
            else:
                raise ValueError(
                    f"layer {i}: expected [degree, schedule] or "
                    f"[degree, schedule, seq] (degree = null | int | "
                    f"[dx, dy]), got {ls!r}")
        return cls(layers=tuple(parsed), **kw)

    @classmethod
    def from_json(cls, text: str) -> "ParallelPlan":
        try:
            payload = json.loads(text)
        except json.JSONDecodeError as e:
            raise ValueError(f"malformed plan JSON: {e}") from None
        return cls.from_dict(payload)

    # ---- files -----------------------------------------------------------
    def save(self, path: str) -> str:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ParallelPlan":
        with open(path) as f:
            return cls.from_json(f.read())
