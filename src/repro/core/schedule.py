"""The four TMP training schedules (paper §3, Fig. 3, Alg. 1–2).

All schedules are expressed as *program structure*; on TPU the XLA
latency-hiding scheduler turns the admitted independence into actual
comm/compute overlap (DESIGN.md §2):

* ``megatron`` — Fig. 3a: one batch, strictly sequential blocked AllReduce.
* ``wang``     — Wang et al. [53]: decompose each row-parallel matmul into
  chunks so chunk i's AllReduce overlaps chunk i+1's matmul (intra-op).
* ``merak``    — Fig. 3b: two sub-batches pipelined, but pass barriers remain
  (emulated with an optimization_barrier on layer gradients) and
  recomputation re-executes collectives (coarse remat).
* ``oases``    — Fig. 3c/d: two sub-batches, cross-pass (barrier-free; the
  transposed backward interleaves recompute and backward the same way), and
  with ``fine_remat`` the recompute contains no collectives at all.
* ``fused``    — beyond-paper: kernel-level collective matmul
  (:mod:`repro.kernels.collective_matmul`).  Each TMP collective is a ring
  streamed through its producing/consuming matmul, so every ring step's
  transfer overlaps the next tile's compute by construction — no scheduler
  heuristics involved.  ``use_pallas=True`` swaps the ``lax.ppermute`` ring
  for the in-kernel RDMA Pallas version on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import tmp as tmpc
from repro.core.axes import MeshInfo
from repro.obs.tracing import phase_scope

SCHEDULES = ("megatron", "wang", "merak", "oases", "fused")


@dataclass(frozen=True)
class TmpCtx:
    """Per-layer TMP context: axes + communication scheme.

    ``seq_parallel`` (beyond-paper, Megatron-SP): activations between TMP
    blocks are sharded along the sequence dim; the block entry all-gathers
    and the block exit reduce-scatters (same link bytes as the AllReduce,
    but rematerialization residuals shrink by tp — see EXPERIMENTS §Perf).

    ``seq_shard`` > 1 (beyond-paper, ring attention — DESIGN.md §12): the
    attention parts keep activations sequence-sharded *through* the mixer
    instead of gathering at the block entry.  Attention weights are
    replicated over the model group (full heads per device) and the KV
    shards circulate around the TMP ring
    (:mod:`repro.kernels.ring_attention`); the MLP/recurrent parts still
    run Megatron-SP.  Requires ``seq_parallel=True`` and
    ``seq_shard == tp_total``.

    ``layout`` selects the partition dimensionality.  ``"auto"`` follows the
    mesh/degree (a ``model_y`` axis or tuple degree activates the 2D hybrid
    layout); ``"1d"`` forces the classic layout, treating a multi-axis model
    group as one flattened ring group.  In 2D, weight *width* shards over
    the x-axes and the *contraction* dim (d_model) over the y-axes; the
    row/gather matmuls decompose into per-axis collectives (fused: per-axis
    rings) — see DESIGN.md §2D hybrid partition.
    """
    info: MeshInfo
    degree: Optional[object] = None   # None | int | (dx, dy)
    schedule: str = "oases"
    wang_chunks: int = 4
    use_pallas: bool = False
    seq_parallel: bool = False
    seq_shard: int = 1                # ring-attention seq shards (1 = off)
    layout: str = "auto"              # auto | 1d | 2d

    def _axes_xy(self) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        if self.layout == "1d":
            from repro.core.axes import deg_total
            return self.info.tp_axes(deg_total(self.degree)), ()
        return self.info.xy_axes(self.degree)

    @property
    def x_axes(self) -> Tuple[str, ...]:
        return self._axes_xy()[0]

    @property
    def y_axes(self) -> Tuple[str, ...]:
        return self._axes_xy()[1]

    @property
    def is_2d(self) -> bool:
        return bool(self.y_axes)

    @property
    def tp_axes(self) -> Tuple[str, ...]:
        ax, ay = self._axes_xy()
        return ax + ay

    def _size(self, axes: Tuple[str, ...]) -> int:
        import math
        s = dict(self.info.mesh.shape)
        return math.prod(s[a] for a in axes) if axes else 1

    @property
    def tp(self) -> int:
        """The *width*-sharding degree (heads / d_ff divide by this) — dx in
        2D, the whole group in 1D."""
        return self._size(self.x_axes)

    @property
    def tp_y(self) -> int:
        return self._size(self.y_axes)

    @property
    def tp_total(self) -> int:
        return self._size(self.tp_axes)

    def reduce(self, x, seq_dim: int = 1):
        if self.seq_parallel and self.tp_axes:
            from jax.ad_checkpoint import checkpoint_name
            y = tmpc.sp_reduce_scatter(x, self.tp_axes, seq_dim)
            return checkpoint_name(y, tmpc.COLLECTIVE_NAME)
        return tmpc.tmp_reduce(x, self.tp_axes)

    def gather_seq(self, x, seq_dim: int = 1):
        """Block entry in SP mode: reassemble the full sequence."""
        if self.seq_parallel and self.tp_axes:
            return tmpc.sp_all_gather(x, self.tp_axes, seq_dim)
        return x

    def shard_seq(self, x, seq_dim: int = 1):
        """Slice a replicated tensor down to this shard's sequence chunk
        (used where the block had no trailing collective)."""
        if self.seq_parallel and self.tp_axes:
            return tmpc.batch_split(x, self.tp_axes, seq_dim)
        return x

    def proj(self, x, w):
        """Column-parallel entry projection ``x @ w``.

        1D: a plain local dot (w's contraction dim is replicated).  2D: w's
        contraction dim is y-sharded — slice x's matching chunk locally
        (free: x is replicated over y) and AllReduce the partial products
        over the y-axes.  In fused mode the psum becomes a collective-matmul
        ring so the y-transfers hide under the tile matmuls.  Detection is
        shape-driven so per-weight divisibility fallbacks (replicated specs)
        compose: a full-row weight always takes the plain-dot path.
        """
        with phase_scope(f"tmp.{self.schedule}.proj"):
            if self.y_axes and w.shape[0] != x.shape[-1]:
                from jax.ad_checkpoint import checkpoint_name
                xy = tmpc.batch_split(x, self.y_axes, x.ndim - 1)
                if self.schedule == "fused" and xy.ndim >= 2:
                    from repro.kernels import collective_matmul as cm
                    y = cm.fused_matmul_allreduce(
                        xy, w, self.y_axes,
                        scatter_dim=self._ring_dim(xy, min(1, xy.ndim - 2),
                                                   self.y_axes),
                        use_pallas=self.use_pallas)
                    return checkpoint_name(y, tmpc.COLLECTIVE_NAME)
                return tmpc.tmp_reduce(jnp.dot(xy, w), self.y_axes)
            return jnp.dot(x, w)

    def contract_reduce(self, t, partial: bool = True):
        """Finish a y-contracted product computed outside :meth:`proj`
        (e.g. the sliced-kv einsum in blocks._qkv): AllReduce over y."""
        if partial and self.y_axes:
            return tmpc.tmp_reduce(t, self.y_axes)
        return t

    def contract_slice(self, x, w_rows: int):
        """x's local chunk of a y-sharded contraction dim (``w_rows`` = the
        weight's leading dim); identity when the weight has full rows."""
        if self.y_axes and w_rows != x.shape[-1]:
            return tmpc.batch_split(x, self.y_axes, x.ndim - 1), True
        return x, False

    def _ring_dim(self, x, preferred: int, axes: Tuple[str, ...]) -> int:
        """Chunking dim for the fused all-reduce rings.

        Training activations ring over the sequence dim; at decode shapes
        the sequence dim is 1 (a single token), so the ring would silently
        fall back to the blocking reference.  The all-reduce flavour is free
        to chunk along ANY non-contraction dim (the output is replicated
        either way), so when the preferred dim has collapsed to 1 we stream
        the ring over the slot-batch dim instead — this is what keeps
        ``schedule="fused"`` overlapping at batch-1 decode shapes.  Dims
        that the group size does not divide are left to the kernel's own
        reference fallback.
        """
        if x.shape[preferred] != 1:
            return preferred
        n = self._size(axes)
        for dim in range(x.ndim - 1):
            if dim != preferred and n > 1 and x.shape[dim] % n == 0:
                return dim
        return preferred

    def row_matmul(self, x, w, seq_dim: int = 1, full_out: Optional[int] = None):
        """x [..., K_local] @ w [K_local, D] followed by AllReduce (or
        reduce-scatter in SP mode).

        'wang' decomposes along the second-to-last dim so the chunked
        AllReduces pipeline against the remaining chunk matmuls; 'fused'
        goes one level further and streams the matmul tiles through a ring
        collective kernel (guaranteed overlap).  The AllReduce flavour
        falls back to the blocking reference for indivisible shapes /
        multi-axis groups; the SP reduce-scatter flavour requires the seq
        dim divisible by the group (guaranteed by the SP gate in
        models/lm.py, which only enables SP when seq % tp == 0).

        2D layout: the collective decomposes per axis — AllReduce the
        partial sums over the x-axes (K is x-sharded), then all-gather the
        y-sharded output columns back to ``full_out`` when the exit weight
        shards them.  Both collective outputs are checkpoint-named so the
        fine-remat recompute stays collective-free (§3.2).
        """
        with phase_scope(f"tmp.{self.schedule}.row_matmul"):
            if self.y_axes:
                from jax.ad_checkpoint import checkpoint_name
                if self.schedule == "fused" and self.x_axes and x.ndim >= 2:
                    from repro.kernels import collective_matmul as cm
                    y = cm.fused_matmul_allreduce(
                        x, w, self.x_axes,
                        scatter_dim=self._ring_dim(
                            x, min(seq_dim, x.ndim - 2), self.x_axes),
                        use_pallas=self.use_pallas)
                    y = checkpoint_name(y, tmpc.COLLECTIVE_NAME)
                else:
                    y = tmpc.tmp_reduce(jnp.dot(x, w), self.x_axes)
                if full_out is not None and w.shape[-1] != full_out:
                    y = checkpoint_name(
                        tmpc.sp_all_gather(y, self.y_axes, y.ndim - 1),
                        tmpc.COLLECTIVE_NAME)
                return y
            if self.schedule == "fused" and self.tp_axes and x.ndim >= 2:
                from jax.ad_checkpoint import checkpoint_name
                from repro.kernels import collective_matmul as cm
                if self.seq_parallel:
                    y = cm.fused_matmul_reducescatter(
                        x, w, self.tp_axes, seq_dim, self.use_pallas)
                else:
                    y = cm.fused_matmul_allreduce(
                        x, w, self.tp_axes,
                        scatter_dim=self._ring_dim(
                            x, min(seq_dim, x.ndim - 2), self.tp_axes),
                        use_pallas=self.use_pallas)
                return checkpoint_name(y, tmpc.COLLECTIVE_NAME)
            if self.schedule == "wang" and not self.seq_parallel \
                    and x.ndim >= 2:
                n = self.wang_chunks
                dim = x.ndim - 2
                if x.shape[dim] % n == 0 and x.shape[dim] >= n:
                    chunks = jnp.split(x, n, axis=dim)
                    outs = [self.reduce(jnp.dot(c, w)) for c in chunks]
                    return jnp.concatenate(outs, axis=dim)
            return self.reduce(jnp.dot(x, w))

    def gather_matmul(self, x, ws, seq_dim: int = 1):
        """Column-parallel block entry: project ``x`` with every weight in
        ``ws`` (wq/wk/wv or wg/wu), gathering the sequence first in SP mode.

        In fused+SP mode one all-gather ring feeds all the matmuls,
        consuming shards as they arrive; otherwise gather once (SP) or
        not at all and apply plain dots.  2D: each weight's y-sharded
        contraction runs through :meth:`proj` (slice + per-axis ring).
        """
        ws = tuple(ws)
        with phase_scope(f"tmp.{self.schedule}.gather_matmul"):
            if self.y_axes:
                return tuple(self.proj(x, w) for w in ws)
            if self.schedule == "fused" and self.seq_parallel \
                    and self.tp_axes:
                from repro.kernels import collective_matmul as cm
                return cm.fused_allgather_matmul(x, ws, self.tp_axes,
                                                 seq_dim, self.use_pallas)
            h = self.gather_seq(x, seq_dim)
            return tuple(jnp.dot(h, w) for w in ws)


def split_tree(tree, split: int):
    """Split the leading (batch) dim of every leaf into `split` sub-batches."""
    def get(i):
        return jax.tree_util.tree_map(
            lambda t: t[i * (t.shape[0] // split):(i + 1) * (t.shape[0] // split)],
            tree)
    return [get(i) for i in range(split)]


def merge_tree(subs):
    return jax.tree_util.tree_map(
        lambda *ts: jnp.concatenate(ts, axis=0), *subs)


def effective_split(schedule: str, split: int, local_batch: int) -> int:
    """Sub-batch split factor: oases/merak split (paper: 2) when divisible.
    'fused' overlaps intra-op (inside the kernel), so like megatron/wang it
    runs the full batch in one pass."""
    if schedule not in SCHEDULES:
        # defense in depth: TrainHParams/ParallelPlan validate at
        # construction, but raw strings can still arrive here
        from repro.core.plan import validate_schedule
        validate_schedule(schedule)
    if schedule in ("megatron", "wang", "fused"):
        return 1
    s = min(split, local_batch)
    while s > 1 and local_batch % s:
        s -= 1
    return max(s, 1)


def apply_layer(parts: Sequence[Callable], p, xs: List, auxs: List,
                schedule: str):
    """Run one layer's residual parts over the sub-batches.

    Program order = Alg. 1: for each part, emit (compute_j, collective_j) for
    every sub-batch j before the residual adds, so collective_j is independent
    of compute_{j+1} — the overlap window.  Returns (xs, aux_scalar).
    """
    aux_total = jnp.float32(0.0)
    for part in parts:
        deltas = []
        for j, (x, a) in enumerate(zip(xs, auxs)):
            # sub-batch scope: Alg. 1's (compute_j, collective_j) chunks
            # are attributable per sub-batch in XLA profiles
            with phase_scope(f"tmp.{schedule}.sub{j}"):
                d, aux = part(p, x, a)
            deltas.append(d)
            aux_total = aux_total + aux
        xs = [x + d for x, d in zip(xs, deltas)]
    if schedule == "merak":
        xs = [tmpc.pass_barrier(x) for x in xs]
    return xs, aux_total
