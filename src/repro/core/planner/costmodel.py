"""Oases planner cost model (paper §4.2), adapted to TPU roofline terms.

The model graph is blocks = (computation sequence, trailing collective) —
for a transformer layer that is [attn-block, mlp-block].  For each block and
each candidate TMP degree n ∈ {2,4,8,16} (powers of two, paper §4.2) we
compute:

* d(F), d(B)   — per-sub-batch compute seconds (bwd ≈ 2x fwd + recompute),
* c(F), c(B)   — per-sub-batch AllReduce seconds, volume 2K(n-1)/n (paper
                 §4 observation i), K = per-chip activation bytes; with
                 coarse remat the *recompute* collectives are added to c(B)
                 — this is how the planner "models the overlapping schedule"
                 (fine-grained recomputation removes them, §3.2),
* m_s, m_t, m_r — Eq. 6 memory terms (param+optimizer state, saved tensors,
                 backward runtime), per chip.

Eq. 3 node costs use max{compute, comm} overlap; Eq. 4 edge costs charge the
batch-resharding AllGather between degree groups plus the overlap destroyed
by that blocking gather.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import (ArchConfig, CROSS_ATTN, GLOBAL_ATTN,
                                LOCAL_ATTN, RGLRU, SSD, ShapeConfig,
                                TrainHParams)


@dataclass(frozen=True)
class HWConfig:
    n_chips: int = 256
    peak_flops: float = 197e12       # bf16
    hbm_bw: float = 819e9
    link_bw: float = 50e9
    hbm_cap: float = 16e9
    mxu_base_eff: float = 0.6        # achievable fraction at healthy shapes
    bytes_act: int = 2               # bf16 activations
    # calibration scale (CPU measurements use different constants)
    comm_latency: float = 5e-6       # per-collective latency floor
    # ---- heterogeneous (per-axis) bandwidth terms, AMP-style ----
    # The commodity-server regime: fast intra-node lanes (NVLink/ICI class)
    # carry the x-axis rings, the thin inter-node NIC carries the y-axis.
    # 0 means "fall back to the uniform link_bw" so every existing caller
    # keeps its single-bandwidth behaviour.
    link_bw_x: float = 0.0           # intra-node (x-axis ring) bytes/s
    link_bw_y: float = 0.0           # inter-node (y-axis ring) bytes/s
    node_size: int = 0               # chips per fast-interconnect node
    # per-hop latency of an inter-node (NIC) crossing; 0 -> comm_latency.
    # Only the decode/serving latency model reads this (training payloads
    # are bandwidth-bound, so the per-hop split would be noise there).
    comm_latency_y: float = 0.0

    @property
    def bw_x(self) -> float:
        return self.link_bw_x or self.link_bw

    @property
    def bw_y(self) -> float:
        return self.link_bw_y or self.link_bw

    @property
    def lat_y(self) -> float:
        return self.comm_latency_y or self.comm_latency

    def ring_bw(self, degree: int) -> float:
        """Effective per-hop bandwidth of a ring over ``degree`` chips: a
        ring confined to one node runs at the intra-node rate; a ring that
        spans nodes is bottlenecked by the slowest (inter-node) hop."""
        ns = self.node_size or self.n_chips
        return self.bw_x if degree <= ns else self.bw_y

    def collective_latency(self, degree: int) -> float:
        """Critical-path latency of one all-reduce over ``degree`` chips at
        decode payloads (bandwidth ~free, hops everything).  Intra-node
        segments ride a switched fabric — log2 depth per phase — while
        every node-boundary crossing pays a full inter-node hop, twice
        (reduce-scatter + all-gather phases)."""
        if degree <= 1:
            return 0.0
        ns = self.node_size or self.n_chips
        intra = 2.0 * self.comm_latency * math.ceil(
            math.log2(min(degree, ns)))
        if degree <= ns:
            return intra
        crossings = math.ceil(degree / ns)
        return intra + 2.0 * crossings * self.lat_y

    def degrade(self, *, n_chips: Optional[int] = None,
                lost_chips: int = 0,
                link_bw_y: Optional[float] = None,
                link_bw_x: Optional[float] = None,
                node_size: Optional[int] = None,
                bw_scale: float = 1.0) -> "HWConfig":
        """The surviving-topology view of this cluster after a fault —
        what the elastic supervisor hands back to :func:`ilp.replan` when
        a host drops or a link degrades (AMP-style heterogeneity
        awareness: replan against *measured* health, not the spec sheet).

        * ``n_chips``/``lost_chips`` — surviving device count (clamped to
          >= 1; ``node_size`` is re-clamped so a partial node never claims
          more chips than survive);
        * ``link_bw_y``/``link_bw_x`` — measured per-link bandwidth
          overrides (a degraded NIC reports its *actual* rate);
        * ``bw_scale`` — uniform multiplier on every link term (straggler
          escalation: the whole collective runs at the slow peer's pace).
        """
        import dataclasses
        n = int(n_chips) if n_chips is not None \
            else self.n_chips - int(lost_chips)
        n = max(n, 1)
        ns = int(node_size) if node_size is not None else self.node_size
        fields: Dict[str, object] = {
            "n_chips": n, "node_size": min(ns, n) if ns else 0}
        if link_bw_y is not None:
            fields["link_bw_y"] = max(float(link_bw_y), 1.0)
        if link_bw_x is not None:
            fields["link_bw_x"] = max(float(link_bw_x), 1.0)
        hw = dataclasses.replace(self, **fields)
        if bw_scale != 1.0:
            s = max(float(bw_scale), 1e-6)
            hw = dataclasses.replace(
                hw, link_bw=hw.link_bw * s,
                link_bw_x=hw.link_bw_x * s, link_bw_y=hw.link_bw_y * s)
        return hw

    @classmethod
    def measure_fields(cls, *, max_devices: int = 8,
                       matmul_dim: int = 1024, ring_bytes: int = 1 << 22,
                       repeats: int = 5) -> Dict[str, float]:
        """The raw micro-bench measurements behind
        :meth:`from_measurements`, as a plain field dict — this is what
        :mod:`repro.core.planner.calibrate` persists per host, so caller
        ``overrides`` can be applied on top of a cache hit without
        re-profiling."""
        import time as _time

        import jax
        import jax.numpy as jnp

        devs = jax.devices()[:max_devices]

        def _best(fn, *args):
            # block the warm-up: under async dispatch an un-synced warm-up
            # call queues its compute ahead of the first timed repeat and
            # inflates it (the min-of-repeats only partially forgives this
            # on short kernels)
            jax.block_until_ready(fn(*args))    # compile + warm, synced
            best = float("inf")
            for _ in range(max(repeats, 1)):
                t0 = _time.perf_counter()
                jax.block_until_ready(fn(*args))
                best = min(best, _time.perf_counter() - t0)
            return best

        d = matmul_dim
        x = jnp.ones((d, d), jnp.float32)
        t_mm = _best(jax.jit(lambda a: a @ a), x)
        flops = 2.0 * d * d * d / max(t_mm, 1e-9)

        big = jnp.ones((1 << 22,), jnp.float32)
        t_cp = _best(jax.jit(lambda a: a * 2.0 + 1.0), big)
        hbm = 2.0 * big.size * 4 / max(t_cp, 1e-9)      # read + write

        fields = dict(n_chips=len(devs), peak_flops=flops, hbm_bw=hbm,
                      mxu_base_eff=1.0, node_size=len(devs))
        if len(devs) > 1:
            from jax.sharding import PartitionSpec as P

            from repro.core import compat
            n = len(devs)
            mesh = compat.make_mesh((n,), ("ring",),
                                    axis_types=compat.auto_axis_types(1))
            elems = max(ring_bytes // 4, n)
            arr = jnp.ones((elems // n * n,), jnp.float32)
            f = compat.shard_map(lambda a: jax.lax.psum(a, ("ring",)),
                                 mesh=mesh, in_specs=P("ring"),
                                 out_specs=P("ring"))
            with compat.set_mesh(mesh):
                t_ar = _best(jax.jit(f), arr)
            # each chip holds a 1/n shard of the input (in_specs=P("ring"))
            # and a ring AllReduce moves 2(n-1)/n of ITS payload
            bw = (arr.size * 4 / n) * 2.0 * (n - 1) / n / max(t_ar, 1e-9)
            fields.update(link_bw=bw, link_bw_x=bw, link_bw_y=bw)
        return fields

    @classmethod
    def from_measurements(cls, *, max_devices: int = 8,
                          matmul_dim: int = 1024, ring_bytes: int = 1 << 22,
                          repeats: int = 5, **overrides) -> "HWConfig":
        """Profile-guided calibration: short on-device micro-benches fill
        the roofline terms this model otherwise takes on faith —

        * a square matmul for ``peak_flops`` (achievable, so
          ``mxu_base_eff`` is folded in and reset to 1.0),
        * a large elementwise op for ``hbm_bw``,
        * a ring AllReduce over the local devices for ``link_bw`` (and the
          per-axis ``link_bw_x``/``link_bw_y`` defaults; single-device
          hosts keep the configured link numbers).

        Keyword ``overrides`` win over measurements — calibrate the chip,
        keep the cluster description (``node_size``, ``link_bw_y``...).
        This is the DEFAULT planner path of the launchers (``train.py``,
        ``dryrun.py``, ``examples/planner_demo.py``; ``--no-calibrate``
        restores the stock chip numbers); the per-host result cache lives
        in :func:`repro.core.planner.calibrate.calibrated_hw`.
        """
        fields = cls.measure_fields(max_devices=max_devices,
                                    matmul_dim=matmul_dim,
                                    ring_bytes=ring_bytes, repeats=repeats)
        fields.update(overrides)
        # a cluster-description override may shrink n_chips below the
        # measured local node: never claim a node larger than the cluster
        if fields.get("node_size") and fields.get("n_chips"):
            fields["node_size"] = min(int(fields["node_size"]),
                                      int(fields["n_chips"]))
        return cls(**fields)


V5E = HWConfig()

# Golden-fixture HWConfigs (tests/test_planner_golden.py pins the plans
# these produce so cost-model edits that silently flip Table-6-style
# decisions fail loudly).
#
# * COMMODITY_25GBE — two 8-GPU boxes joined by 25 GbE (~3.1 GB/s): the
#   paper's commodity-server regime.  1D rings spanning both boxes crawl at
#   NIC speed; the 2D hybrid keeps the wide x-ring on PCIe/NVLink-class
#   intra-node lanes and sends only the thin y-traffic across.
# * NVLINK_BOX — a single 16-GPU NVLink-class box: uniform fast links, so
#   the 2D split buys nothing and the planner should stay effectively 1D.
COMMODITY_25GBE = HWConfig(
    n_chips=16, node_size=8, peak_flops=125e12, hbm_bw=1008e9,
    link_bw=3.1e9, link_bw_x=120e9, link_bw_y=3.1e9, hbm_cap=24e9,
    comm_latency_y=30e-6)
NVLINK_BOX = HWConfig(
    n_chips=16, node_size=16, peak_flops=125e12, hbm_bw=1008e9,
    link_bw=250e9, hbm_cap=24e9)


def _dxy(degree) -> Tuple[int, int]:
    """(dx, dy) view of a planner degree; ints are (n, 1)."""
    if isinstance(degree, (tuple, list)):
        return int(degree[0]), int(degree[1])
    return int(degree), 1


def _dtot(degree) -> int:
    dx, dy = _dxy(degree)
    return dx * dy


def _dkey(degree):
    """Hashable canonical form: int for 1D, tuple for 2D."""
    dx, dy = _dxy(degree)
    return dx if dy == 1 else (dx, dy)


def overlapped_time(d: float, c: float, ring_steps: int) -> float:
    """Node cost of a fused collective-matmul block (schedule='fused').

    The kernel streams matmul tiles into a ring collective, so per tile-ring
    the exposed time is ``max(T_comm, T_compute)`` — the slower side fully
    hides the faster — plus one ring step of pipeline fill (the first
    transfer has no prior tile to hide behind).  This is the term that lets
    the planner *choose* fused partitions: comm that a blocking schedule
    charges at ``T_comm + T_compute`` is genuinely free below the compute
    roofline.
    """
    steps = max(ring_steps, 1)
    return max(d, c) + min(d, c) / steps


def overlapped_time_2d(d: float, c_x: float, c_y: float,
                       ring_steps_x: int) -> float:
    """Composed fused cost of a 2D node.

    The x-axis ring overlaps the tile matmuls exactly as in 1D
    (``max(T_comm_x, T_compute)``); the y-axis collectives (entry psums +
    exit gather) then overlap the x-side pipeline fill, so the node pays
    ``max(T_comm_x, T_compute) + max(T_comm_y, fill)``.  Degenerates to
    :func:`overlapped_time` at dy == 1 (c_y == 0)."""
    fill = min(d, c_x) / max(ring_steps_x, 1)
    return max(d, c_x) + max(c_y, fill)


def _mxu_eff(hw: HWConfig, *dims: int) -> float:
    """Efficiency discount for narrow per-chip matmul dims (the paper's
    arithmetic-density caveat, §5.6)."""
    eff = hw.mxu_base_eff
    for d in dims:
        if d < 512:
            eff *= max(d, 16) / 512.0
    return max(eff, 0.02 * hw.mxu_base_eff)


@dataclass
class BlockCost:
    name: str
    flops_fwd: float          # total fwd flops for the whole global batch
    comm_bytes_k: float       # K: per-*replica-group* AllReduce payload bytes
    n_collectives: int        # collectives in this block's forward
    params: int               # parameters in this block
    act_saved: float          # bytes saved for bwd per chip (fine remat)


def _attn_flops(cfg: ArchConfig, tokens: int, seq: int, window=None) -> float:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    proj = 2.0 * tokens * d * (cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd
                               + cfg.num_heads * hd)
    ctx = min(window or seq, seq)
    attn = 2.0 * 2.0 * tokens * ctx * cfg.num_heads * hd
    return proj + attn


def ring_attn_costs(cfg: ArchConfig, blk: BlockCost, shape: ShapeConfig,
                    hp: TrainHParams, hw: HWConfig,
                    options: Sequence) -> NodeCosts:
    """Ring-attention (seq == degree) node costs of an attention block.

    The sequence axis — not the head axis — is sharded over the group:
    every chip holds the FULL attention weights (replicated; their grads
    psum at the shard_map boundary) and 1/n of the sequence.  The block's
    trailing collective disappears (q/k/v/o are all seq-local, ``wo`` is
    replicated), and in its place the KV shard circulates the ring, one
    hop per online-softmax step, each hop issued before the step's block
    compute so the transfer hides under it (kernels/ring_attention.py).
    The exposed time is therefore ``max(T_attn_block, T_kv_ring) + fill``
    — :func:`overlapped_time` with ``n - 1`` ring steps — which the ILP
    consumes as a per-(layer, degree) constant.

    The memory trade this buys (Eq. 6, ring column): saved tensors shrink
    to the seq-local shard — the ``(1 - 1/n)`` gathered-residual saving
    that makes ring win at long context — while the attention weights are
    charged replicated (×n the head-sharded cost; optimizer state still
    ZeRO-shards over dp).  2D degrees and n == 1 are not ring-capable and
    come back as ``inf`` so no consumer can pick them silently.

    Conventions mirror :func:`node_costs`: seconds per iteration (the
    per-slot costs scaled back by micro), memory bytes per chip.
    """
    split = max(hp.split, 1)
    out = NodeCosts([], [], [], [], [], [])
    tokens = shape.global_batch * shape.seq_len
    hd = cfg.resolved_head_dim
    kv_width = 2.0 * cfg.num_kv_heads * hd          # k + v rows per token
    for opt in options:
        dx, dy = _dxy(opt)
        n = dx * dy
        if dy > 1 or n <= 1:
            for lst in (out.d_f, out.c_f, out.d_b, out.c_b,
                        out.mem_s, out.mem_t, out.c_f_y, out.c_b_y):
                lst.append(float("inf"))
            continue
        dp = max(hw.n_chips // n, 1)
        t_chip = tokens / dp
        # same auto-accumulation floor as node_costs: batch rows only
        rows = max(int(shape.global_batch // dp), 1)
        micro = hp.microbatch if hp.microbatch > 0 else \
            min(max(1, int(math.ceil(t_chip / 8192.0))), rows)
        t_live = t_chip / micro
        t_loc = t_live / n                 # seq-local tokens per chip
        # full-width projections on 1/n of the tokens: same flops per chip
        # as head sharding, but the narrow matmul dim is the token axis
        eff = _mxu_eff(hw, cfg.num_heads * hd, int(t_loc // split))
        d_f = blk.flops_fwd / hw.n_chips / (hw.peak_flops * eff) \
            / split / micro
        # KV ring: each chip ships its (k, v) shard n-1 times per pass
        kv_hop = (t_loc / split) * kv_width * hw.bytes_act
        c_f = (n - 1) * (kv_hop / hw.ring_bw(n) + hw.comm_latency)
        d_f *= micro
        c_f *= micro
        recompute = 1.0 if hp.remat else 0.0
        d_b = d_f * (2.0 + recompute)
        # reverse ring rotates the bf16 KV tuple plus f32 (dk, dv) partials
        c_b = c_f * (hw.bytes_act + 4.0) / hw.bytes_act
        zdp = dp if hp.zero1 else 1
        mem_s = blk.params * (2.0 + 12.0 / zdp)
        mem_t = (t_loc * cfg.d_model * hw.bytes_act
                 * (1.5 if hp.fine_remat else 0.5)
                 + 2.0 * t_loc * kv_width * hw.bytes_act)  # 2 in-flight slots
        out.d_f.append(d_f)
        out.c_f.append(c_f)
        out.d_b.append(d_b)
        out.c_b.append(c_b)
        out.mem_s.append(mem_s)
        out.mem_t.append(mem_t)
        out.c_f_y.append(0.0)
        out.c_b_y.append(0.0)
    return out


def _block_costs(cfg: ArchConfig, kind: str, tokens: int, seq: int) -> List[BlockCost]:
    """Blocks for one layer; flops are global-batch totals."""
    d = cfg.d_model
    out = []
    if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else None
        fl = _attn_flops(cfg, tokens, seq, window)
        p = d * cfg.resolved_head_dim * (2 * cfg.num_heads
                                         + 2 * cfg.num_kv_heads)
        out.append(BlockCost("attn", fl, tokens * d, 1, p, 2 * tokens * d))
        if kind == CROSS_ATTN:
            out.append(BlockCost("xattn", fl, tokens * d, 1, p,
                                 2 * tokens * d))
    elif kind == RGLRU:
        w = cfg.rglru_width or d
        fl = 2.0 * tokens * d * 3 * w + 10.0 * tokens * w
        out.append(BlockCost("rglru", fl, tokens * d, 1, 3 * d * w,
                             2 * tokens * d))
    elif kind == SSD:
        d_inner = cfg.ssm_expand * d
        nh = d_inner // cfg.ssm_headdim
        n = cfg.ssm_state
        fl = (2.0 * tokens * d * (3 * d_inner + 2 * n + nh)
              + 2.0 * tokens * d_inner * n * 4)
        out.append(BlockCost("ssd", fl, 0.0, 0, 3 * d * d_inner,
                             2 * tokens * d))
    if kind != SSD and cfg.d_ff:
        if cfg.moe is not None:
            fl = 2.0 * tokens * 3 * d * cfg.d_ff * cfg.moe.top_k
            p = cfg.moe.num_experts * 3 * d * cfg.d_ff
        else:
            fl = 2.0 * tokens * 3 * d * cfg.d_ff
            p = 3 * d * cfg.d_ff
        out.append(BlockCost("mlp", fl, tokens * d, 1, p, 2 * tokens * d))
    return out


def layer_blocks(cfg: ArchConfig, shape: ShapeConfig) -> List[List[BlockCost]]:
    """Per layer: its blocks (the planner's graph nodes), for all layers."""
    tokens = shape.global_batch * shape.seq_len
    pat = cfg.layer_pattern
    return [_block_costs(cfg, pat[i % len(pat)], tokens, shape.seq_len)
            for i in range(cfg.num_layers)]


@dataclass
class NodeCosts:
    """Per (block, degree-option): everything Eq. 3/6 needs (seconds/bytes
    per chip, per sub-batch).  ``c_f``/``c_b`` are the TOTAL collective
    seconds of the option; ``c_f_y``/``c_b_y`` hold the y-axis (inter-node)
    component so 2D-aware consumers can recover the x part as ``c - c_y``
    (both are 0 for 1D options)."""
    d_f: List[float]
    c_f: List[float]
    d_b: List[float]
    c_b: List[float]
    mem_s: List[float]
    mem_t: List[float]
    c_f_y: List[float] = None
    c_b_y: List[float] = None

    def __post_init__(self):
        if self.c_f_y is None:
            self.c_f_y = [0.0] * len(self.c_f)
        if self.c_b_y is None:
            self.c_b_y = [0.0] * len(self.c_b)


def node_costs(cfg: ArchConfig, blk: BlockCost, shape: ShapeConfig,
               hp: TrainHParams, hw: HWConfig,
               options: Sequence) -> NodeCosts:
    """Options may mix int (1D) and ``(dx, dy)`` (2D) degrees.

    1D comm: the block-output AllReduce over the full group, charged at the
    heterogeneity-aware ring bandwidth (a ring spanning nodes crawls at the
    inter-node hop — AMP's observation).  2D comm decomposes per axis: the
    x-ring AllReduces the 1/dy-sized output chunk intra-node; the y-axis
    pays the entry partial-sums plus the exit gather, modelled as a full-K
    AllReduce over dy across the inter-node links.
    """
    split = max(hp.split, 1)
    out = NodeCosts([], [], [], [], [], [])
    tokens = shape.global_batch * shape.seq_len
    for opt in options:
        dx, dy = _dxy(opt)
        n = dx * dy
        dp = max(hw.n_chips // n, 1)
        t_chip = tokens / dp                    # tokens on this chip / iter
        # gradient accumulation bounds live activations (auto ~8k tok/chip)
        # — but it splits BATCH ROWS only, so at long sequence the floor is
        # one full sample per microbatch (the regime where the seq axis /
        # ring attention is the only remaining activation-memory lever)
        rows = max(int(shape.global_batch // dp), 1)
        micro = hp.microbatch if hp.microbatch > 0 else \
            min(max(1, int(math.ceil(t_chip / 8192.0))), rows)
        t_live = t_chip / micro
        # width shards over dx only in 2D (the §5.6 arithmetic-density
        # caveat bites later — one of the 2D layout's selling points)
        width = max(cfg.d_ff, cfg.num_heads * cfg.resolved_head_dim) // dx
        eff = _mxu_eff(hw, width, int(t_live // split))
        d_f = blk.flops_fwd / hw.n_chips / (hw.peak_flops * eff) / split / micro
        # AllReduce of the block output: per-chip payload K(n) (per micro,
        # per sub-batch; the totals below are multiplied back by micro)
        k_bytes = (t_live / split) * (blk.comm_bytes_k / max(tokens, 1)) \
            * hw.bytes_act if blk.comm_bytes_k else 0.0
        ring_x = 2.0 * (dx - 1) / dx if dx > 1 else 0.0
        ring_y = 2.0 * (dy - 1) / dy if dy > 1 else 0.0
        # y rings hop between nodes whenever the whole group spills out of
        # one node; the x ring is judged on its own extent
        bw_y_eff = hw.ring_bw(n) if dy > 1 else hw.bw_y
        c_x = c_y = 0.0
        if blk.n_collectives:
            if dx > 1:
                c_x = (k_bytes / dy) * ring_x / hw.ring_bw(dx) \
                    + hw.comm_latency
            if dy > 1:
                c_y = k_bytes * ring_y / bw_y_eff + hw.comm_latency
        c_f = c_x + c_y
        # NOTE: d/c are per (micro x sub-batch) slot; Eq. 3 sums over slots.
        # Scale both by micro so node costs stay per-iteration.
        d_f *= micro
        c_f *= micro
        c_y *= micro
        # backward: 2x fwd compute (+1x recompute when remat)
        recompute = 1.0 if hp.remat else 0.0
        d_b = d_f * (2.0 + recompute)
        c_b = c_f  # grad-side AllReduce
        c_b_y = c_y
        if hp.remat and not hp.fine_remat:
            c_b += c_f  # coarse remat re-executes the forward collective
            c_b_y += c_y
        # memory per chip (Eq. 6): bf16 weights /n, f32 master+m+v ZeRO'd /dp
        zdp = dp if hp.zero1 else 1
        mem_s = blk.params * (2.0 / n + 12.0 / (n * zdp))
        # saved tensors live only for one microbatch; fine remat additionally
        # keeps each block's collective output (the §3.2 memory<->comm trade)
        mem_t = (t_live * cfg.d_model * hw.bytes_act
                 * (1.5 if hp.fine_remat else 0.5))
        out.d_f.append(d_f)
        out.c_f.append(c_f)
        out.d_b.append(d_b)
        out.c_b.append(c_b)
        out.mem_s.append(mem_s)
        out.mem_t.append(mem_t)
        out.c_f_y.append(c_y)
        out.c_b_y.append(c_b_y)
    return out


def edge_cost(cfg: ArchConfig, shape: ShapeConfig, hw: HWConfig,
              n_from, n_to, node_from: NodeCosts, i_from: int,
              i_to: int) -> float:
    """Eq. 4: resharding AllGather + destroyed overlap.

    Degrees may be 2D tuples; the batch resharding depends only on the
    *total* degree (extra-dp axes), so an x/y re-split at equal total is
    free here (weights are already laid out per layer)."""
    n_from, n_to = _dtot(n_from), _dtot(n_to)
    if n_from == n_to:
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    d = cfg.d_model
    if n_to > n_from:
        # batch gathered over ratio r on the way in (forward AllGather)
        dp_to = max(hw.n_chips // n_to, 1)
        r = n_to // n_from
        gathered = tokens / dp_to * d * hw.bytes_act
        t_ag = gathered * (r - 1) / r / hw.link_bw + hw.comm_latency
    else:
        # degree decrease: free local slice fwd, AllGather in backward
        dp_from = max(hw.n_chips // n_from, 1)
        r = n_from // n_to
        gathered = tokens / dp_from * d * hw.bytes_act
        t_ag = gathered * (r - 1) / r / hw.link_bw + hw.comm_latency
    # destroyed overlap: the blocking gather serializes what the last
    # collective of `from` could have hidden (min term of Eq. 4)
    lost = min(node_from.c_f[i_from], node_from.d_f[i_to])
    return t_ag + lost


def estimate_iteration(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
                       degrees: Sequence, hw: HWConfig = V5E,
                       options: Sequence = (2, 4, 8, 16),
                       stages: int = 1,
                       schedules: Optional[Sequence[str]] = None,
                       seqs: Optional[Sequence[int]] = None) -> Dict:
    """Evaluate f(s) (Eq. 3–5) for a concrete per-layer strategy (entries
    int or ``(dx, dy)``).  Also the cost model used by benchmarks/fig6
    (Spearman vs measured).  ``stages`` > 1: each chip holds only 1/stages
    of the layer stack (pipeline parallelism), scaling the per-layer
    WEIGHT/optimizer memory; saved activations do NOT shrink — a 1F1B
    stage keeps up to min(stages, n_micro) microbatches' residuals in
    flight, which cancels the layer reduction (see
    :func:`pipeline_mem_terms`).

    ``schedules``: optional per-layer schedule names (the executable-plan
    search space) — ``None`` runs every layer under ``hp.schedule``.  At
    a transition out of an oases/merak overlap run the pending collective
    is exposed (the next group's schedule gives it nothing to hide
    behind), which is exactly the conservatism the grouped execution
    shows; uniform inputs reproduce the single-schedule estimate
    bit-for-bit.

    ``seqs``: optional per-layer ring-attention seq shards (the plan's
    seq axis; 1 = head-sharded).  A ring layer's attention block swaps
    its AllReduce for the overlapped KV-ring term (ring_attn_costs) —
    exposed as ``max(T_attn, T_kv_ring) + fill`` regardless of the
    layer's schedule (the ring is its own schedule) — while its MLP
    block keeps the layer schedule.  Every seq-axis change between
    adjacent layers (and a trailing ring layer before the LM head)
    charges one residual regather: the exit AllGather (or its backward
    mirror) that the next group's layout cannot hide — the KV-ring
    exposure at schedule/seq transitions."""
    blocks = layer_blocks(cfg, shape)
    options = list(options)
    for d in degrees:                      # tolerate degrees ∉ options
        if _dkey(d) not in {_dkey(o) for o in options}:
            options.append(_dkey(d))
    opt_index = {_dkey(o): i for i, o in enumerate(options)}
    scheds = (list(schedules) if schedules is not None
              else [hp.schedule] * cfg.num_layers)
    lseqs = list(seqs) if seqs is not None else [1] * cfg.num_layers
    seq = []   # (NodeCosts, option_idx, degree, schedule, ring)
    for layer, degree, sched, sq in zip(blocks, degrees, scheds, lseqs):
        for blk in layer:
            ring = sq > 1 and blk.name in ("attn", "xattn")
            nc = (ring_attn_costs(cfg, blk, shape, hp, hw, options)
                  if ring else node_costs(cfg, blk, shape, hp, hw, options))
            seq.append((nc, opt_index[_dkey(degree)], degree, sched, ring))

    split = max(hp.split, 1)

    def pass_time(dkey, ckey, cykey):
        total = 0.0
        prev_c = 0.0
        for nc, j, n, sched, ring in seq:
            d = getattr(nc, dkey)[j]
            c = getattr(nc, ckey)[j]
            if ring:
                # KV ring overlaps block compute; the pending collective
                # of a preceding overlap run has nothing to hide behind
                total += prev_c
                total += overlapped_time(split * d, split * c,
                                         _dtot(n) - 1)
                prev_c = 0.0
            elif split > 1 and sched in ("oases", "merak"):
                # Eq. 3: sub-batch 0 compute overlaps previous comm; sub-batch
                # 1 compute overlaps own sub-batch-0 comm
                total += max(d, prev_c) + max(d, c)
                prev_c = c
            elif sched == "fused":
                # kernel-level collective matmul: comm is hidden under the
                # tile matmuls of the same block.  2D nodes compose per
                # axis: max(c_x, d) + max(c_y, fill) — the y collectives
                # hide under the x-ring's pipeline fill when thin enough.
                dx, dy = _dxy(n)
                c_y = getattr(nc, cykey)[j]
                total += prev_c   # leftover overlap-run cool-down exposed
                total += overlapped_time_2d(split * d, split * (c - c_y),
                                            split * c_y, dx - 1)
                prev_c = 0.0
            elif sched == "wang":
                # intra-op decomposition hides all but one chunk
                total += prev_c
                prev_c = 0.0
                total += split * d + c / max(hp.split * 2, 1) + c * 0.1
            else:
                total += prev_c
                total += split * d + split * c
                prev_c = 0.0
        total += prev_c   # cool-down: last collective exposed
        return total

    t_f = pass_time("d_f", "c_f", "c_f_y")
    t_b = pass_time("d_b", "c_b", "c_b_y")
    # edges
    t_e = 0.0
    for a in range(len(seq) - 1):
        n1, n2 = seq[a][2], seq[a + 1][2]
        if _dkey(n1) != _dkey(n2):
            t_e += edge_cost(cfg, shape, hw, n1, n2, seq[a][0], seq[a][1],
                             seq[a + 1][1]) * 2  # fwd + bwd reshard
    # seq-axis transitions: entering a ring group slices the residual
    # locally (free) but leaving one regathers it — and the backward pass
    # mirrors the pair, so each boundary nets one exposed AllGather of the
    # per-chip residual over the ring group (incl. the exit before the
    # LM head when the last layer rides the ring)
    tokens = shape.global_batch * shape.seq_len
    for a, sq in enumerate(lseqs + [1]):
        prev = lseqs[a - 1] if a else 1
        if sq == prev:
            continue
        grp = max(prev, sq)
        deg = _dtot(degrees[min(a, len(degrees) - 1)])
        dp_a = max(hw.n_chips // max(deg, 1), 1)
        res = tokens / dp_a * cfg.d_model * hw.bytes_act
        t_e += res * (grp - 1) / grp / hw.ring_bw(grp) + hw.comm_latency
    # memory (Eq. 6)
    s_scale, t_scale = pipeline_mem_scales(stages, hp.microbatch)
    mem = 0.0
    for nc, j, n, _sched, _ring in seq:
        mem += nc.mem_s[j] * s_scale + nc.mem_t[j] * t_scale
    vp = cfg.padded_vocab()
    last = max(_dtot(degrees[-1]), 1)
    head = vp * cfg.d_model * (2.0 / last) * (1 if cfg.tie_embeddings else 2)
    mem += head + head * 6.0    # embed/head + optimizer states
    m_r = 4.0 * shape.global_batch * shape.seq_len * cfg.d_model \
        * hw.bytes_act / (hw.n_chips / last)
    mem += m_r
    total = t_f + t_b + t_e
    return {"iter_s": total, "fwd_s": t_f, "bwd_s": t_b, "edge_s": t_e,
            "mem_bytes": mem, "fits": mem < hw.hbm_cap,
            "tokens_per_s": shape.global_batch * shape.seq_len / total}


# --------------------------------------------------------------------------
# pipeline-parallel composition (PP x TMP, Megatron/AMP-style)
# --------------------------------------------------------------------------
def pipeline_mem_scales(stages: int, n_micro: int) -> Tuple[float, float]:
    """Per-stage scaling of the Eq. 6 memory terms: weights/optimizer state
    (mem_s) shrink 1/stages, but live activations (mem_t) do not — a 1F1B
    stage holds up to min(stages, n_micro) in-flight microbatches, which
    cancels the 1/stages layer reduction.  Returns (s_scale, t_scale)."""
    s = max(stages, 1)
    in_flight = min(s, n_micro) if n_micro > 0 else s
    return 1.0 / s, in_flight / s


def stage_hw(hw: HWConfig, pp: int) -> HWConfig:
    """The hardware slice one pipeline stage owns: n_chips/pp chips with
    the same node topology — a stage that fits inside one node keeps every
    TMP ring on the fast intra-node lanes, which is the whole point of
    placing PP across boxes on commodity clusters."""
    import dataclasses
    return dataclasses.replace(hw, n_chips=max(hw.n_chips // pp, 1))


def p2p_hop_seconds(cfg: ArchConfig, shape: ShapeConfig, hw: HWConfig,
                    pp: int, n_micro: int, degree=1) -> float:
    """One microbatch's activation transfer across one stage boundary.

    Activations are replicated over the stage's TMP group and sharded over
    its data axes, so each chip ships its dp-shard of the microbatch's
    [mb, s, d] tensor to its peer in the next stage.  The hop rides the
    inter-node links when stages occupy whole nodes, the intra-node lanes
    when several stages share one."""
    chips = max(hw.n_chips // max(pp, 1), 1)
    ns = hw.node_size or hw.n_chips
    bw = hw.bw_y if chips >= ns else hw.bw_x
    dp = max(chips // max(_dtot(degree), 1), 1)
    mb_tokens = shape.global_batch * shape.seq_len / max(n_micro, 1)
    return (mb_tokens / dp) * cfg.d_model * hw.bytes_act / bw \
        + hw.comm_latency


# --------------------------------------------------------------------------
# serving latency model (per-token decode, batch = concurrent slots)
# --------------------------------------------------------------------------
def _gather_eff(page_size: int) -> float:
    """HBM efficiency of reading a KV cache through a block table: each
    page is a separate (strided) DMA paying a fixed ~2-row startup against
    ``page_size`` contiguous rows.  0 = dense layout (no discount)."""
    if page_size <= 0:
        return 1.0
    return page_size / (page_size + 2.0)


def _decode_layer_time(cfg: ArchConfig, kind: str, hw: HWConfig, degree,
                       rows: int, kv_len: int, schedule: str, *,
                       q_tokens: int = 1, page_size: int = 0) -> float:
    """One layer's decode-step seconds for ``rows`` slot rows at KV context
    ``kv_len`` under per-stage degree ``(dx, dy)``.

    Decode inverts the training regime: matmuls are memory-bound (the
    whole weight matrix streams from HBM for a handful of rows) and the
    collectives are LATENCY-bound (the payload is ``rows * d_model`` bytes
    — kilobytes, not megabytes).  A fused ring still hides the *bandwidth*
    component under the tile matmuls, but the per-hop latency floor is
    serial and has nothing to hide behind at single-token shapes — the
    overlap term saturates, which is what pushes the latency planner off
    wide rings (toward 2D splits or pipeline stages) on commodity links.

    ``q_tokens > 1`` models a speculative *verify* forward: flops and
    collective payloads scale with the extra tokens per row but the weight
    stream and the KV read do not, and the per-hop latency floor is paid
    ONCE — that amortization is the entire speculative-decoding win.
    ``page_size`` applies the paged-cache gather discount to the KV read.
    """
    dx, dy = _dxy(degree)
    n = dx * dy
    total = 0.0
    for blk in _block_costs(cfg, kind, rows * q_tokens, kv_len):
        w_bytes = blk.params * hw.bytes_act / n
        kv_bytes = 0.0
        if blk.name in ("attn", "xattn"):
            kv_bytes = (2.0 * rows * kv_len * cfg.num_kv_heads
                        * cfg.resolved_head_dim * hw.bytes_act / dx
                        / _gather_eff(page_size))
        width = max(cfg.d_ff, cfg.num_heads * cfg.resolved_head_dim) // dx
        eff = _mxu_eff(hw, width, rows * q_tokens)
        d = max((w_bytes + kv_bytes) / hw.hbm_bw,
                blk.flops_fwd / n / (hw.peak_flops * eff))
        if not blk.n_collectives:
            total += d
            continue
        k_bytes = rows * q_tokens * cfg.d_model * hw.bytes_act
        c_bw = c_lat = 0.0
        if dx > 1:
            c_bw += (k_bytes / dy) * 2.0 * (dx - 1) / dx / hw.ring_bw(dx)
            c_lat += hw.collective_latency(dx)
        if dy > 1:
            c_bw += k_bytes * 2.0 * (dy - 1) / dy / hw.ring_bw(n)
            # the y hops cross nodes whenever the whole group spills out
            # of one (the 2D layout's intended placement)
            ns = hw.node_size or hw.n_chips
            lat_hop = hw.lat_y if n > ns else hw.comm_latency
            c_lat += 2.0 * (dy - 1) * lat_hop
        if schedule == "fused":
            total += max(d, c_bw) + c_lat
        else:
            total += d + c_bw + c_lat
    return total


def _decode_head_time(cfg: ArchConfig, hw: HWConfig, rows: int,
                      n_tmp: int) -> float:
    """LM-head matmul + greedy top-1 all-gather, paid once per engine
    step outside the layer stack.  The embed/head are vocab-sharded over
    the TMP group only and REPLICATED over ``pipe`` (models/params.py) —
    every stage computes the full local head after the broadcast — so the
    sharding divisor is the per-stage group ``n_tmp``, not n_tmp * pp."""
    vp = cfg.padded_vocab()
    w_bytes = vp * cfg.d_model * hw.bytes_act / max(n_tmp, 1)
    flops = 2.0 * rows * cfg.d_model * vp / max(n_tmp, 1)
    t = max(w_bytes / hw.hbm_bw, flops / (hw.peak_flops * hw.mxu_base_eff))
    # greedy argmax all-gather over the TMP group (one phase)
    t += hw.collective_latency(n_tmp) / 2.0
    return t


def decode_step_time(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
                     hw: HWConfig, degree=1, pp: int = 1, *,
                     virtual_stages: int = 1, n_micro: int = 0,
                     page_size: int = 0, spec_k: int = 0,
                     spec_accept: float = 0.8,
                     draft: Optional[ArchConfig] = None) -> Dict:
    """Per-engine-step latency of sharded decode on a ``(dx, dy, pp)``
    serving mesh — one token for every one of ``shape.global_batch``
    concurrent slots at KV context ``shape.seq_len``.

    ``degree`` is the PER-STAGE TMP degree (int or ``(dx, dy)``); ``pp``
    stages each own ``num_layers / pp`` of the stack on ``n_chips / pp``
    chips.  Under PP the slot batch streams through the stages as
    ``n_micro`` micro-groups (``core/pipeline.decode_stream``):
    ``ticks = n_micro + pp*v - 1`` and every tick runs one stage's layers
    on one micro-group — fewer layers per tick, but the stage weights
    re-stream from HBM once per micro-group, which is the latency/
    throughput trade the planner arbitrates.

    ``page_size > 0`` applies the paged-KV gather discount to the cache
    read.  ``spec_k > 0`` models a speculative round instead of a single
    step: ``spec_k + 1`` forwards of the (replicated, dense-cache)
    ``draft`` model plus one ``q_tokens = spec_k + 1`` verify forward of
    the target, emitting ``E = (1 - a^(k+1)) / (1 - a)`` expected tokens
    per slot (``a = spec_accept``).  The reported ``step_s`` is the
    per-emitted-token equivalent ``round_s / E``, directly comparable to
    the undrafted step — speculative wins exactly where the target step
    is dominated by the per-layer collective latency floor (commodity
    links), because the verify pays that floor once per ``E`` tokens
    while the draft, being replicated, pays none at all.
    """
    batch = max(shape.global_batch, 1)
    kv_len = shape.seq_len
    pat = cfg.layer_pattern
    v = max(virtual_stages, 1)
    dx, dy = _dxy(degree)
    n_s = dx * dy
    if spec_k > 0:
        if draft is None:
            raise ValueError(
                "spec_k > 0 needs a draft ArchConfig — the round time is "
                "(k+1) draft forwards + one verify forward")
        if pp > 1:
            raise ValueError(
                "speculative decoding does not compose with pipeline "
                "stages (lm.build_verify rejects 'pipe' meshes) — model "
                "spec_k on pp=1 candidates only")

    if pp <= 1:
        layers = sum(_decode_layer_time(cfg, pat[i % len(pat)], hw, degree,
                                        batch, kv_len, hp.schedule,
                                        page_size=page_size)
                     for i in range(cfg.num_layers))
        total = layers + _decode_head_time(cfg, hw, batch, n_s)
        micro, t_hop = 1, 0.0
    else:
        # the execution path's resolver, so the planner never reports an
        # n_micro the engine would refuse (explicit non-divisors raise
        # there too)
        from repro.core.pipeline import resolve_decode_micro
        micro = resolve_decode_micro(batch, pp, v, n_micro)
        mb = batch // micro
        per_tick = sum(
            _decode_layer_time(cfg, pat[i % len(pat)], hw, degree, mb,
                               kv_len, hp.schedule, page_size=page_size)
            for i in range(cfg.num_layers)) / pp
        chips = max(hw.n_chips // pp, 1)
        ns = hw.node_size or hw.n_chips
        spans = chips >= ns            # stages own whole nodes
        bw = hw.bw_y if spans else hw.bw_x
        lat = hw.lat_y if spans else hw.comm_latency
        t_hop = mb * cfg.d_model * hw.bytes_act / bw + lat
        ticks = micro + pp * v - 1
        total = ticks * (per_tick + t_hop)
        # broadcast of the last stage's hidden state (psum over pipe)
        total += (batch * cfg.d_model * hw.bytes_act * 2.0 * (pp - 1) / pp
                  / bw + 2 * (pp - 1) * lat)
        total += _decode_head_time(cfg, hw, batch, n_s)

    e_tokens = 1.0
    if spec_k > 0:
        # one round: k+1 draft forwards (replicated — degree 1, dense
        # cache, no collectives) + one (k+1)-token verify of the target
        dpat = draft.layer_pattern
        draft_s = sum(
            _decode_layer_time(draft, dpat[i % len(dpat)], hw, 1, batch,
                               kv_len, hp.schedule)
            for i in range(draft.num_layers))
        draft_s += _decode_head_time(draft, hw, batch, 1)
        verify_s = sum(
            _decode_layer_time(cfg, pat[i % len(pat)], hw, degree, batch,
                               kv_len, hp.schedule, q_tokens=spec_k + 1,
                               page_size=page_size)
            for i in range(cfg.num_layers))
        verify_s += _decode_head_time(cfg, hw, batch * (spec_k + 1), n_s)
        a = min(max(spec_accept, 0.0), 0.999)
        e_tokens = (1.0 - a ** (spec_k + 1)) / (1.0 - a)
        round_s = (spec_k + 1) * draft_s + verify_s
        total = round_s / e_tokens

    # memory: bf16 weights /(pp * n_s) per chip + the KV cache of the
    # stage's layers, head-sharded over dx
    params = sum(b.params for i in range(cfg.num_layers)
                 for b in _block_costs(cfg, pat[i % len(pat)], 1, kv_len))
    mem = params * hw.bytes_act / (pp * n_s)
    # head/embed replicated over pipe: sharded by the TMP group only
    mem += cfg.padded_vocab() * cfg.d_model * hw.bytes_act / max(n_s, 1)
    kv_layers = sum(1 for i in range(cfg.num_layers)
                    if pat[i % len(pat)] in (GLOBAL_ATTN, LOCAL_ATTN,
                                             CROSS_ATTN))
    mem += (kv_layers / pp) * (2.0 * batch * kv_len * cfg.num_kv_heads
                               * cfg.resolved_head_dim * hw.bytes_act / dx)
    if spec_k > 0:
        # replicated draft weights + its dense KV cache on every chip
        dpat = draft.layer_pattern
        dparams = sum(b.params for i in range(draft.num_layers)
                      for b in _block_costs(draft, dpat[i % len(dpat)], 1,
                                            kv_len))
        mem += dparams * hw.bytes_act
        mem += (draft.padded_vocab() * draft.d_model * hw.bytes_act
                + draft.num_layers * 2.0 * batch * kv_len
                * draft.num_kv_heads * draft.resolved_head_dim
                * hw.bytes_act)
    # with spec, step_s is already round_s / E, so batch / step_s IS the
    # emitted-token throughput
    return {"step_s": total, "tok_per_s": batch / total,
            "n_micro": micro, "t_hop": t_hop, "e_tokens": e_tokens,
            "mem_bytes": mem, "fits": mem < hw.hbm_cap}


def pipeline_time(t_tmp: float, pp: int, n_micro: int,
                  virtual_stages: int = 1,
                  t_hop: float = 0.0) -> Tuple[float, float, float]:
    """Compose a full-stack TMP iteration time (modeled on one stage's
    chips — :func:`stage_hw`) into the interleaved-1F1B estimate.

    Each stage is busy ``t_tmp / pp`` per iteration; the fill/drain bubble
    adds ``(pp-1)/v`` microbatch slots; P2P transfers expose the fill/drain
    hops (fwd + bwd) on the critical path plus whatever part of each
    steady-state hop the next microbatch's compute cannot hide.  Returns
    ``(total_s, bubble_fraction, p2p_s)``; degenerates to
    ``(t_tmp, 0, 0)`` at pp == 1.
    """
    if pp <= 1:
        return t_tmp, 0.0, 0.0
    m = max(n_micro, 1)
    v = max(virtual_stages, 1)
    t_mb = t_tmp / (pp * m)              # per-stage per-microbatch slot
    bubble = (pp - 1) * t_mb / v
    p2p = 2.0 * (pp - 1) * t_hop \
        + 2.0 * max(m - 1, 0) * max(t_hop - t_mb, 0.0)
    total = t_tmp / pp + bubble + p2p
    return total, bubble / total if total else 0.0, p2p
