"""Oases planner ILP (paper §4, Eq. 2–6), solved with scipy HiGHS.

Decision: one-hot s_{i,j} over TMP-degree options per graph node (block).
Eq. 3's max{} terms are linearized with auxiliary continuous u-variables;
Eq. 5's quadratic edge term s_v^T R s_u with per-edge product binaries
y_{jk} >= s_vj + s_uk - 1.  Eq. 6 memory is a single linear constraint.

Same-layer blocks share one degree (the paper plans per layer, Table 6), so
s is per-LAYER and the per-block costs are summed within a layer.

Planner v2: the option space extends beyond the paper's 1D baseline to 2D
hybrid partitions ``(dx, dy)`` — width over dx intra-node lanes, the
contraction dim over dy inter-node hops (arXiv:2104.05343-style), costed
with the per-axis bandwidths of :class:`costmodel.HWConfig`.  ``layout``
picks the search space: ``'1d'`` (ints only, the paper), ``'2d'`` (every
factorization including the 1D-equivalent ``(n, 1)``), ``'auto'`` (union).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import LinearConstraint, milp
from scipy.sparse import lil_matrix

from repro.configs.base import ArchConfig, ShapeConfig, TrainHParams
from repro.core.planner import costmodel as cm


def _telemetry_plan(entry: str, pr):
    """Record a finished solve through the process-global telemetry
    recorder (repro.obs): solve time histogram + a planner.plan event
    carrying the chosen plan and its predicted iteration time.  A no-op
    unless a recorder is configured (launchers' --telemetry)."""
    from repro import obs
    rec = obs.get_recorder()
    rec.observe("planner.solve_ms", pr.solve_ms, entry=entry)
    rec.event("planner.plan", entry=entry,
              predicted_ms=round(pr.predicted_s * 1e3, 3),
              solve_ms=round(pr.solve_ms, 1), status=str(pr.status),
              msg=f"[planner] {entry}: {pr.summary()}")
    return pr


def _fmt_degree(d) -> str:
    dx, dy = cm._dxy(d)
    return f"{dx}x{dy}" if dy > 1 else str(dx)


@dataclass
class PlanResult:
    degrees: List[object]                  # int (1D) or (dx, dy) (2D)
    predicted_s: float
    solve_ms: float
    status: str
    groups: List[Tuple[object, int]]       # (degree, count) runs
    schedules: Optional[List[str]] = None  # per-layer schedule names
    plan: Optional[object] = None          # executable ParallelPlan
    seqs: Optional[List[int]] = None       # per-layer ring seq shards

    def summary(self) -> str:
        sq = self.seqs if self.seqs and any(q > 1 for q in self.seqs) \
            else None
        if sq or (self.schedules is not None
                  and len(set(self.schedules)) > 1):
            scheds = self.schedules or [""] * len(self.degrees)
            runs = " + ".join(
                f"[{_fmt_degree(d)}{'/' + s if s else ''}"
                f"{f'/seq{q}' if q > 1 else ''}] * {n}"
                for (d, s, q), n in _runs(list(zip(
                    self.degrees, scheds, sq or [1] * len(self.degrees)))))
        else:
            sched = f"/{self.schedules[0]}" if self.schedules else ""
            runs = " + ".join(f"[{_fmt_degree(d)}{sched}] * {n}"
                              for d, n in self.groups)
        return (f"[{runs}] predicted {self.predicted_s*1e3:.1f} ms/iter "
                f"(ILP {self.solve_ms:.1f} ms, {self.status})")


def _runs(values: Sequence) -> List[Tuple[object, int]]:
    out = []
    for d in values:
        if out and out[-1][0] == d:
            out[-1] = (d, out[-1][1] + 1)
        else:
            out.append((d, 1))
    return out


def _as_plan(hp, degrees, schedules, *, seqs=None, pp: int = 1,
             virtual_stages: int = 1, microbatch: Optional[int] = None,
             decode_micro: int = 0, mesh_shape=(), mesh_axes=()):
    """Wrap an ILP decision as an executable ParallelPlan.

    Under pipeline parallelism the per-stage TMP degree lives in the MESH
    (stage-internal model axes), not in per-layer pinned degrees — the
    grouped layout does not compose with PP — so pp > 1 plans record
    mesh-following (``None``) degrees and should carry the mesh signature
    instead.  A seq-sharded decision over a UNIFORM degree likewise
    records mesh-following degrees: the ring runs on the plain
    ``(data, model)`` mesh of that degree and the seq axis alone decides
    per-layer behaviour (lm.build_train_loss's stacked ring fast path /
    seq-grouped scan both require mesh-following degrees there)."""
    import dataclasses as _dc

    from repro.core.plan import ParallelPlan
    if microbatch is not None:
        hp = _dc.replace(hp, microbatch=microbatch)
    hp = _dc.replace(hp, virtual_stages=max(virtual_stages, 1))
    if seqs is not None and not any(q > 1 for q in seqs):
        seqs = None
    follow = pp > 1 or (seqs is not None
                        and len({cm._dkey(d) for d in degrees}) == 1)
    return ParallelPlan.from_hparams(
        hp, len(degrees),
        degrees=([None] * len(degrees) if follow
                 else [_dkey_plan(d) for d in degrees]),
        schedules=list(schedules), seqs=list(seqs) if seqs else None,
        pp=max(pp, 1), decode_micro=decode_micro,
        mesh_shape=mesh_shape, mesh_axes=mesh_axes)


def _dkey_plan(d):
    dx, dy = cm._dxy(d)
    return dx if dy == 1 else (dx, dy)


def _mesh_sig(hw: cm.HWConfig, pp: int, degree) -> Tuple[Tuple[int, ...],
                                                         Tuple[str, ...]]:
    """The canonical launch mesh of a uniform-degree (pp, degree) decision
    on ``hw`` — recorded into the decision's ParallelPlan so ``--plan``
    launches reconstruct the mesh the planner actually costed."""
    dx, dy = cm._dxy(degree)
    dp = max(hw.n_chips // (max(pp, 1) * dx * dy), 1)
    if dy > 1:
        shape: Tuple[int, ...] = (dp, dx, dy)
        axes: Tuple[str, ...] = ("data", "model_x", "model_y")
    else:
        shape, axes = (dp, dx), ("data", "model")
    if pp > 1:
        shape, axes = (pp,) + shape, ("pipe",) + axes
    return shape, axes


def _plan_mesh_sig(hw: cm.HWConfig, degrees) -> Tuple[Tuple[int, ...],
                                                      Tuple[str, ...]]:
    """Launch mesh of a (pp = 1) per-layer plan: a uniform strategy takes
    the plain/2D mesh; mixed (or per-layer-2D) strategies need the
    FACTORED mesh — binary t-sub-axes covering the largest group, extra
    axes doubling as data parallelism for lower-degree layers (the
    execution contract of lm._grouped_scan).  Returns ``((), ())`` when
    the factored axes would exceed the t1..t4 vocabulary (the launcher's
    explicit --mesh takes over)."""
    import math as _math
    kinds = {cm._dkey(d) for d in degrees}
    dmax = max(cm._dtot(d) for d in degrees)
    if len(kinds) == 1:
        return _mesh_sig(hw, 1, next(iter(kinds)))
    k = int(_math.log2(dmax))
    if k > 4 or 2 ** k != dmax:               # beyond T_AXES: don't guess
        return (), ()
    dp = max(hw.n_chips // dmax, 1)
    return ((dp,) + (2,) * k,
            ("data",) + tuple(f"t{i + 1}" for i in range(k)))


def expand_options(cfg: ArchConfig, hw: cm.HWConfig,
                   options: Sequence[int], layout: str) -> List:
    """The per-layer degree option space for a layout.

    2D factorizations keep dx within one node (the x-ring must ride the
    fast lanes) and require the contraction dim divisible by dy (the
    per-axis decomposition slices d_model); ``(n, 1)`` degenerates stay so
    a forced-2D search is never less expressive than 1D.
    """
    base = [int(n) for n in options]
    if layout == "1d":
        return base
    ns = hw.node_size or hw.n_chips
    out: List = [] if layout == "2d" else list(base)
    for n in base:
        dy = 2
        while dy <= n:
            dx = n // dy
            if (dx * dy == n and dx <= ns
                    and cfg.d_model % dy == 0):
                out.append((dx, dy))
            dy *= 2
        if layout == "2d":
            out.append((n, 1))
    return out


def _consolidate_seqs(cfg, degrees, lsched, lseqs):
    """Defragment the ILP's seq axis.  Layers with identical
    (kind, degree, schedule) are cost-identical columns, so HiGHS
    scatters a memory-driven ring-layer count arbitrarily among them.
    Sorting each equivalence class's seq values in place (head-sharded
    first, ring last) keeps the exact per-class ring count — Eq. 3/6
    node terms are unchanged — while minimizing seq-axis transitions,
    each of which estimate_iteration charges a residual regather."""
    pat = cfg.layer_pattern
    groups: Dict[tuple, List[int]] = {}
    for i in range(len(lseqs)):
        groups.setdefault(
            (pat[i % len(pat)], cm._dkey(degrees[i]), lsched[i]),
            []).append(i)
    out = list(lseqs)
    for idxs in groups.values():
        for i, v in zip(idxs, sorted(lseqs[i] for i in idxs)):
            out[i] = v
    return out


def _smooth_schedules(cfg, shape, hp, degrees, lsched, hw, options, scheds,
                      lseqs=None, ring_ok=None, mem_cap=None):
    """Post-solve consistency guard for the (degree, schedule[, seq])
    search.

    The ILP's linearization charges schedule and seq transitions nothing
    (edge products range over degree pairs only), while
    ``estimate_iteration`` exposes the pending overlap cool-down when
    leaving an oases/merak run and the residual regather at every
    seq-axis boundary — so a near-tie could fragment the stack into a
    plan the estimator scores worse than a uniform overlay.  Evaluate the
    ILP's choice against every uniform-schedule overlay on the SAME
    (degrees, seqs), and — when the seq axis is in play — against the
    uniform seq overlays (all-off, and all-on where every layer is
    ring-capable), keeping the cheapest MEMORY-FEASIBLE candidate (seq
    overlays move Eq. 6, so each one re-checks ``mem_cap``; the ILP
    choice wins exact ties).  Returns ``(schedules, seqs, estimate)``."""
    L = len(lsched)
    lseqs = list(lseqs) if lseqs is not None else [1] * L
    base = [1] * L
    seq_cands = [list(lseqs)]
    if any(q > 1 for q in lseqs):
        seq_cands.append(base)
        full = [int(cm._dtot(d)) if (ring_ok is None or ring_ok[i])
                and not isinstance(degrees[i], (tuple, list))
                and cm._dtot(degrees[i]) > 1 else 1
                for i, d in enumerate(degrees)]
        if full != lseqs and any(q > 1 for q in full):
            seq_cands.append(full)
    candidates = [(list(lsched), sq) for sq in seq_cands]
    if len(set(lsched)) > 1:
        candidates += [([s] * L, sq) for s in scheds for sq in seq_cands]
    e0 = cm.estimate_iteration(cfg, shape, hp, degrees, hw, options,
                               schedules=list(lsched), seqs=list(lseqs))
    best = None
    for cand, sq in candidates:
        e = cm.estimate_iteration(cfg, shape, hp, degrees, hw, options,
                                  schedules=cand, seqs=sq)
        # an overlay must not move Eq. 6 the wrong way past the cap (the
        # estimator's mem includes fixed terms the ILP row does not, so
        # "no worse than the ILP's own choice" is the consistent bar)
        if (mem_cap is not None and e["mem_bytes"] > mem_cap
                and e["mem_bytes"] > e0["mem_bytes"]):
            continue                      # overlay broke Eq. 6: drop it
        key = (e["iter_s"],
               sum(a != b for a, b in zip(sq, sq[1:])),
               sum(a != b for a, b in zip(cand, cand[1:])))
        if best is None or key < best[0]:
            best = (key, cand, sq, e)
    return best[1], best[2], best[3]


def _pair_pass_bounds(sched: str, split: int, d: float, c: float,
                      fused_v: float) -> Tuple[float, float]:
    """The two Eq. 3 lower bounds of one (layer, degree, schedule) option
    for one pass: the layer's exposed-time variable u must satisfy
    ``u >= lb1`` and ``u >= lb2`` when this option is chosen.  Non-overlap
    schedules collapse both bounds to the same constant (matching
    estimate_iteration's per-schedule branches exactly — this is what
    lets the ILP search (degree, schedule) pairs with the existing
    per-schedule exposed-cost terms)."""
    if sched == "fused":
        return fused_v, fused_v
    if sched in ("oases", "merak") and split > 1:
        return split * d, (split - 1) * d + c
    if sched == "wang":
        v = split * d + c / max(split * 2, 1) + c * 0.1
        return v, v
    v = split * (d + c)                      # megatron / split == 1
    return v, v


def plan(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
         hw: cm.HWConfig = cm.V5E,
         options: Sequence[int] = (2, 4, 8, 16),
         mem_cap: Optional[float] = None,
         time_limit: float = 20.0,
         layout: str = "1d",
         stages: int = 1,
         objective: str = "throughput",
         schedules: Optional[Sequence[str]] = None,
         seq: str = "none"
         ) -> "PlanResult | ServingPlanResult":
    """``layout`` is the explicit search-space knob (it deliberately does
    NOT read ``hp.tmp_layout``, which governs the *execution* layout and
    defaults to mesh-following 'auto'): '1d' preserves the paper's search
    space; pass '2d' or 'auto' to enable hybrid partitions.  ``stages``:
    pipeline-stage count — weight/optimizer rows of Eq. 6 scale 1/stages
    (each chip holds that fraction of the layers) while live activations
    keep their in-flight-microbatch factor (costmodel.pipeline_mem_scales;
    used by :func:`plan_joint`).

    ``schedules`` extends the per-layer option space from degrees to
    ``(degree, schedule)`` pairs — the paper's actual search space (§4,
    Table 6 plans per layer): pass a tuple of schedule names or
    ``"auto"`` for all of them; ``None`` (default) searches degrees only
    under ``hp.schedule``.  The result's ``.plan`` is the executable
    :class:`~repro.core.plan.ParallelPlan`.

    ``objective='latency'`` retargets the search at serving: instead of
    the per-layer throughput ILP it runs :func:`plan_serving` — a
    ``(dx, dy, pp)`` mesh search minimizing per-token decode-step latency
    (``costmodel.decode_step_time``) — and returns a
    :class:`ServingPlanResult`.

    ``seq`` opens the plan's third per-layer axis, ring attention
    (kernels/ring_attention.py): ``'auto'`` extends every 1D degree
    option n > 1 on a self/local-attention layer with its seq-sharded
    variant seq == n — attention weights replicated, sequence sharded,
    the block collective replaced by the overlapped KV ring
    (``costmodel.ring_attn_costs``) — so the one-hot ranges over
    (degree, schedule, seq ∈ {1, degree}) triples.  ``'none'`` (default)
    keeps the two-axis search exactly.  The seq axis does not compose
    with pipeline stages (``stages > 1`` forces it off, matching
    core/plan.py's validation)."""
    if objective == "latency":
        # the serving search defaults to the full layout space ('1d' here
        # is plan()'s paper-faithful TRAINING default, not a user choice;
        # call plan_serving directly to force a 1D-only latency search)
        return plan_serving(cfg, shape, hp, hw, options=options,
                            mem_cap=mem_cap,
                            layout="auto" if layout == "1d" else layout)
    if objective != "throughput":
        raise ValueError(
            f"unknown planner objective {objective!r}: expected "
            f"'throughput' (training iteration time, the default) or "
            f"'latency' (serving per-token decode latency)")
    t0 = time.perf_counter()
    from repro.core.plan import validate_schedule
    if schedules is None:
        scheds: Tuple[str, ...] = (hp.schedule,)
    elif schedules == "auto":
        # preference order, not SCHEDULES order: cost ties resolve to the
        # earliest entry, and oases/merak are exactly tied in the model
        # (same Eq. 3 bounds) while barrier-free oases is never worse in
        # reality — so oases leads and merak can only win a real gap
        # (there is none), keeping auto plans on the paper's schedule
        scheds = ("oases", "fused", "wang", "megatron", "merak")
    else:
        scheds = tuple(validate_schedule(s, what="planner schedule")
                       for s in schedules)
        if not scheds:
            raise ValueError("schedules must name at least one schedule "
                             "(or be None / 'auto')")
    if seq not in ("none", "auto"):
        raise ValueError(f"unknown planner seq axis {seq!r}: expected "
                         f"'none' (head-sharded only, the default) or "
                         f"'auto' (offer seq == degree ring attention "
                         f"per layer)")
    options = expand_options(cfg, hw, options, layout)
    L = cfg.num_layers
    D = len(options)
    ring_on = seq == "auto" and stages == 1
    # option/layer ring capability: 1D groups of >= 2 chips, on layers
    # whose attention is self/local (cross-attn KV comes from the encoder
    # and stays head-sharded — models/params.py keeps those specs classic)
    ring_opt = [cm._dxy(o)[1] == 1 and cm._dtot(o) > 1 for o in options]
    from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN
    pat = cfg.layer_pattern
    ring_layer = [pat[i % len(pat)] in (GLOBAL_ATTN, LOCAL_ATTN)
                  for i in range(L)]
    # the per-layer one-hot ranges over (degree, schedule, seq) TRIPLES;
    # rf == 1 means "ring: seq == this option's degree"
    pairs = [(dj, sj, rf) for dj in range(D) for sj in range(len(scheds))
             for rf in ((0, 1) if ring_on and ring_opt[dj] else (0,))]
    P = len(pairs)
    mem_cap = mem_cap if mem_cap is not None else hw.hbm_cap

    # per-layer aggregated cost vectors, indexed by DEGREE option (blocks
    # within a layer summed; the degree-only terms are schedule-agnostic —
    # per-pair exposed costs derive from them in _pair_pass_bounds)
    blocks = cm.layer_blocks(cfg, shape)
    split = max(hp.split, 1)
    need_fused = "fused" in scheds

    d_f = np.zeros((L, D))
    c_f = np.zeros((L, D))
    d_b = np.zeros((L, D))
    c_b = np.zeros((L, D))
    mem = np.zeros((L, D))
    # fused node costs must be summed over blocks PER BLOCK (the kernel
    # rings are per-block: one block's comm never hides under another
    # block's compute), matching estimate_iteration — aggregating d/c
    # first and applying max{} after would understate comm-bound layers
    fused_f = np.zeros((L, D))
    fused_b = np.zeros((L, D))
    # ring-pair cost split: the MLP-side blocks keep the layer schedule
    # (d/c/fused *_m arrays) while the attention block collapses to the
    # overlapped ring constant (ring_f/ring_b) with its own Eq. 6 row
    d_f_m = np.zeros((L, D))
    c_f_m = np.zeros((L, D))
    d_b_m = np.zeros((L, D))
    c_b_m = np.zeros((L, D))
    mem_m = np.zeros((L, D))
    fused_f_m = np.zeros((L, D))
    fused_b_m = np.zeros((L, D))
    ring_f = np.zeros((L, D))
    ring_b = np.zeros((L, D))
    mem_r = np.zeros((L, D))
    s_sc, t_sc = cm.pipeline_mem_scales(stages, hp.microbatch)
    for i, layer in enumerate(blocks):
        for blk in layer:
            nc = cm.node_costs(cfg, blk, shape, hp, hw, options)
            d_f[i] += nc.d_f
            c_f[i] += nc.c_f
            d_b[i] += nc.d_b
            c_b[i] += nc.c_b
            mem[i] += np.array(nc.mem_s) * s_sc + np.array(nc.mem_t) * t_sc
            if need_fused:
                for j in range(D):
                    dx_j, _ = cm._dxy(options[j])
                    fused_f[i, j] += cm.overlapped_time_2d(
                        split * nc.d_f[j],
                        split * (nc.c_f[j] - nc.c_f_y[j]),
                        split * nc.c_f_y[j], dx_j - 1)
                    fused_b[i, j] += cm.overlapped_time_2d(
                        split * nc.d_b[j],
                        split * (nc.c_b[j] - nc.c_b_y[j]),
                        split * nc.c_b_y[j], dx_j - 1)
            if not (ring_on and ring_layer[i]):
                continue
            if blk.name == "attn":
                rc = cm.ring_attn_costs(cfg, blk, shape, hp, hw, options)
                for j in range(D):
                    if not ring_opt[j]:
                        continue
                    n_j = cm._dtot(options[j])
                    ring_f[i, j] += cm.overlapped_time(
                        split * rc.d_f[j], split * rc.c_f[j], n_j - 1)
                    ring_b[i, j] += cm.overlapped_time(
                        split * rc.d_b[j], split * rc.c_b[j], n_j - 1)
                    mem_r[i, j] += rc.mem_s[j] * s_sc + rc.mem_t[j] * t_sc
            else:
                d_f_m[i] += nc.d_f
                c_f_m[i] += nc.c_f
                d_b_m[i] += nc.d_b
                c_b_m[i] += nc.c_b
                mem_m[i] += (np.array(nc.mem_s) * s_sc
                             + np.array(nc.mem_t) * t_sc)
                if need_fused:
                    for j in range(D):
                        dx_j, _ = cm._dxy(options[j])
                        fused_f_m[i, j] += cm.overlapped_time_2d(
                            split * nc.d_f[j],
                            split * (nc.c_f[j] - nc.c_f_y[j]),
                            split * nc.c_f_y[j], dx_j - 1)
                        fused_b_m[i, j] += cm.overlapped_time_2d(
                            split * nc.d_b[j],
                            split * (nc.c_b[j] - nc.c_b_y[j]),
                            split * nc.c_b_y[j], dx_j - 1)

    # Eq. 3 per layer, both passes, per (degree, schedule) pair:
    #   overlap (oases/merak, split>1): u >= split*d AND
    #       u >= (split-1)*d + c  (comm hidden behind the other sub-batch's
    #       compute, cool-down exposed)
    #   fused / wang / blocking: one constant exposed cost (both bounds
    #       collapse) — see _pair_pass_bounds.
    # Variables: x = [s(0,0)..s(L-1,P-1), uF_0..uF_{L-1}, uB_..., y_edges]
    # y products range over DEGREE pairs only (edge costs are
    # schedule-agnostic: a schedule change at equal degree reshard nothing).
    nS = L * P
    nU = 2 * L
    edges = [(i, i + 1) for i in range(L - 1)]
    nY = len(edges) * D * D
    N = nS + nU + nY

    cost = np.zeros(N)
    integrality = np.zeros(N)
    integrality[:nS] = 1
    integrality[nS + nU:] = 1
    lb = np.zeros(N)
    ub = np.ones(N)
    ub[nS:nS + nU] = np.inf

    # objective: sum of u variables + edge costs via y
    cost[nS:nS + nU] = 1.0

    # Deterministic tie-breaks (the Eq. 3 max{} linearization leaves every
    # compute-bound degree at the same objective, and HiGHS fragments such
    # ties into arbitrary per-layer mixes):
    # * a 1%-of-comm nudge aligns the ILP's preference with
    #   estimate_iteration's sequential model (lower exposed comm wins);
    # * a ~3e-4-of-compute epsilon prefers 1D, then the thinnest y split;
    # * a ~1e-4-of-compute epsilon prefers earlier-listed schedules, so
    #   degenerate schedule ties collapse to one deterministic choice
    #   instead of HiGHS-arbitrary per-layer fragmentation.
    # All sit well below any real gap (tens of percent in the commodity
    # regime) but above HiGHS's ~1e-7 tolerances, so ties resolve the same
    # way on every solve.
    # * a ~5e-5-of-compute epsilon prefers the head-sharded (seq == 1)
    #   variant, so ring only wins a real modeled gap.
    scale = float(np.mean(d_f) + np.mean(c_f)) or 1.0
    for p, (j, sj, rf) in enumerate(pairs):
        _, dyj = cm._dxy(options[j])
        for i in range(L):
            cost[i * P + p] += 1e-2 * (c_f[i, j] + c_b[i, j])
            if dyj > 1:
                cost[i * P + p] += 3e-4 * scale * (1.0 + np.log2(dyj))
            if sj:
                cost[i * P + p] += 1e-4 * scale * sj
            if rf:
                cost[i * P + p] += 5e-5 * scale

    rows = []
    lo = []
    hi = []

    def add(coefs: Dict[int, float], lo_v, hi_v):
        rows.append(coefs)
        lo.append(lo_v)
        hi.append(hi_v)

    # one-hot rows
    for i in range(L):
        add({i * P + p: 1.0 for p in range(P)}, 1.0, 1.0)

    # ring pairs exist only on ring-capable layers: pin the others' s to 0
    if ring_on:
        for i in range(L):
            if ring_layer[i]:
                continue
            for p, (_, _, rf) in enumerate(pairs):
                if rf:
                    ub[i * P + p] = 0.0

    # u constraints: two lower-bound rows per (layer, pass) whenever any
    # pair's bounds differ (the overlap schedules), one otherwise — the
    # single-schedule default emits exactly the pre-pair rows.  Ring
    # pairs bound u by the MLP-side schedule terms plus the overlapped
    # ring constant (both bounds shift by the same constant).
    for i in range(L):
        for off, dk, ck, fk, dmk, cmk, fmk, rk in (
                (0, d_f, c_f, fused_f, d_f_m, c_f_m, fused_f_m, ring_f),
                (L, d_b, c_b, fused_b, d_b_m, c_b_m, fused_b_m, ring_b)):
            u = nS + off + i
            b1 = np.zeros(P)
            b2 = np.zeros(P)
            for p, (j, sj, rf) in enumerate(pairs):
                if rf:
                    v1, v2 = _pair_pass_bounds(
                        scheds[sj], split, dmk[i, j], cmk[i, j], fmk[i, j])
                    b1[p], b2[p] = v1 + rk[i, j], v2 + rk[i, j]
                else:
                    b1[p], b2[p] = _pair_pass_bounds(
                        scheds[sj], split, dk[i, j], ck[i, j], fk[i, j])
            add({u: 1.0, **{i * P + p: -b1[p] for p in range(P)}},
                0.0, np.inf)
            if np.any(b2 != b1):
                add({u: 1.0, **{i * P + p: -b2[p] for p in range(P)}},
                    0.0, np.inf)

    # edge products + costs over degree pairs: y_e,dj,dk >= sum_{p in
    # pairs(dj)} s_a,p + sum_{p in pairs(dk)} s_b,p - 1
    deg_pairs = {j: [p for p, (dj, _, _) in enumerate(pairs) if dj == j]
                 for j in range(D)}
    for e, (a, b) in enumerate(edges):
        for j in range(D):
            for k in range(D):
                if options[j] == options[k]:
                    continue
                yi = nS + nU + e * D * D + j * D + k
                coefs = {yi: 1.0}
                for p in deg_pairs[j]:
                    coefs[a * P + p] = -1.0
                for p in deg_pairs[k]:
                    coefs[b * P + p] = coefs.get(b * P + p, 0.0) - 1.0
                add(coefs, -1.0, np.inf)
                nc_from = cm.NodeCosts(
                    [d_f[a, j]], [c_f[a, j]], [d_b[a, j]], [c_b[a, j]],
                    [0], [0])
                cost[yi] = cm.edge_cost(
                    cfg, shape, hw, options[j], options[k],
                    nc_from, 0, 0) * 2.0

    # Eq. 6 memory: sum_i s_i . mem_i + fixed <= cap (schedule-agnostic)
    vp = cfg.padded_vocab()
    max_total = max(cm._dtot(o) for o in options)
    fixed = vp * cfg.d_model * 2.0 / max_total * (2 if not cfg.tie_embeddings else 1)
    fixed *= 7.0  # + f32 optimizer states
    add({i * P + p: (mem_m[i, j] + mem_r[i, j]) if rf else mem[i, j]
         for i in range(L) for p, (j, _, rf) in enumerate(pairs)},
        -np.inf, mem_cap - fixed)

    A = lil_matrix((len(rows), N))
    for r, coefs in enumerate(rows):
        for c_idx, v in coefs.items():
            A[r, c_idx] = v
    con = LinearConstraint(A.tocsc(), np.array(lo), np.array(hi))
    # mip_rel_gap must sit below the tie-break epsilons or HiGHS stops at
    # an incumbent that still fragments degenerate ties
    res = milp(c=cost, constraints=con, integrality=integrality,
               bounds=(lb, ub),
               options={"time_limit": time_limit, "presolve": True,
                        "mip_rel_gap": 1e-9})
    solve_ms = (time.perf_counter() - t0) * 1e3

    if res.x is None:
        # infeasible (e.g. memory cap too tight at low degrees): fall back
        # to uniform max total degree (preferring a 1D int on ties)
        fb = max(options,
                 key=lambda o: (cm._dtot(o), not isinstance(o, tuple)))
        degrees = [fb] * L
        lsched = [scheds[0]] * L
        est = cm.estimate_iteration(cfg, shape, hp, degrees, hw, options,
                                    schedules=lsched)
        msh, max_ = _plan_mesh_sig(hw, degrees)
        return _telemetry_plan("plan", PlanResult(
            degrees, est["iter_s"], solve_ms,
            f"fallback:{res.status}", _runs(degrees),
            schedules=lsched,
            plan=_as_plan(hp, degrees, lsched,
                          mesh_shape=msh, mesh_axes=max_)))

    s = res.x[:nS].reshape(L, P)
    chosen = [pairs[int(np.argmax(s[i]))] for i in range(L)]
    degrees = [options[j] for j, _, _ in chosen]
    lsched = [scheds[sj] for _, sj, _ in chosen]
    lseqs = [int(cm._dtot(options[j])) if rf else 1 for j, _, rf in chosen]
    if any(q > 1 for q in lseqs):
        lseqs = _consolidate_seqs(cfg, degrees, lsched, lseqs)
    lsched, lseqs, est = _smooth_schedules(
        cfg, shape, hp, degrees, lsched, hw, options, scheds,
        lseqs=lseqs, ring_ok=ring_layer, mem_cap=mem_cap)
    msh, max_ = _plan_mesh_sig(hw, degrees)
    return _telemetry_plan("plan", PlanResult(
        degrees, est["iter_s"], solve_ms,
        str(res.status), _runs(degrees), schedules=lsched, seqs=lseqs,
        plan=_as_plan(hp, degrees, lsched, seqs=lseqs,
                      mesh_shape=msh, mesh_axes=max_)))


def replan(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
           hw: cm.HWConfig,
           options: Sequence[int] = (2, 4, 8, 16),
           mem_cap: Optional[float] = None,
           time_limit: float = 5.0,
           layout: str = "1d",
           schedules: Optional[Sequence[str]] = None,
           uniform: bool = True) -> PlanResult:
    """Mid-run replanning against a degraded topology
    (``HWConfig.degrade``): the elastic supervisor's planner entry point
    (runtime/elastic.py).

    Differences from :func:`plan`, all in the name of producing a plan
    that is guaranteed executable on whatever survived:

    * the option space is CLAMPED to the surviving chip count (each
      option rounds down to the largest power of two <= min(option,
      n_chips); degree 1 — no TMP — is the 1-chip limit case);
    * ``uniform=True`` (default) collapses a mixed-degree decision to its
      max-degree uniform strategy — a surviving mesh is relaunched as a
      plain ``(data, model)`` mesh, not the factored t-axis mesh that
      per-layer mixed degrees require — and records the mesh-following
      (degree ``None``) form so the plan runs on the relaunched mesh
      without a grouped parameter relayout;
    * a short default ``time_limit`` — this runs between training steps.
    """
    import math as _math

    def _clamp(n: int) -> int:
        n = max(min(int(n), hw.n_chips), 1)
        return 2 ** int(_math.log2(n))

    opts = sorted({_clamp(n) for n in options}) or [1]
    pr = plan(cfg, shape, hp, hw, options=opts, mem_cap=mem_cap,
              time_limit=time_limit, layout=layout, schedules=schedules)
    if not uniform:
        return _telemetry_plan("replan", pr)
    degrees, scheds = list(pr.degrees), list(pr.schedules)
    if len({(cm._dkey(d), s) for d, s in zip(degrees, scheds)}) > 1:
        # collapse like plan_joint: the max-degree strategy is the one
        # that satisfied Eq. 6 memory everywhere
        k = max(range(len(degrees)), key=lambda i: cm._dtot(degrees[i]))
        degrees = [degrees[k]] * len(degrees)
        scheds = [scheds[k]] * len(scheds)
        est = cm.estimate_iteration(cfg, shape, hp, degrees, hw, opts,
                                    schedules=scheds)
        pr = PlanResult(degrees, est["iter_s"], pr.solve_ms,
                        f"uniform-collapse:{pr.status}", _runs(degrees),
                        schedules=scheds)
    # mesh-following executable form: the decision lives in the mesh
    # signature (dp x tp), the layers follow the mesh — so the relaunched
    # trainer needs no factored axes and no grouped param layout
    from repro.core.plan import ParallelPlan
    msh, max_ = _mesh_sig(hw, 1, pr.degrees[0])
    pr.plan = ParallelPlan.from_hparams(
        hp, len(pr.degrees), schedules=list(pr.schedules),
        mesh_shape=msh, mesh_axes=max_)
    return _telemetry_plan("replan", pr)


# --------------------------------------------------------------------------
# joint PP x TMP search (the pipeline axis of core/pipeline.py)
# --------------------------------------------------------------------------
@dataclass
class JointPlanResult:
    pp: int                                # pipeline stages (1 = TMP-only)
    n_micro: int                           # 1F1B microbatch count
    virtual_stages: int
    degrees: List[object]                  # per-layer TMP degrees per stage
    predicted_s: float                     # composed pipeline iteration time
    tmp_s: float                           # the stage-internal TMP time
    bubble_fraction: float
    p2p_s: float
    mem_bytes: float
    fits: bool
    tmp_only_s: float                      # best pp=1 candidate (baseline)
    solve_ms: float
    status: str
    groups: List[Tuple[object, int]]
    schedules: Optional[List[str]] = None  # per-layer schedule names
    plan: Optional[object] = None          # executable ParallelPlan

    def summary(self) -> str:
        runs = " + ".join(f"[{_fmt_degree(d)}] * {n}"
                          for d, n in self.groups)
        return (f"pp={self.pp} x [{runs}] m={self.n_micro} "
                f"v={self.virtual_stages} predicted "
                f"{self.predicted_s*1e3:.1f} ms/iter (bubble "
                f"{self.bubble_fraction*100:.1f}%, p2p "
                f"{self.p2p_s*1e3:.2f} ms; tmp-only "
                f"{self.tmp_only_s*1e3:.1f} ms; {self.status})")


def _default_pp_options(cfg: ArchConfig, hw: cm.HWConfig,
                        virtual_stages: int = 1) -> List[int]:
    """Power-of-two stage counts that divide both the chips and the
    EXECUTABLE layer unit — the scan-group count num_layers/|pattern|
    (models/params.stack_layout), which is what
    core/pipeline.validate_stage_layout enforces at training time — capped
    at 8 (deeper pipes need more microbatches than the Eq. 3 shapes
    carry)."""
    v = max(virtual_stages, 1)
    pat = max(len(cfg.layer_pattern), 1)
    groups = cfg.num_layers // pat if cfg.num_layers % pat == 0 else 0
    out = [1]
    p = 2
    while p <= min(hw.n_chips // 2, 8):
        if hw.n_chips % p == 0 and groups and groups % (p * v) == 0:
            out.append(p)
        p *= 2
    return out


def _default_microbatch_options(pp: int, v: int,
                                shape: ShapeConfig) -> List[int]:
    """Candidate 1F1B microbatch counts: pp..8*pp*v, divisors of the
    global batch (more microbatches shrink the bubble; fewer keep each
    matmul fat — the search arbitrates via the cost model)."""
    if pp == 1:
        return [0]                        # resolve_hp semantics (auto)
    out = [m for m in (pp, 2 * pp, 4 * pp * v, 8 * pp * v)
           if m <= shape.global_batch and shape.global_batch % m == 0]
    seen: List[int] = []
    for m in out:
        if m not in seen:
            seen.append(m)
    if seen:
        return seen
    # no power-of-two-ish candidate divides the batch: fall back to the
    # largest divisor <= pp so the winning plan stays executable
    # (resolve_microbatch rejects non-divisors at training time)
    m = min(pp, shape.global_batch)
    while m > 1 and shape.global_batch % m:
        m -= 1
    return [m]


def plan_joint(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
               hw: cm.HWConfig = cm.V5E,
               options: Sequence[int] = (2, 4, 8, 16),
               mem_cap: Optional[float] = None,
               time_limit: float = 20.0,
               layout: str = "auto",
               pp_options: Optional[Sequence[int]] = None,
               virtual_stages: int = 1,
               schedules: Optional[Sequence[str]] = None) -> JointPlanResult:
    """Joint (pp, per-stage TMP degrees, microbatch count) search.

    ``options`` name the TOTAL model-parallel capacity exactly as in
    :func:`plan` — a pp-stage candidate searches per-stage TMP degrees
    ``option / pp``, which hold per-chip weight memory constant across
    candidates (a stage owns 1/pp of the layers), so ``options=(16,)``
    expresses the same "weights must spread over 16 chips" regime whether
    the spread is one 16-way ring or 2 stages x 8-way rings.

    For every candidate stage count the per-layer TMP ILP runs on the
    *stage's* hardware slice (n_chips/pp chips, same node topology), then
    the pipeline-bubble + P2P terms compose the stage time into an
    iteration estimate (:func:`costmodel.pipeline_time`).  On commodity
    fixtures this is the AMP decision: stages across boxes (activations,
    thin) x TMP within a box (weight collectives, fat); on a uniform
    NVLink box the bubble buys nothing and the search stays TMP-only.
    Ties break toward lower pp, then fewer microbatches.
    """
    import dataclasses as _dc
    t0 = time.perf_counter()
    cap = mem_cap if mem_cap is not None else hw.hbm_cap
    v = max(virtual_stages, 1)
    pps = list(pp_options) if pp_options is not None \
        else _default_pp_options(cfg, hw, v)
    candidates: List[JointPlanResult] = []
    # (pp, m, opts) worklist first, so the per-ILP budget spreads
    # time_limit across ALL solves (floored at 1 s each — HiGHS under a
    # sub-second cap returns junk incumbents, so a long worklist can
    # overrun a very small time_limit by up to len(work) seconds)
    work: List[Tuple[int, int, List[int]]] = []
    for pp in pps:
        chips = max(hw.n_chips // pp, 1)
        # clamp (not filter) to the stage's chip count so tiny hosts —
        # e.g. a 1-device --calibrate run — still get a plan
        opts = sorted({min(max(int(n) // pp, 1), chips) for n in options})
        if not opts:
            continue
        for m in _default_microbatch_options(pp, v, shape):
            work.append((pp, m, opts))
            if pp == 1:
                break                      # microbatch=auto covers pp=1
    per_solve = max(time_limit / max(len(work), 1), 1.0)
    for pp, m, opts in work:
        hw_s = cm.stage_hw(hw, pp)
        hp_m = _dc.replace(hp, microbatch=m,
                           virtual_stages=v if pp > 1 else 1)
        pr = plan(cfg, shape, hp_m, hw_s, options=opts,
                  mem_cap=cap, time_limit=per_solve, layout=layout,
                  stages=pp, schedules=schedules)
        deg_max = max(cm._dtot(d) for d in pr.degrees)
        # executability: the runtime (pipeline.resolve_microbatch) needs
        # n_micro to divide the PER-SHARD batch under this plan's dp, not
        # just the global batch — clamp to the largest dividing count
        dp = max((hw.n_chips // pp) // max(deg_max, 1), 1)
        local = max(shape.global_batch // dp, 1)
        n_micro = min(max(m, 1), local)
        while n_micro > 1 and local % n_micro:
            n_micro -= 1
        if n_micro != max(m, 1):
            # the candidate's costs must describe the clamped count, not
            # the one the ILP was seeded with
            hp_m = _dc.replace(hp_m, microbatch=n_micro)
        # executable plan: a pp>1 plan must be strategy-uniform (stage-
        # internal TMP is uniform per stage) — collapse to the dominant
        # (max-degree) strategy when the per-stage ILP mixed, and rank the
        # candidate on the COLLAPSED strategy (what would actually run),
        # not the inexecutable mixed one
        pdeg, psched = list(pr.degrees), list(pr.schedules)
        if pp > 1 and len({(cm._dkey(d), s)
                           for d, s in zip(pdeg, psched)}) > 1:
            k = max(range(len(pdeg)), key=lambda i: cm._dtot(pdeg[i]))
            pdeg = [pdeg[k]] * len(pdeg)
            psched = [psched[k]] * len(psched)
        est = cm.estimate_iteration(cfg, shape, hp_m, pdeg,
                                    hw_s, opts, stages=pp,
                                    schedules=psched)
        t_hop = cm.p2p_hop_seconds(cfg, shape, hw, pp, n_micro,
                                   deg_max) if pp > 1 else 0.0
        total, bfrac, p2p = cm.pipeline_time(est["iter_s"], pp,
                                             n_micro, v, t_hop)
        candidates.append(JointPlanResult(
            pp=pp, n_micro=n_micro,
            virtual_stages=v if pp > 1 else 1,
            degrees=pdeg, predicted_s=total,
            tmp_s=est["iter_s"], bubble_fraction=bfrac, p2p_s=p2p,
            mem_bytes=est["mem_bytes"],
            fits=est["mem_bytes"] < cap,
            tmp_only_s=0.0, solve_ms=0.0, status=pr.status,
            groups=_runs(pdeg), schedules=psched,
            plan=_as_plan(hp, pdeg, psched, pp=pp,
                          virtual_stages=v if pp > 1 else 1,
                          microbatch=n_micro if pp > 1 else hp.microbatch,
                          **(dict(zip(("mesh_shape", "mesh_axes"),
                                      _mesh_sig(hw, pp, pdeg[0])))
                             if pp > 1 else {}))))
    if not candidates:
        raise ValueError(
            f"no feasible (pp, degree) candidates for {cfg.name} on "
            f"{hw.n_chips} chips with options {tuple(options)}")
    fitting = [c for c in candidates if c.fits] or candidates
    best = min(fitting, key=lambda c: (c.predicted_s, c.pp, c.n_micro))
    tmp_only = [c for c in candidates if c.pp == 1]
    best.tmp_only_s = min(c.predicted_s for c in tmp_only) if tmp_only \
        else float("inf")
    best.solve_ms = (time.perf_counter() - t0) * 1e3
    return _telemetry_plan("plan_joint", best)


# --------------------------------------------------------------------------
# serving-mesh search (objective="latency")
# --------------------------------------------------------------------------
@dataclass
class ServingPlanResult:
    degree: object                         # per-stage TMP degree: int | (dx, dy)
    pp: int                                # pipeline stages (1 = TMP-only)
    n_micro: int                           # decode micro-groups in flight
    predicted_s: float                     # per-engine-step (per-token) latency
    tok_per_s: float                       # batch tokens per step / latency
    mem_bytes: float
    fits: bool
    tmp_only_s: float                      # best pp=1 candidate (baseline)
    solve_ms: float
    status: str
    plan: Optional[object] = None          # executable ParallelPlan
    spec_k: int = 0                        # chosen speculative depth (0 = off)
    page_size: int = 0                     # paged-KV block size (0 = dense)

    @property
    def dxy(self) -> Tuple[int, int]:
        return cm._dxy(self.degree)

    def summary(self) -> str:
        spec = f" spec_k={self.spec_k}" if self.spec_k else ""
        return (f"serve pp={self.pp} x [{_fmt_degree(self.degree)}]"
                f"{spec} m={self.n_micro} predicted "
                f"{self.predicted_s*1e3:.2f} ms/token "
                f"({self.tok_per_s:.0f} tok/s; tmp-only "
                f"{self.tmp_only_s*1e3:.2f} ms; {self.status})")


def plan_serving(cfg: ArchConfig, shape: ShapeConfig, hp: TrainHParams,
                 hw: cm.HWConfig = cm.V5E,
                 options: Sequence[int] = (2, 4, 8, 16),
                 mem_cap: Optional[float] = None,
                 layout: str = "auto",
                 pp_options: Optional[Sequence[int]] = None,
                 virtual_stages: int = 1,
                 spec_options: Sequence[int] = (0,),
                 draft: Optional[ArchConfig] = None,
                 spec_accept: float = 0.8,
                 page_size: int = 0) -> ServingPlanResult:
    """Search ``(dx, dy, pp)`` serving meshes for minimum per-token decode
    latency (``costmodel.decode_step_time``).

    ``options`` name the TOTAL model-parallel capacity exactly as in
    :func:`plan`/:func:`plan_joint`: a pp-stage candidate shards each
    stage ``option / pp`` ways, holding per-chip weight memory constant
    across candidates.  ``shape`` describes the serving point —
    ``global_batch`` concurrent decode slots at KV context ``seq_len``
    (e.g. ``configs.base.DECODE_32K``).  At these shapes collectives are
    latency-bound, so on commodity fixtures wide 1D rings that span boxes
    lose to 2D splits or cross-box pipeline stages; on a uniform NVLink
    box the 1D ring stays optimal.  Ties break toward fewer stages, then
    the 1D layout, then the thinnest y split, then the smallest spec_k.

    ``spec_options`` adds speculative depths to the search (``draft`` is
    the proposer ArchConfig, required for any k > 0; ``spec_accept`` is
    the modeled per-token acceptance rate).  Speculation composes with
    pp=1 candidates only (``lm.build_verify`` rejects pipe meshes), so a
    pipeline candidate competes at k=0.  The latency floor the verify
    amortizes is exactly the per-layer collective latency, so commodity
    fixtures pick k > 1 while a uniform fast box keeps k at 0 or 1
    (pinned in tests/test_planner_golden.py).  ``page_size`` threads the
    paged-KV gather discount into every candidate.
    """
    t0 = time.perf_counter()
    cap = mem_cap if mem_cap is not None else hw.hbm_cap
    v = max(virtual_stages, 1)
    spec_ks = sorted({int(k) for k in spec_options})
    if any(k > 0 for k in spec_ks) and draft is None:
        raise ValueError(
            f"spec_options {tuple(spec_options)} include k > 0 but no "
            f"draft model was given — pass draft=<ArchConfig> (e.g. "
            f"get_config('gpt-draft-h2048'))")
    candidates = []
    for n_total in (int(n) for n in options):
        pps = list(pp_options) if pp_options is not None \
            else _default_pp_options(cfg, hw, v)
        for pp in pps:
            if n_total % pp or n_total // pp < 1:
                continue
            n_s = n_total // pp
            for deg in expand_options(cfg, hw, [n_s], layout):
                for k in spec_ks:
                    if k > 0 and pp > 1:
                        continue
                    est = cm.decode_step_time(
                        cfg, shape, hp, hw, deg, pp, virtual_stages=v,
                        page_size=page_size, spec_k=k,
                        spec_accept=spec_accept,
                        draft=draft if k > 0 else None)
                    dx, dy = cm._dxy(deg)
                    fits = est["mem_bytes"] < cap
                    candidates.append((est["step_s"], pp, dy, dx, k, deg,
                                       est, fits))
    if not candidates:
        raise ValueError(
            f"no feasible (degree, pp) serving candidates for {cfg.name} "
            f"on {hw.n_chips} chips with options {tuple(options)}")
    fitting = [c for c in candidates if c[7]] or candidates
    best = min(fitting, key=lambda c: c[:5])
    tmp_only = [c for c in candidates if c[1] == 1 and c[4] == 0]
    _, pp, _, _, spec_k, deg, est, fits = best
    return _telemetry_plan("plan_serving", ServingPlanResult(
        degree=deg, pp=pp, n_micro=est["n_micro"],
        predicted_s=est["step_s"], tok_per_s=est["tok_per_s"],
        mem_bytes=est["mem_bytes"], fits=fits,
        tmp_only_s=min(c[0] for c in tmp_only) if tmp_only else float("inf"),
        solve_ms=(time.perf_counter() - t0) * 1e3,
        status="fits" if fits else "over-memory",
        spec_k=spec_k, page_size=page_size,
        plan=_as_plan(hp, [deg] * cfg.num_layers,
                      [hp.schedule] * cfg.num_layers, pp=pp,
                      virtual_stages=v if pp > 1 else 1,
                      decode_micro=est["n_micro"] if pp > 1 else 0,
                      **dict(zip(("mesh_shape", "mesh_axes"),
                                 _mesh_sig(hw, pp, deg))))))
