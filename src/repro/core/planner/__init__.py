from repro.core.planner.costmodel import (COMMODITY_25GBE, HWConfig,
                                          NVLINK_BOX, V5E,
                                          estimate_iteration, layer_blocks,
                                          node_costs, overlapped_time,
                                          overlapped_time_2d)
from repro.core.planner.ilp import PlanResult, expand_options, plan

__all__ = ["COMMODITY_25GBE", "HWConfig", "NVLINK_BOX", "V5E",
           "estimate_iteration", "layer_blocks", "node_costs",
           "overlapped_time", "overlapped_time_2d", "PlanResult",
           "expand_options", "plan"]
