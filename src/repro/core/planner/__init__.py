from repro.core.planner.costmodel import (HWConfig, V5E, estimate_iteration,
                                          layer_blocks, node_costs,
                                          overlapped_time)
from repro.core.planner.ilp import PlanResult, plan

__all__ = ["HWConfig", "V5E", "estimate_iteration", "layer_blocks",
           "node_costs", "overlapped_time", "PlanResult", "plan"]
