from repro.core.planner.costmodel import (COMMODITY_25GBE, HWConfig,
                                          NVLINK_BOX, V5E, decode_step_time,
                                          estimate_iteration, layer_blocks,
                                          node_costs, overlapped_time,
                                          overlapped_time_2d,
                                          p2p_hop_seconds, pipeline_time,
                                          stage_hw)
from repro.core.planner.calibrate import calibrated_hw
from repro.core.planner.ilp import (JointPlanResult, PlanResult,
                                    ServingPlanResult, expand_options, plan,
                                    plan_joint, plan_serving, replan)

__all__ = ["COMMODITY_25GBE", "HWConfig", "NVLINK_BOX", "V5E",
           "calibrated_hw", "decode_step_time", "estimate_iteration",
           "layer_blocks", "node_costs", "overlapped_time",
           "overlapped_time_2d", "p2p_hop_seconds", "pipeline_time",
           "stage_hw", "JointPlanResult", "PlanResult",
           "ServingPlanResult", "expand_options", "plan", "plan_joint",
           "plan_serving", "replan"]
