"""Per-host cached planner calibration (ROADMAP item 3 / AMP §2210.07297:
cost models only transfer when calibrated per cluster).

:func:`calibrated_hw` is the launchers' default path to a planner
:class:`~repro.core.planner.costmodel.HWConfig`: it runs the
``HWConfig.measure_fields`` micro-benches once per host and memoizes the
raw measurements in a JSON cache keyed by a host fingerprint (hostname,
backend platform, device kind/count, jax version), so repeated planner
invocations — every ``train.py --planner`` / ``dryrun.py`` run, every CI
job on the same runner image — pay the profiling cost once.

Caller ``overrides`` are applied ON TOP of the cached measurements at
load time (they are never baked into the cache): calibrate the chip, keep
the caller's cluster description (``n_chips``, ``node_size``,
``link_bw_y``...).

Escape hatches:

* ``--no-calibrate`` on the launchers — stock chip numbers, no profiling;
* ``REPRO_NO_CALIBRATE=1`` — same, for test/CI environments;
* ``REPRO_CAL_CACHE=<dir>`` — relocate the cache (default
  ``~/.cache/repro-oases``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional

from repro.core.planner.costmodel import HWConfig

_ENV_DISABLE = "REPRO_NO_CALIBRATE"
_ENV_CACHE = "REPRO_CAL_CACHE"
_MEM_CACHE: Dict[str, Dict[str, float]] = {}    # fingerprint -> fields


def host_fingerprint() -> str:
    """Identity of the measurement: same fingerprint == same expected
    micro-bench results.  Device kind/count and backend catch accelerator
    changes; the jax version catches dispatch-overhead changes (the CPU
    numbers are dominated by it)."""
    import platform as _platform

    import jax
    devs = jax.devices()
    kind = devs[0].device_kind.replace(" ", "_") if devs else "none"
    return "-".join([
        _platform.node() or "unknown-host",
        jax.default_backend(),
        kind,
        f"d{len(devs)}",
        f"jax{jax.__version__}",
    ])


def cache_dir() -> str:
    return os.environ.get(_ENV_CACHE) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-oases")


def cache_path(fingerprint: Optional[str] = None) -> str:
    fp = fingerprint or host_fingerprint()
    safe = "".join(c if (c.isalnum() or c in "._-") else "_" for c in fp)
    return os.path.join(cache_dir(), f"hwcal-{safe}.json")


def _load(path: str, fingerprint: str) -> Optional[Dict[str, float]]:
    try:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("fingerprint") != fingerprint:
            return None
        fields = rec.get("fields")
        return dict(fields) if isinstance(fields, dict) else None
    except (OSError, ValueError):
        return None


def _store(path: str, fingerprint: str, fields: Dict[str, float]) -> None:
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"fingerprint": fingerprint, "time": time.time(),
                       "fields": fields}, f, indent=1)
        os.replace(tmp, path)       # atomic: concurrent runs never tear
    except OSError:
        pass                        # cache is an optimization, not a need


def calibrated_hw(*, cache: bool = True, max_devices: int = 8,
                  repeats: int = 5, **overrides) -> HWConfig:
    """A measurement-calibrated :class:`HWConfig` for this host, cached.

    ``overrides`` win over (cached or fresh) measurements and are applied
    at load time.  With ``REPRO_NO_CALIBRATE`` set the measurements are
    skipped entirely and the overrides alone configure a stock
    :class:`HWConfig` — the launchers' ``--no-calibrate`` equivalent for
    environments where even a cached profile is unwanted.
    """
    if os.environ.get(_ENV_DISABLE):
        return HWConfig(**overrides)
    fp = host_fingerprint()
    fields = _MEM_CACHE.get(fp) if cache else None
    if fields is None and cache:
        fields = _load(cache_path(fp), fp)
    if fields is None:
        fields = HWConfig.measure_fields(max_devices=max_devices,
                                         repeats=repeats)
        if cache:
            _store(cache_path(fp), fp, fields)
    if cache:
        _MEM_CACHE[fp] = dict(fields)
    merged = {**fields, **overrides}
    if merged.get("node_size") and merged.get("n_chips"):
        merged["node_size"] = min(int(merged["node_size"]),
                                  int(merged["n_chips"]))
    return HWConfig(**merged)


def describe(hw: HWConfig) -> Dict[str, object]:
    """Loggable view of a calibrated config (floats rounded to 3 s.f.)."""
    out = {}
    for k, v in dataclasses.asdict(hw).items():
        out[k] = float(f"{v:.3g}") if isinstance(v, float) else v
    return out
