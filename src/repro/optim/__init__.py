from repro.optim.adamw import (AdamWConfig, abstract_opt_state, apply_updates,
                               init_opt_state, opt_state_specs)

__all__ = ["AdamWConfig", "abstract_opt_state", "apply_updates",
           "init_opt_state", "opt_state_specs"]
