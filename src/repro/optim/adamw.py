"""AdamW with mixed precision, ZeRO-1 state sharding, global-norm clipping,
cosine LR schedule, and optional int8 gradient compression with error
feedback (beyond-paper distributed-optimization tricks).

Pure-JAX pytree implementation (no optax dependency).  The optimizer step is
meant to run OUTSIDE shard_map (plain jit); sharding of states is declared
via NamedShardings derived from the param spec tree.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.axes import MeshInfo
from repro.models import params as prm


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to 10%."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return cfg.learning_rate * warm * cos


# --------------------------------------------------------------------------
# state specs (ZeRO-1: shard f32 master/m/v over the data axes too)
# --------------------------------------------------------------------------
def _zero1_pspec(spec: prm.Spec, info: MeshInfo, enable: bool) -> P:
    """Additionally shard the largest replicated dim over the batch axes."""
    entries = list(spec.pspec) + [None] * (len(spec.shape) - len(spec.pspec))
    if not enable or not info.batch_axes:
        return P(*entries)
    dp = info.dp
    for i, (e, dim) in enumerate(zip(entries, spec.shape)):
        if e is None and dim % dp == 0 and dim >= dp:
            entries[i] = info.batch_axes if len(info.batch_axes) > 1 \
                else info.batch_axes[0]
            break
    return P(*entries)


def opt_state_specs(param_specs, info: MeshInfo, *, zero1: bool = True):
    """Spec tree for (master, m, v) — all f32, ZeRO-1 sharded."""
    def one(s: prm.Spec):
        ps = _zero1_pspec(s, info, zero1)
        return prm.Spec(s.shape, ps, jnp.float32, s.scale)
    f32 = prm.tree_map_specs(one, param_specs)
    return {"master": f32, "m": f32, "v": f32,
            "step": prm.Spec((), P(), jnp.int32, 0.0),
            "err": None}  # error-feedback buffers added when compression on


def init_opt_state(params, param_specs, info: MeshInfo, *, zero1: bool = True):
    specs = opt_state_specs(param_specs, info, zero1=zero1)
    def zeros(tree):
        return prm.tree_map_specs(
            lambda s: jnp.zeros(s.shape, s.dtype), tree)

    return {
        "master": jax.tree_util.tree_map(
            lambda w: w.astype(jnp.float32), params),
        "m": zeros(specs["m"]),
        "v": zeros(specs["v"]),
        "step": jnp.zeros((), jnp.int32),
        "err": None,
    }


def abstract_opt_state(param_specs, info: MeshInfo, mesh, *,
                       zero1: bool = True):
    specs = opt_state_specs(param_specs, info, zero1=zero1)
    def mk(tree):
        return prm.tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, s.pspec)), tree)
    return {"master": mk(specs["master"]), "m": mk(specs["m"]),
            "v": mk(specs["v"]),
            "step": jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(mesh, P())),
            "err": None}


# --------------------------------------------------------------------------
def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def compress_int8(g, err):
    """Int8 stochastic-free quantization with error feedback."""
    gf = g.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq


def apply_updates(params, grads, opt_state, cfg: AdamWConfig, *,
                  compress: bool = False, zero_shardings=None,
                  param_shardings=None):
    """One AdamW step.  Returns (new_params, new_opt_state, grad_norm).

    ``zero_shardings``/``param_shardings``: NamedSharding trees.  When given,
    the f32 grads and the m/v/master update run in the ZeRO-sharded layout
    (per-chip 1/dp size) and the master->param cast happens BEFORE the
    gather back to the replicated param layout — without this, XLA
    materializes three full-size f32 state tensors per chip (§Perf)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)

    def _c(tree, shardings):
        if shardings is None:
            return tree
        return jax.tree_util.tree_map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s),
            tree, shardings)

    grads = _c(jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32), grads), zero_shardings)
    if compress:
        err = opt_state["err"] or jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        pairs = jax.tree_util.tree_map(compress_int8, grads, err)
        grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = opt_state["err"]

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-6))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(w32, m, v, g):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        neww = w32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                           + cfg.weight_decay * w32 * (w32.ndim > 1))
        return neww, m, v

    out = jax.tree_util.tree_map(upd, opt_state["master"], opt_state["m"],
                                 opt_state["v"], grads)
    master = _c(jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)),
        zero_shardings)
    m = _c(jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)),
        zero_shardings)
    v = _c(jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple)),
        zero_shardings)
    # cast to the param dtype BEFORE the ZeRO->replicated gather so the
    # all-gather moves bf16, not f32
    new_params = jax.tree_util.tree_map(
        lambda w32, w: w32.astype(w.dtype), master, params)
    new_params = _c(new_params, param_shardings)
    return new_params, {"master": master, "m": m, "v": v, "step": step,
                        "err": new_err}, gnorm
