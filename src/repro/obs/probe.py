"""Runtime overlap-efficiency probe.

The paper's speedup claim is "TMP communication hides under compute";
the planner *predicts* how much hides (``costmodel.overlapped_time`` and
the per-schedule exposed-cost terms of ``estimate_iteration``), but
until now nothing *measured* it online.  This probe closes that loop:

1. :func:`plan_group_model` mirrors the cost model's per-schedule pass
   formulas per executable layer group (the same grouping the trainer
   runs, ``models/params.plan_groups``), yielding per-group compute
   seconds, physical collective seconds, and the *predicted* exposed-
   communication fraction.
2. :class:`OverlapProbe.report` takes a *measured* iteration time (the
   trainer's median step wall time), subtracts the modeled compute floor
   to get the measured exposed-communication total, attributes it to
   groups by their collective-seconds share, and emits per-group
   ``overlap.group`` events carrying measured vs predicted exposed
   fraction and the residual against the calibrated model's prediction.
3. Residual drift beyond ``stale_threshold`` emits a
   ``calibration_stale`` event pointing at the per-host calibration
   cache (``core/planner/calibrate.py``) — AMP's observation that cost
   models drift per cluster, now checked continuously instead of only in
   the offline bench tier (DESIGN.md §10).

The group model covers the layer stack (the planner's Eq. 3 domain);
embedding/head/edge costs live in the residual by construction, which is
why the stale threshold defaults loose — the signal is *drift*, not
absolute agreement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.obs.recorder import NULL


@dataclass(frozen=True)
class GroupModel:
    """Modeled per-layer-group quantities (whole iteration: fwd + bwd)."""
    label: str                  # e.g. "g0:attn[8/oases]x12"
    kind: str
    schedule: str
    degree: object              # int | (dx, dy)
    layers: int
    compute_s: float            # modeled compute floor (comm fully hidden)
    comm_s: float               # physical collective seconds (all passes)
    predicted_s: float          # schedule-aware predicted group time

    @property
    def predicted_exposed_s(self) -> float:
        return max(self.predicted_s - self.compute_s, 0.0)

    @property
    def predicted_exposed_frac(self) -> float:
        return self.predicted_exposed_s / self.comm_s if self.comm_s else 0.0


def _group_pass(items, split: int, dkey: str, ckey: str, cykey: str) -> float:
    """One pass (fwd or bwd) over a group's blocks — the same per-schedule
    branches as ``costmodel.estimate_iteration``'s pass_time, with the
    trailing overlap-run cool-down exposed at the group boundary (the
    conservatism grouped execution actually shows at transitions)."""
    from repro.core.planner import costmodel as cm
    total = 0.0
    prev_c = 0.0
    for nc, degree, sched in items:
        d = getattr(nc, dkey)[0]
        c = getattr(nc, ckey)[0]
        if split > 1 and sched in ("oases", "merak"):
            total += max(d, prev_c) + max(d, c)
            prev_c = c
        elif sched == "fused":
            dx, _dy = cm._dxy(degree)
            c_y = getattr(nc, cykey)[0]
            total += prev_c
            total += cm.overlapped_time_2d(split * d, split * (c - c_y),
                                           split * c_y, dx - 1)
            prev_c = 0.0
        elif sched == "wang":
            total += prev_c
            prev_c = 0.0
            total += split * d + c / max(split * 2, 1) + c * 0.1
        else:
            total += prev_c
            total += split * d + split * c
            prev_c = 0.0
    return total + prev_c


def plan_group_model(cfg, shape, hp, hw, degrees: Sequence,
                     schedules: Optional[Sequence[str]] = None
                     ) -> List[GroupModel]:
    """Per-executable-layer-group cost decomposition of a concrete plan.

    ``degrees`` must be concrete (the caller resolves mesh-following
    ``None`` entries to the mesh's model-group size before probing)."""
    from repro.core.planner import costmodel as cm
    from repro.models import params as prm

    split = max(hp.split, 1)
    blocks = cm.layer_blocks(cfg, shape)
    scheds = (list(schedules) if schedules is not None
              else [hp.schedule] * cfg.num_layers)
    out: List[GroupModel] = []
    li = 0
    for gi, g in enumerate(prm.plan_groups(cfg, list(degrees), scheds)):
        items = []
        compute = comm = 0.0
        for layer in blocks[li:li + g.count]:
            for blk in layer:
                nc = cm.node_costs(cfg, blk, shape, hp, hw, [g.degree])
                items.append((nc, g.degree, g.schedule))
                compute += split * (nc.d_f[0] + nc.d_b[0])
                comm += split * (nc.c_f[0] + nc.c_b[0])
        li += g.count
        predicted = (_group_pass(items, split, "d_f", "c_f", "c_f_y")
                     + _group_pass(items, split, "d_b", "c_b", "c_b_y"))
        dxs = cm._dkey(g.degree)
        out.append(GroupModel(
            label=f"g{gi}:{g.kind}[{dxs}/{g.schedule}]x{g.count}",
            kind=g.kind, schedule=g.schedule, degree=g.degree,
            layers=g.count, compute_s=compute, comm_s=comm,
            predicted_s=predicted))
    return out


class OverlapProbe:
    """Measured-vs-modeled overlap accounting over a run's layer groups.

    ``stale_threshold``: relative model residual beyond which a
    ``calibration_stale`` event fires (default 0.5 — the group model
    deliberately excludes embedding/head/edge terms, so the useful signal
    is drift over time, not absolute agreement)."""

    def __init__(self, groups: Sequence[GroupModel], *,
                 stale_threshold: float = 0.5,
                 hw_note: str = ""):
        self.groups = list(groups)
        self.stale_threshold = stale_threshold
        self.hw_note = hw_note

    @classmethod
    def for_run(cls, cfg, shape, hp, hw, degrees,
                schedules=None, **kw) -> "OverlapProbe":
        return cls(plan_group_model(cfg, shape, hp, hw, degrees, schedules),
                   **kw)

    def report(self, measured_iter_s: float, recorder=None, *,
               step: Optional[int] = None) -> Dict:
        """Decompose one measured iteration time; emits telemetry through
        ``recorder`` (one ``overlap.group`` event per group, overall
        gauges, and ``calibration_stale`` on drift) and returns the
        decomposition for in-process consumers/tests."""
        rec = recorder if recorder is not None else NULL
        compute_t = sum(g.compute_s for g in self.groups)
        comm_t = sum(g.comm_s for g in self.groups)
        model_t = sum(g.predicted_s for g in self.groups)
        if comm_t <= 0.0 or model_t <= 0.0:
            rec.event("overlap.skip",
                      msg="[overlap] no collective communication in this "
                          "plan — probe has nothing to measure",
                      step=step)
            return {"groups": [], "skipped": "no-comm"}
        # the comm seconds the run failed to hide: measured time above the
        # modeled compute floor, clamped into [0, total collective time]
        exposed_t = min(max(measured_iter_s - compute_t, 0.0), comm_t)
        rows = []
        for g in self.groups:
            share = g.comm_s / comm_t
            meas_exposed = exposed_t * share
            meas_frac = meas_exposed / g.comm_s
            meas_s = g.compute_s + meas_exposed
            residual = (meas_s - g.predicted_s) / g.predicted_s \
                if g.predicted_s > 0 else 0.0
            row = {"group": g.label, "kind": g.kind,
                   "schedule": g.schedule, "layers": g.layers,
                   "compute_s": g.compute_s, "comm_s": g.comm_s,
                   "predicted_exposed_frac": g.predicted_exposed_frac,
                   "measured_exposed_frac": meas_frac,
                   "residual": residual}
            rows.append(row)
            rec.event("overlap.group", step=step, **{
                k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in row.items()})
        overall_meas_frac = exposed_t / comm_t
        overall_residual = (measured_iter_s - model_t) / model_t
        rec.gauge("overlap.measured_exposed_frac", overall_meas_frac,
                  step=step)
        rec.gauge("overlap.model_residual", overall_residual, step=step)
        stale = abs(overall_residual) > self.stale_threshold
        if stale:
            rec.event(
                "calibration_stale",
                msg=(f"[overlap] measured iteration {measured_iter_s*1e3:.1f}"
                     f" ms vs modeled {model_t*1e3:.1f} ms "
                     f"(residual {overall_residual:+.0%} > "
                     f"±{self.stale_threshold:.0%}) — the calibrated cost "
                     f"model looks stale for this host; re-run calibration "
                     f"(core/planner/calibrate.calibrated_hw; delete the "
                     f"hwcal cache under REPRO_CAL_CACHE or "
                     f"~/.cache/repro-oases)"
                     + (f" [{self.hw_note}]" if self.hw_note else "")),
                step=step, residual=round(overall_residual, 4),
                threshold=self.stale_threshold)
        return {"groups": rows,
                "measured_iter_s": measured_iter_s,
                "modeled_iter_s": model_t,
                "compute_s": compute_t, "comm_s": comm_t,
                "measured_exposed_frac": overall_meas_frac,
                "model_residual": overall_residual,
                "calibration_stale": stale}
