"""Telemetry record schema: one JSONL line per record.

Kept as a hand-rolled validator (no jsonschema dependency in the image):
the CI smoke (`train.py --steps 3 --telemetry` -> ``repro.obs.report
--validate``) and tests/test_obs.py both run every emitted line through
:func:`validate_record`, so the schema IS enforced, just without the
library.

Record shape::

    {"ts": <float unix-seconds>,
     "kind": "counter" | "gauge" | "histogram" | "event" | "span",
     "name": "<dotted.metric.name>",
     # kind-dependent:
     "value": <number>,          # counter / gauge / histogram
     "dur_s": <number >= 0>,     # span
     "msg": "<human line>",      # event (optional)
     "tags": {str: str|num|bool|null}}   # optional, flat
"""
from __future__ import annotations

from typing import Dict, List

from repro.obs.recorder import KINDS

_NUM = (int, float)


class SchemaError(ValueError):
    pass


def validate_record(rec: Dict) -> Dict:
    """Validate one parsed JSONL record; returns it, raises
    :class:`SchemaError` naming the violated field otherwise."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record is not an object: {rec!r}")
    for req in ("ts", "kind", "name"):
        if req not in rec:
            raise SchemaError(f"missing required field {req!r}: {rec!r}")
    if not isinstance(rec["ts"], _NUM):
        raise SchemaError(f"ts must be numeric: {rec['ts']!r}")
    kind = rec["kind"]
    if kind not in KINDS:
        raise SchemaError(f"unknown kind {kind!r} (valid: {KINDS})")
    if not isinstance(rec["name"], str) or not rec["name"]:
        raise SchemaError(f"name must be a non-empty string: {rec!r}")
    if kind in ("counter", "gauge", "histogram"):
        if not isinstance(rec.get("value"), _NUM):
            raise SchemaError(f"{kind} record needs a numeric value: {rec!r}")
    if kind == "span":
        if not isinstance(rec.get("dur_s"), _NUM) or rec["dur_s"] < 0:
            raise SchemaError(f"span record needs dur_s >= 0: {rec!r}")
    if "msg" in rec and not isinstance(rec["msg"], str):
        raise SchemaError(f"msg must be a string: {rec!r}")
    tags = rec.get("tags")
    if tags is not None:
        if not isinstance(tags, dict):
            raise SchemaError(f"tags must be an object: {rec!r}")
        for k, v in tags.items():
            if not isinstance(k, str):
                raise SchemaError(f"tag key must be a string: {k!r}")
            if v is not None and not isinstance(v, (str, bool) + _NUM):
                raise SchemaError(
                    f"tag value must be scalar (str/num/bool/null), got "
                    f"{k}={v!r}")
    allowed = {"ts", "kind", "name", "value", "dur_s", "msg", "tags"}
    extra = set(rec) - allowed
    if extra:
        raise SchemaError(f"unknown fields {sorted(extra)}: {rec!r}")
    return rec


def validate_lines(lines) -> List[Dict]:
    """Validate an iterable of JSONL strings; returns the parsed records."""
    import json
    out = []
    for i, ln in enumerate(lines):
        ln = ln.strip()
        if not ln:
            continue
        try:
            rec = json.loads(ln)
        except ValueError as e:
            raise SchemaError(f"line {i + 1} is not valid JSON: {e}")
        try:
            out.append(validate_record(rec))
        except SchemaError as e:
            raise SchemaError(f"line {i + 1}: {e}")
    return out
