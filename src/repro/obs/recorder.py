"""Structured telemetry core: counters / gauges / histograms, events, and
wall-clock spans, with a JSONL sink, an in-memory ring buffer, and a
near-zero-overhead disabled mode.

Design constraints (why this is not "just logging"):

* **Hot-path safe.**  The trainer calls the recorder every step, the
  serving engine every tick.  A record is one small dict appended to a
  bounded deque plus (when a sink directory is configured) one buffered
  JSON line — no locks on the read path, one lock around the buffered
  file writes (the async checkpointer reports write latency from its
  worker thread).  With telemetry disabled the :class:`NullRecorder`
  methods are bare early-returns, well under a microsecond per call
  (guarded by tests/test_obs.py::test_null_recorder_overhead).
* **Self-describing.**  Every record is one JSONL line validated by
  :mod:`repro.obs.schema`; ``python -m repro.obs.report`` renders a run's
  per-phase breakdown from the files alone — no live process needed.
* **Familiar console output.**  Events carry an optional human-readable
  ``msg``; a console sink prints it verbatim, so the pre-telemetry
  ``log_fn``/``print`` strings survive unchanged while the structured
  payload rides along in the JSONL.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

KINDS = ("counter", "gauge", "histogram", "event", "span")

# samples kept per histogram for percentile queries (summary() /
# report.py); a bounded deque so a million-step run cannot grow without
# limit — percentiles over the most recent window are what an operator
# wants anyway
HIST_WINDOW = 8192


class _NullSpan:
    """Reusable no-op context manager (no per-call allocation)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The disabled-mode recorder: every method is a bare early return.

    A single shared instance (:data:`NULL`) is the process default, so
    instrumented hot paths cost one attribute lookup + one no-op call
    when telemetry is off."""

    enabled = False
    out_dir: Optional[str] = None

    def counter(self, name, value=1, **tags):
        pass

    def gauge(self, name, value, **tags):
        pass

    def observe(self, name, value, **tags):
        pass

    def event(self, name, msg="", **tags):
        pass

    def span(self, name, **tags):
        return _NULL_SPAN

    def flush(self):
        pass

    def close(self):
        pass

    def summary(self):
        return {}


NULL = NullRecorder()


class _Span:
    """Timing context manager: records a ``span`` with ``dur_s`` on exit
    (perf_counter — monotonic, so an NTP slew mid-span cannot produce a
    negative duration)."""

    __slots__ = ("_rec", "name", "tags", "_t0")

    def __init__(self, rec: "Recorder", name: str, tags: Dict):
        self._rec = rec
        self.name = name
        self.tags = tags

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._rec._emit("span", self.name,
                        dur_s=time.perf_counter() - self._t0,
                        tags=self.tags or None)
        return False


class Recorder:
    """Structured telemetry recorder.

    ``out_dir``: directory for the JSONL sink (``telemetry.jsonl`` is
    appended; the directory is created).  ``None`` keeps records
    in-memory only (ring buffer + aggregates) — the launch default, so
    instrumentation is always safe to call.

    ``console``: optional callable for human-readable event lines (the
    pre-telemetry ``log_fn``); non-event records never hit the console.

    ``flush_every``: JSONL lines buffered between file flushes.  Must be
    positive — a zero/negative interval would either busy-flush or never
    flush, both silent misconfigurations (launch/serve.py forwards its
    ``--telemetry-flush`` flag here).

    ``ring_size``: bounded in-memory record history (most recent wins) —
    the crash-dump / in-process-inspection view.
    """

    enabled = True

    def __init__(self, out_dir: Optional[str] = None, *,
                 ring_size: int = 2048, flush_every: int = 64,
                 console: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.time):
        if flush_every <= 0:
            raise ValueError(
                f"telemetry flush interval must be a positive number of "
                f"records, got {flush_every} — use flush_every=1 for "
                f"write-through, or leave the default (64)")
        if ring_size <= 0:
            raise ValueError(f"ring_size must be positive, got {ring_size}")
        self.out_dir = out_dir
        self.console = console
        self.clock = clock
        self.flush_every = flush_every
        self.ring: deque = deque(maxlen=ring_size)
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, deque] = {}
        self._lock = threading.Lock()
        self._buf: List[str] = []
        self._file = None
        if out_dir is not None:
            os.makedirs(out_dir, exist_ok=True)
            self._file = open(os.path.join(out_dir, "telemetry.jsonl"), "a")

    # ---- emit paths ------------------------------------------------------
    def _emit(self, kind: str, name: str, *, value=None, dur_s=None,
              msg=None, tags=None):
        rec = {"ts": self.clock(), "kind": kind, "name": name}
        if value is not None:
            rec["value"] = value
        if dur_s is not None:
            rec["dur_s"] = dur_s
        if msg:
            rec["msg"] = msg
        if tags:
            rec["tags"] = tags
        self.ring.append(rec)
        if self._file is not None:
            with self._lock:
                self._buf.append(json.dumps(rec))
                if len(self._buf) >= self.flush_every:
                    self._flush_locked()
        return rec

    def counter(self, name: str, value: float = 1, **tags):
        """Monotonic count (events seen, tokens decoded, restarts)."""
        self.counters[name] = self.counters.get(name, 0) + value
        self._emit("counter", name, value=value, tags=tags or None)

    def gauge(self, name: str, value: float, **tags):
        """Point-in-time level (queue depth, slot occupancy, loss)."""
        self.gauges[name] = value
        self._emit("gauge", name, value=value, tags=tags or None)

    def observe(self, name: str, value: float, **tags):
        """Histogram sample (step time, decode latency, TTFT)."""
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = deque(maxlen=HIST_WINDOW)
        h.append(value)
        self._emit("histogram", name, value=value, tags=tags or None)

    def event(self, name: str, msg: str = "", **tags):
        """Discrete occurrence with structured payload and an optional
        human-readable line (printed by the console sink verbatim, so
        existing log output stays familiar)."""
        self._emit("event", name, msg=msg, tags=tags or None)
        if self.console is not None:
            self.console(msg if msg else
                         f"[{name}] " + " ".join(f"{k}={v}"
                                                 for k, v in tags.items()))

    def span(self, name: str, **tags) -> _Span:
        """``with rec.span("phase"): ...`` — wall-clock span record."""
        return _Span(self, name, tags)

    # ---- lifecycle -------------------------------------------------------
    def _flush_locked(self):
        if self._buf and self._file is not None:
            self._file.write("\n".join(self._buf) + "\n")
            self._file.flush()
            self._buf = []

    def flush(self):
        if self._file is not None:
            with self._lock:
                self._flush_locked()

    def close(self):
        if self._file is not None:
            with self._lock:
                self._flush_locked()
                self._file.close()
                self._file = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ---- in-process queries ---------------------------------------------
    def percentile(self, name: str, q: float) -> Optional[float]:
        """q in [0, 100] over the histogram's retained window (nearest-rank
        on the sorted samples; None when the histogram is empty)."""
        h = self.hists.get(name)
        if not h:
            return None
        xs = sorted(h)
        idx = min(int(round(q / 100.0 * (len(xs) - 1))), len(xs) - 1)
        return xs[idx]

    def summary(self) -> Dict:
        """Aggregated view: counters, last gauges, histogram p50/p90/p99."""
        hist = {}
        for name, h in self.hists.items():
            if not h:
                continue
            hist[name] = {
                "count": len(h),
                "mean": sum(h) / len(h),
                "p50": self.percentile(name, 50),
                "p90": self.percentile(name, 90),
                "p99": self.percentile(name, 99),
            }
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": hist}


# --------------------------------------------------------------------------
# process-global recorder (planner / kernels instrumentation reaches it
# without threading a parameter through every call chain)
# --------------------------------------------------------------------------
_GLOBAL: object = NULL


def get_recorder():
    """The process-global recorder (NullRecorder unless configured)."""
    return _GLOBAL


def set_recorder(rec) -> object:
    """Install ``rec`` as the process-global recorder; returns the
    previous one (tests restore it)."""
    global _GLOBAL
    prev, _GLOBAL = _GLOBAL, (rec if rec is not None else NULL)
    return prev


def configure(out_dir: Optional[str] = None, **kw) -> Recorder:
    """Build a :class:`Recorder` and install it globally (the launchers'
    ``--telemetry <dir>`` entry point)."""
    rec = Recorder(out_dir, **kw)
    set_recorder(rec)
    return rec
