"""Schedule-phase tracing helpers.

Two span flavours with different lifetimes:

* :func:`phase_scope` — a ``jax.named_scope``: a *trace-time* annotation
  that names the ops staged inside it, so the gather/compute/reduce
  chunks of the TMP schedules (megatron/wang/oases/fused) appear in the
  compiled HLO's op metadata and in XLA profiles.  Zero runtime cost —
  the scope only exists while tracing.
* :func:`trace_annotation` — a ``jax.profiler.TraceAnnotation``: a
  *host-side* region (step dispatch, engine tick) visible on the Python
  track of a ``jax.profiler.trace()`` capture.  Falls back to a no-op
  when the profiler backend is unavailable.

Both are safe to leave in hot paths unconditionally.
"""
from __future__ import annotations

import contextlib


def phase_scope(name: str):
    """Name the jax ops staged inside the block (XLA-profile visible)."""
    import jax
    return jax.named_scope(name)


def trace_annotation(name: str):
    """Host-side profiler region; no-op when the profiler is missing."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()
