"""Render a telemetry run's JSONL into per-phase breakdown tables —
the reproduction's own Fig. 2, from the files alone (no live process).

    python -m repro.obs.report <telemetry-dir | telemetry.jsonl>
    python -m repro.obs.report <dir> --validate    # schema gate (CI)

Sections:

* **phases** — every histogram/span metric: count, mean, p50/p90/p99 and
  the share of total accounted wall time (the per-phase breakdown);
* **overlap** — the runtime overlap-efficiency probe's per-layer-group
  events: predicted vs measured exposed-communication fraction and the
  residual against the calibrated cost model;
* **serving** — the serving path's own dashboard when ``serving.*``
  metrics are present: throughput, mean/percentile TTFT, prefix-cache hit
  rate, speculative accept rate, page-pool level and admission
  backpressure;
* **counters / gauges** — run totals and last-seen levels;
* **events** — the notable trail (faults, replans, calibration_stale,
  planner decisions), newest last.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    """Parse all records from a telemetry.jsonl file or a directory
    containing one (or several — merged in name order)."""
    files = []
    if os.path.isdir(path):
        for name in sorted(os.listdir(path)):
            if name.endswith(".jsonl"):
                files.append(os.path.join(path, name))
        if not files:
            raise FileNotFoundError(f"no .jsonl telemetry files in {path}")
    else:
        files = [path]
    records = []
    for f in files:
        with open(f) as fh:
            for ln in fh:
                ln = ln.strip()
                if ln:
                    records.append(json.loads(ln))
    return records


def _pct(xs: List[float], q: float) -> float:
    ys = sorted(xs)
    return ys[min(int(round(q / 100.0 * (len(ys) - 1))), len(ys) - 1)]


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}us"


def _table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def _serving_section(hists: Dict[str, List[float]],
                     counters: Dict[str, float],
                     gauges: Dict[str, float]) -> List[List[str]]:
    """The serving path's dashboard rows (empty when the run emitted no
    ``serving.*`` metrics): throughput and TTFT from the histograms,
    cache efficiency and backpressure from the gauges/counters."""
    if not any(n.startswith("serving.")
               for n in (*hists, *counters, *gauges)):
        return []
    rows: List[List[str]] = []
    if "serving.tok_per_s" in gauges:
        rows.append(["throughput (tok/s)",
                     f"{gauges['serving.tok_per_s']:.1f}"])
    if "serving.decoded_tokens" in counters:
        rows.append(["decoded tokens",
                     f"{counters['serving.decoded_tokens']:g}"])
    ttft = hists.get("serving.ttft_s")
    if ttft:
        rows.append(["TTFT mean / p90",
                     f"{_fmt_s(sum(ttft) / len(ttft))} / "
                     f"{_fmt_s(_pct(ttft, 90))}"])
    steps = hists.get("serving.decode_step_s")
    if steps:
        rows.append(["decode step p50 / p99",
                     f"{_fmt_s(_pct(steps, 50))} / "
                     f"{_fmt_s(_pct(steps, 99))}"])
    if "serving.prefix_hit_rate" in gauges:
        rows.append(["prefix-cache hit rate",
                     f"{gauges['serving.prefix_hit_rate']:.1%}"])
    if "serving.spec_accept_rate" in gauges:
        rows.append(["speculative accept rate",
                     f"{gauges['serving.spec_accept_rate']:.1%}"])
    if "serving.free_pages" in gauges:
        rows.append(["free KV pages (last)",
                     f"{gauges['serving.free_pages']:g}"])
    if "serving.admission_deferred" in counters:
        rows.append(["admissions deferred (cache full)",
                     f"{counters['serving.admission_deferred']:g}"])
    if "serving.slot_occupancy" in gauges:
        rows.append(["slot occupancy (last)",
                     f"{gauges['serving.slot_occupancy']:.1%}"])
    return rows


def render(records: List[Dict]) -> str:
    hists: Dict[str, List[float]] = {}
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    overlap_rows: List[Dict] = []
    events: List[Dict] = []
    for r in records:
        kind = r.get("kind")
        if kind == "histogram":
            hists.setdefault(r["name"], []).append(float(r["value"]))
        elif kind == "span":
            hists.setdefault(r["name"], []).append(float(r["dur_s"]))
        elif kind == "counter":
            counters[r["name"]] = counters.get(r["name"], 0) \
                + float(r["value"])
        elif kind == "gauge":
            gauges[r["name"]] = float(r["value"])
        elif kind == "event":
            if r["name"] == "overlap.group":
                overlap_rows.append(r.get("tags") or {})
            events.append(r)

    parts: List[str] = []
    if hists:
        totals = {n: sum(v) for n, v in hists.items()}
        grand = sum(totals.values()) or 1.0
        rows = []
        for name in sorted(hists, key=lambda n: -totals[n]):
            xs = hists[name]
            rows.append([name, str(len(xs)), _fmt_s(sum(xs) / len(xs)),
                         _fmt_s(_pct(xs, 50)), _fmt_s(_pct(xs, 90)),
                         _fmt_s(_pct(xs, 99)), _fmt_s(totals[name]),
                         f"{totals[name] / grand:5.1%}"])
        parts.append("== per-phase breakdown ==\n" + _table(
            ["phase", "count", "mean", "p50", "p90", "p99", "total",
             "share"], rows))
    if overlap_rows:
        rows = []
        for t in overlap_rows:
            rows.append([
                str(t.get("group", "?")), str(t.get("schedule", "?")),
                str(t.get("layers", "?")),
                f"{float(t.get('predicted_exposed_frac', 0)):.1%}",
                f"{float(t.get('measured_exposed_frac', 0)):.1%}",
                f"{float(t.get('residual', 0)):+.0%}",
            ])
        parts.append(
            "== overlap efficiency (exposed-communication fraction) ==\n"
            + _table(["group", "schedule", "layers", "predicted",
                      "measured", "residual"], rows))
    serving_rows = _serving_section(hists, counters, gauges)
    if serving_rows:
        parts.append("== serving ==\n" + _table(["metric", "value"],
                                                serving_rows))
    if counters:
        rows = [[n, f"{v:g}"] for n, v in sorted(counters.items())]
        parts.append("== counters ==\n" + _table(["counter", "total"], rows))
    if gauges:
        rows = [[n, f"{v:g}"] for n, v in sorted(gauges.items())]
        parts.append("== gauges (last) ==\n" + _table(["gauge", "value"],
                                                      rows))
    notable = [e for e in events
               if e["name"] != "overlap.group"]
    if notable:
        rows = []
        for e in notable[-20:]:
            tags = e.get("tags") or {}
            detail = e.get("msg") or " ".join(f"{k}={v}"
                                              for k, v in tags.items())
            rows.append([e["name"], detail[:100]])
        parts.append("== events (last 20) ==\n" + _table(["event",
                                                          "detail"], rows))
    if not parts:
        return "(no telemetry records)"
    return "\n\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry run's JSONL into per-phase "
                    "breakdown tables")
    ap.add_argument("path", help="telemetry directory or .jsonl file")
    ap.add_argument("--validate", action="store_true",
                    help="validate every record against the schema and "
                         "exit non-zero on a violation (CI gate)")
    args = ap.parse_args(argv)
    records = load(args.path)
    if args.validate:
        from repro.obs.schema import SchemaError, validate_record
        try:
            for i, rec in enumerate(records):
                validate_record(rec)
        except SchemaError as e:
            print(f"schema violation at record {i + 1}: {e}",
                  file=sys.stderr)
            return 1
        print(f"{len(records)} telemetry records OK")
        return 0
    print(render(records))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
