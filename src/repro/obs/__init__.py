"""Unified telemetry subsystem (DESIGN.md §11).

``repro.obs`` is the one place runtime observability lives:

* :class:`Recorder` — counters / gauges / histograms, structured events,
  wall-clock spans; JSONL sink + in-memory ring buffer; a
  :class:`NullRecorder` disabled mode whose calls cost well under a
  microsecond (the hot paths are instrumented unconditionally);
* :func:`phase_scope` / :func:`trace_annotation` — schedule-phase spans
  that surface the TMP gather/compute/reduce chunks in XLA profiles;
* :class:`OverlapProbe` — the runtime overlap-efficiency probe: measured
  exposed-communication fraction per layer group, residual against the
  calibrated cost model, and the ``calibration_stale`` drift signal;
* ``python -m repro.obs.report`` — render a run's JSONL into per-phase
  breakdown tables (the reproduction's own Fig. 2).
"""
from repro.obs.recorder import (NULL, NullRecorder,  # noqa: F401
                                Recorder, configure, get_recorder,
                                set_recorder)
from repro.obs.tracing import phase_scope, trace_annotation  # noqa: F401

__all__ = [
    "Recorder", "NullRecorder", "NULL",
    "configure", "get_recorder", "set_recorder",
    "phase_scope", "trace_annotation",
    "OverlapProbe", "plan_group_model",
]


def __getattr__(name):
    # probe pulls in the cost model; keep the base import light
    if name in ("OverlapProbe", "plan_group_model"):
        from repro.obs import probe
        return getattr(probe, name)
    raise AttributeError(name)
