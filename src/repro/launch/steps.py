"""Jit-able step functions shared by the trainer, server, dry-run and
benchmarks: train_step (fwd+bwd+AdamW), prefill_step, serve_step."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeConfig, TrainHParams
from repro.core.axes import batch_pspec, deg_total, mesh_info
from repro.models import lm
from repro.models import params as prm
from repro.optim import adamw


def _min_degree(degrees, tp: int) -> int:
    """Smallest *total* degree in a plan (entries None | int | (dx, dy);
    None = mesh-following, i.e. the whole ``tp`` group)."""
    return min(deg_total(d) or tp for d in degrees)


def unpack_plan(cfg: ArchConfig, hp: TrainHParams, plan,
                degrees=None, schedules=None):
    """Project an executable ParallelPlan onto the (hp, degrees,
    schedules) triple the step builders consume.  Explicit degrees/
    schedules win over the plan's (callers that pass both are layering a
    manual override on top)."""
    if plan is not None:
        plan.validate_for(cfg)
        hp = plan.apply(hp)
        if degrees is None:
            degrees = plan.planned_degrees
        if schedules is None and plan.uniform_schedule is None:
            schedules = list(plan.schedules)
    return hp, degrees, schedules


def auto_microbatch(global_batch: int, dp: int, seq_len: int,
                    d_model: int, num_layers: int,
                    act_budget: float = 5e9, act_shard: int = 1) -> int:
    """Gradient-accumulation count sized so one microbatch's rematerialized
    activations (~3 [t,d] bf16 tensors per layer with the fine policy) fit
    the activation budget, floored at 1 sequence per chip."""
    local = max(global_batch // max(dp, 1), 1)
    token_budget = act_budget * act_shard / (3.0 * d_model * 2.0
                                             * max(num_layers, 1))
    seqs = max(1, min(local, int(token_budget // max(seq_len, 1))))
    n = max(1, local // seqs)
    while n > 1 and (local % n or global_batch % n):
        n -= 1
    return n    # 1 = no accumulation (resolved; 0 means "auto")


def resolve_hp(hp: TrainHParams, shape_kind: str, global_batch: int,
               dp: int, *, seq_len: int = 4096, d_model: int = 4096,
               num_layers: int = 32, tp: int = 1) -> TrainHParams:
    """Fill auto fields (microbatch=0 -> auto for training).  Sequence
    parallelism shards the remat residuals tp-ways, so the activation
    budget stretches by tp."""
    import dataclasses
    if shape_kind == "train" and hp.microbatch == 0:
        # ring attention (seq_shard) shards the residuals like SP does
        shard = tp if (hp.seq_parallel or hp.seq_shard > 1) else 1
        return dataclasses.replace(
            hp, microbatch=auto_microbatch(global_batch, dp, seq_len,
                                           d_model, num_layers,
                                           act_shard=shard))
    return hp


def resolve_for_mesh(cfg: ArchConfig, info, hp: TrainHParams,
                     global_batch: int, seq_len: int,
                     degrees=None) -> TrainHParams:
    """One resolution used by build_train_step, the abstract-input builder
    and the Trainer so they always agree on the microbatch semantics.

    On a pipeline mesh ``hp.microbatch`` becomes the 1F1B microbatch count
    (gradient accumulation is folded into the schedule — no outer loop);
    otherwise the classic gradient-accumulation auto-sizing applies."""
    import dataclasses
    from repro.core import pipeline as pl
    if info.pp > 1:
        if degrees is not None:
            raise ValueError(
                "per-layer planner degrees do not compose with pipeline "
                "parallelism yet — drop degrees= or the 'pipe' mesh axis")
        n_micro = pl.resolve_microbatch(
            max(global_batch // max(info.dp, 1), 1), info.pp,
            max(hp.virtual_stages, 1), hp.microbatch)
        return dataclasses.replace(hp, microbatch=n_micro,
                                   seq_parallel=False)
    dp_eff = info.dp * (info.tp // _min_degree(degrees, info.tp)) \
        if degrees else info.dp
    return resolve_hp(hp, "train", global_batch, dp_eff, seq_len=seq_len,
                      d_model=cfg.d_model, num_layers=cfg.num_layers,
                      tp=info.tp)


def build_train_step(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                     global_batch: int, seq_len: int,
                     degrees: Optional[Sequence[int]] = None,
                     schedules: Optional[Sequence[str]] = None,
                     plan=None):
    """returns (train_step(params, opt_state, batch) ->
                (params, opt_state, metrics), specs).

    ``plan``: an executable :class:`repro.core.plan.ParallelPlan` —
    desugars into (hp overrides, per-layer degrees/schedules) via
    :func:`unpack_plan`; the legacy kwargs keep working unchanged."""
    info = mesh_info(mesh)
    hp, degrees, schedules = unpack_plan(cfg, hp, plan, degrees, schedules)
    hp = resolve_for_mesh(cfg, info, hp, global_batch, seq_len, degrees)
    # pipeline mode: the microbatch loop IS the 1F1B schedule, folded into
    # loss_fn — the step sees the full batch and a single value_and_grad
    pipelined = info.pp > 1
    micro_b = global_batch // hp.microbatch \
        if (hp.microbatch > 1 and not pipelined) else global_batch
    loss_fn, specs, _ = lm.build_train_loss(
        cfg, mesh, hp, global_batch=micro_b, seq_len=seq_len,
        degrees=degrees, schedules=schedules,
        seqs=plan.planned_seqs if plan is not None else None)
    ocfg = adamw.AdamWConfig(
        learning_rate=hp.learning_rate, weight_decay=hp.weight_decay,
        warmup_steps=hp.warmup_steps, total_steps=hp.total_steps,
        grad_clip=hp.grad_clip)

    # ZeRO-sharded gradient layout: the f32 grad (and its accumulator) is
    # sharded like the optimizer state, so GSPMD turns the backward's
    # data-axis psum into a reduce-scatter and the accumulator shrinks by
    # dp (§Perf: this is what lets 20B-scale train cells fit 16 GB HBM).
    g_specs = adamw.opt_state_specs(specs, info, zero1=hp.zero1)["m"]
    g_shardings = prm.shardings_tree(g_specs, mesh)

    def _constrain(g):
        # shard FIRST (in the grad dtype), cast to f32 after — the other
        # order materializes a full-size f32 copy per chip before GSPMD
        # gets to slice it
        return jax.tree_util.tree_map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s)
            .astype(jnp.float32), g, g_shardings)

    def train_step(params, opt_state, batch):
        if hp.microbatch and hp.microbatch > 1 and not pipelined:
            # gradient accumulation: batch arrives pre-shaped
            # [n_micro, B/n, ...] with the batch dim sharded on axis 1, so
            # indexing axis 0 never reshards.
            n = hp.microbatch

            def micro(i, acc):
                g_acc, l_acc = acc
                mb = jax.tree_util.tree_map(
                    lambda t: jax.lax.dynamic_index_in_dim(
                        t, i, 0, keepdims=False), batch)
                (ls, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree_util.tree_map(
                    jnp.add, g_acc, _constrain(g)), l_acc + ls)

            zero_g = jax.tree_util.tree_map(
                lambda w, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(w.shape, jnp.float32), s),
                params, g_shardings)
            grads, loss = jax.lax.fori_loop(0, n, micro, (zero_g, 0.0))
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = _constrain(grads)
        params, opt_state, gnorm = adamw.apply_updates(
            params, grads, opt_state, ocfg, compress=hp.grad_compress,
            zero_shardings=g_shardings,
            param_shardings=prm.shardings_tree(specs, mesh))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, specs


def train_abstract_inputs(cfg: ArchConfig, mesh, hp: TrainHParams, *,
                          global_batch: int, seq_len: int,
                          degrees=None, schedules=None, plan=None):
    """ShapeDtypeStruct stand-ins for every train_step input (no alloc).
    With gradient accumulation the batch arrives pre-shaped
    [n_micro, B/n, ...], batch dim sharded on axis 1."""
    info = mesh_info(mesh)
    hp, degrees, schedules = unpack_plan(cfg, hp, plan, degrees, schedules)
    hp = resolve_for_mesh(cfg, info, hp, global_batch, seq_len, degrees)
    # the ONE strategy normalization build_train_loss itself runs, so the
    # abstract specs agree with the traced step (grouped promotion, ring
    # seq collapse/expansion) by construction
    seqs = plan.planned_seqs if plan is not None else None
    degrees, schedules, seqs, hp = lm._normalize_strategy(
        cfg, hp, degrees, schedules, seqs)
    ring = hp.seq_shard > 1 and degrees is None
    specs = prm.model_specs(cfg, info, degrees=degrees, max_pos=seq_len,
                            layout=hp.tmp_layout,
                            virtual_stages=hp.virtual_stages,
                            schedules=schedules, seqs=seqs,
                            seq_shard=hp.seq_shard if ring else 1)
    params = prm.abstract_params(specs, mesh)
    opt_state = adamw.abstract_opt_state(specs, info, mesh, zero1=hp.zero1)
    # pipeline meshes take the flat batch; 1F1B slices microbatches itself
    n = hp.microbatch if (hp.microbatch > 1 and info.pp == 1) else 1
    micro_b = global_batch // n
    bp = batch_pspec(info, micro_b)
    lead = (n,) if n > 1 else ()
    spec_entries = ((None,) if n > 1 else ()) + tuple(bp)
    bs = NamedSharding(mesh, jax.sharding.PartitionSpec(*spec_entries))

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(lead + shape, dtype, sharding=bs)

    batch = {
        "tokens": sds((micro_b, seq_len), jnp.int32),
        "labels": sds((micro_b, seq_len), jnp.int32),
    }
    if cfg.context_len:
        cd = cfg.context_dim or cfg.d_model
        batch["ctx"] = sds((micro_b, cfg.context_len, cd), jnp.bfloat16)
    return params, opt_state, batch


def build_prefill_step(cfg, mesh, hp, *, global_batch, seq_len):
    fn, specs, st_specs = lm.build_prefill(
        cfg, mesh, hp, global_batch=global_batch, seq_len=seq_len)
    return fn, specs, st_specs


def prefill_abstract_inputs(cfg, mesh, hp, *, global_batch, seq_len):
    info = mesh_info(mesh)
    specs = prm.model_specs(cfg, info, max_pos=seq_len + 1,
                            layout=hp.tmp_layout)
    params = prm.abstract_params(specs, mesh)
    bs = NamedSharding(mesh, batch_pspec(info, global_batch))
    batch = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                            jnp.int32, sharding=bs)}
    if cfg.context_len:
        cd = cfg.context_dim or cfg.d_model
        batch["ctx"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.context_len, cd), jnp.bfloat16, sharding=bs)
    return params, batch


def build_serve_step(cfg, mesh, hp, *, global_batch, seq_len):
    fn, specs, st_specs = lm.build_decode(
        cfg, mesh, hp, global_batch=global_batch, seq_len=seq_len)
    return fn, specs, st_specs


def serve_abstract_inputs(cfg, mesh, hp, *, global_batch, seq_len):
    info = mesh_info(mesh)
    specs = prm.model_specs(cfg, info, max_pos=seq_len + 8,
                            layout=hp.tmp_layout,
                            virtual_stages=hp.virtual_stages)
    params = prm.abstract_params(specs, mesh)
    bspec = batch_pspec(info, global_batch)
    st_specs = prm.cache_specs(cfg, info, batch=global_batch, seq=seq_len,
                               batch_spec=bspec, layout=hp.tmp_layout,
                               virtual_stages=hp.virtual_stages)
    state = prm.abstract_params(st_specs, mesh)
    bs = NamedSharding(mesh, bspec)
    tokens = jax.ShapeDtypeStruct((global_batch,), jnp.int32, sharding=bs)
    pos = jax.ShapeDtypeStruct((global_batch,), jnp.int32, sharding=bs)
    return params, state, tokens, pos


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                hp: Optional[TrainHParams] = None, degrees=None,
                schedules=None, plan=None):
    """The dry-run contract: ShapeDtypeStruct stand-ins for the step that
    this (arch x shape) cell lowers."""
    hp = hp or TrainHParams()
    if shape.kind == "train":
        return train_abstract_inputs(cfg, mesh, hp,
                                     global_batch=shape.global_batch,
                                     seq_len=shape.seq_len, degrees=degrees,
                                     schedules=schedules, plan=plan)
    hp, degrees, schedules = unpack_plan(cfg, hp, plan, degrees, schedules)
    if shape.kind == "prefill":
        return prefill_abstract_inputs(cfg, mesh, hp,
                                       global_batch=shape.global_batch,
                                       seq_len=shape.seq_len)
    return serve_abstract_inputs(cfg, mesh, hp,
                                 global_batch=shape.global_batch,
                                 seq_len=shape.seq_len)


def step_fn_for(cfg, shape, mesh, hp: Optional[TrainHParams] = None,
                degrees=None, schedules=None, plan=None):
    hp = hp or TrainHParams()
    if shape.kind == "train":
        fn, _ = build_train_step(cfg, mesh, hp,
                                 global_batch=shape.global_batch,
                                 seq_len=shape.seq_len, degrees=degrees,
                                 schedules=schedules, plan=plan)
        return fn
    hp, degrees, schedules = unpack_plan(cfg, hp, plan, degrees, schedules)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, hp,
                                  global_batch=shape.global_batch,
                                  seq_len=shape.seq_len)[0]
    return build_serve_step(cfg, mesh, hp, global_batch=shape.global_batch,
                            seq_len=shape.seq_len)[0]
