"""Training launcher.

Single-host CPU testbed:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --seq 128

TPU pod (per-host, via launch/scripts/tpu_pod.sh): the same entrypoint with
--distributed initializes jax.distributed from the TPU environment and
builds the production mesh.
"""
from __future__ import annotations

import argparse
import json


def main():
    from repro.core.schedule import SCHEDULES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU demo)")
    ap.add_argument("--schedule", default="oases", choices=list(SCHEDULES))
    ap.add_argument("--no-fine-remat", dest="fine_remat",
                    action="store_false")
    ap.add_argument("--planner", action="store_true",
                    help="per-layer TMP degrees from the ILP (factored mesh)")
    ap.add_argument("--calibrate", action="store_true", default=True,
                    help="profile-guided --planner inputs (the DEFAULT: "
                         "HWConfig.from_measurements via the per-host "
                         "calibration cache)")
    ap.add_argument("--no-calibrate", dest="calibrate",
                    action="store_false",
                    help="plan with the stock chip numbers instead of "
                         "on-device calibration")
    ap.add_argument("--tmp-layout", default="auto",
                    choices=["auto", "1d", "2d"],
                    help="partition layout: 1d (classic), 2d (hybrid "
                         "model_x*model_y), auto (follow the mesh; the "
                         "planner searches both spaces)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stages (prepends a 'pipe' mesh "
                         "axis; composes with --mesh dxm specs)")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="interleaved-1F1B virtual stages per device")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="1F1B microbatch count / grad-accumulation steps "
                         "(0 = auto)")
    ap.add_argument("--seq-shard", type=int, default=1,
                    help="ring-attention sequence shards per attention "
                         "layer (power of two; must equal the mesh model "
                         "group size — DESIGN.md §12)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/run0")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 2x4) | production | multipod")
    ap.add_argument("--plan", default="", metavar="plan.json",
                    help="execute a ParallelPlan file (planner output / "
                         "--save-plan); overrides the legacy parallelism "
                         "flags in one shot")
    ap.add_argument("--save-plan", default="", metavar="out.json",
                    help="write the resolved ParallelPlan (desugared "
                         "flags or the ILP decision under --planner) for "
                         "later --plan runs")
    ap.add_argument("--planner-schedules", default="current",
                    choices=["current", "auto"],
                    help="--planner search space: degrees under the "
                         "--schedule ('current') or the full per-layer "
                         "(degree, schedule) space of the paper ('auto')")
    ap.add_argument("--planner-seq", default="none",
                    choices=["none", "auto"],
                    help="--planner seq axis: 'auto' lets the ILP shard "
                         "long sequences over KV rings per attention "
                         "layer instead of (only) sharding heads")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--elastic", action="store_true",
                    help="run under the ElasticSupervisor: faults trigger "
                         "ILP replanning + in-memory relayout instead of "
                         "a crash (runtime/elastic.py)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="elastic: host count the devices split across "
                         "(host h owns the contiguous device slice)")
    ap.add_argument("--max-replans", type=int, default=3)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--restart-backoff", type=float, default=0.05,
                    help="restart backoff base seconds (exponential)")
    ap.add_argument("--inject-fail", default="", metavar="STEPS",
                    help="chaos: comma-separated steps raising a generic "
                         "worker failure (restart-from-checkpoint path)")
    ap.add_argument("--inject-host-loss", default="", metavar="STEP:HOST",
                    help="chaos: lose HOST at STEP (comma-separated pairs; "
                         "elastic replan + relayout path)")
    ap.add_argument("--inject-link-degrade", default="", metavar="STEP:BW",
                    help="chaos: degrade inter-node bandwidth to BW "
                         "bytes/s at STEP")
    ap.add_argument("--inject-ckpt-corrupt", default="", metavar="STEPS",
                    help="chaos: bit-flip the checkpoint written at these "
                         "steps (intact-fallback path)")
    ap.add_argument("--inject-ckpt-fail", type=int, default=0,
                    metavar="N",
                    help="chaos: first N checkpoint writes raise a "
                         "transient OSError (async retry path)")
    ap.add_argument("--telemetry", default="", metavar="DIR",
                    help="write structured telemetry (JSONL) under DIR; "
                         "render with `python -m repro.obs.report DIR`. "
                         "Also enables the end-of-run overlap-efficiency "
                         "probe (measured vs modeled exposed comm)")
    ap.add_argument("--telemetry-flush", type=int, default=64,
                    metavar="N",
                    help="JSONL records buffered between file flushes "
                         "(must be positive; 1 = write-through)")
    args = ap.parse_args()

    telemetry = None
    if args.telemetry:
        from repro import obs
        if args.telemetry_flush <= 0:
            raise SystemExit(
                f"--telemetry-flush must be a positive number of records, "
                f"got {args.telemetry_flush} (use 1 for write-through)")
        # global install: planner/serving instrumentation reaches it via
        # obs.get_recorder(); console=print keeps the familiar log lines
        telemetry = obs.configure(args.telemetry,
                                  flush_every=args.telemetry_flush,
                                  console=print)

    def _steps(spec):
        return tuple(int(s) for s in spec.split(",") if s)

    def _pairs(spec, second=int):
        return tuple((int(a), second(b))
                     for a, b in (p.split(":") for p in spec.split(",")
                                  if p))

    if args.distributed:
        import jax
        jax.distributed.initialize()

    import dataclasses

    from repro.configs.base import TrainHParams
    from repro.configs.registry import get_config
    from repro.core.axes import mesh_info
    from repro.launch.mesh import resolve_launch
    from repro.runtime import Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(dtype="float32")

    hp = TrainHParams(schedule=args.schedule, fine_remat=args.fine_remat,
                      learning_rate=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1),
                      use_planner=args.planner, tmp_layout=args.tmp_layout,
                      microbatch=args.microbatch,
                      virtual_stages=args.virtual_stages,
                      seq_shard=args.seq_shard)
    # the ONE desugaring path (launch/mesh.py): legacy flags or a --plan
    # file become (mesh, ParallelPlan, projected hp)
    mesh, pplan, hp = resolve_launch(cfg, hp, mesh=args.mesh, pp=args.pp,
                                     plan_file=args.plan)
    if args.planner and not args.plan:
        from repro.configs.base import ShapeConfig
        from repro.core.planner import plan as plan_search
        from repro.core.planner.costmodel import V5E
        info = mesh_info(mesh)
        if args.calibrate:
            # profile-guided by default: the cost model's chip terms come
            # from measurements (cached per host), the cluster shape from
            # the resolved mesh; --no-calibrate keeps the spec-sheet V5E
            from repro.core.planner.calibrate import calibrated_hw, describe
            hw = calibrated_hw(n_chips=info.mesh.size)
            print(f"planner: calibrated hw {describe(hw)}")
        else:
            hw = V5E
        # plan for the workload actually being trained, not a fixed table
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        pr = plan_search(cfg, shape, hp, hw,
                         layout=args.tmp_layout,
                         options=tuple(n for n in (2, 4, 8, 16)
                                       if n <= info.tp) or (info.tp,),
                         schedules="auto"
                         if args.planner_schedules == "auto" else None,
                         seq=args.planner_seq)
        print(f"planner: {pr.summary()}")
        if info.factored or pr.plan.planned_degrees is None:
            # mixed degrees need the factored mesh; a mesh-following plan
            # (uniform degrees — incl. ring seq-shard plans) runs anywhere
            pplan = dataclasses.replace(pplan, layers=pr.plan.layers)
        else:
            print("planner: mesh is not factored — plan shown for "
                  "inspection only, training uses the uniform layout")
    if args.save_plan:
        pplan.save(args.save_plan)
        print(f"[plan] wrote {args.save_plan}: {pplan.summary()}")
    from repro.runtime import FailureInjector
    injector = FailureInjector(
        fail_at_steps=_steps(args.inject_fail),
        host_loss=_pairs(args.inject_host_loss),
        link_degrade=_pairs(args.inject_link_degrade, float),
        ckpt_fail_saves=args.inject_ckpt_fail,
        corrupt_at_steps=_steps(args.inject_ckpt_corrupt))

    if args.elastic:
        import jax

        from repro.configs.base import ShapeConfig
        from repro.runtime import ElasticConfig, ElasticSupervisor, Topology
        from repro.runtime import elastic as el
        ndev = len(jax.devices())
        hosts = max(args.hosts, 1)
        if ndev % hosts:
            raise SystemExit(f"--hosts {hosts} does not divide the "
                             f"{ndev} visible devices")
        topo = Topology(n_hosts=hosts, chips_per_host=ndev // hosts)

        def make_trainer(topology, plan):
            m = el.mesh_for(topology, plan or pplan)
            return Trainer(cfg, m, hp, global_batch=args.batch,
                           seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                           injector=injector,
                           plan=plan if plan is not None else pplan,
                           telemetry=telemetry)

        sup = ElasticSupervisor(
            make_trainer, topology=topo, cfg=cfg,
            shape=ShapeConfig("cli", args.seq, args.batch, "train"),
            hp=hp,
            econfig=ElasticConfig(max_replans=args.max_replans,
                                  max_restarts=args.max_restarts,
                                  backoff_s=args.restart_backoff),
            telemetry=telemetry)
        res = sup.run(args.steps, ckpt_every=args.ckpt_every,
                      seed=args.seed)
        if telemetry is not None:
            telemetry.close()
        print(json.dumps({
            "final_step": res["final_step"],
            "first_loss": res["losses"][0], "last_loss": res["losses"][-1],
            "slow_steps": len(res["slow_steps"]),
            "events": [e.describe() for e in res["events"]],
            "replans": res["replans"], "restarts": res["restarts"],
            "surviving_chips": res["topology"].n_chips,
        }, indent=1))
        return

    trainer = Trainer(cfg, mesh, hp, global_batch=args.batch,
                      seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                      injector=injector, plan=pplan, telemetry=telemetry)
    res = trainer.train(args.steps, ckpt_every=args.ckpt_every,
                        seed=args.seed)
    if telemetry is not None:
        telemetry.close()
    print(json.dumps({
        "final_step": res["final_step"],
        "first_loss": res["losses"][0], "last_loss": res["losses"][-1],
        "slow_steps": len(res["slow_steps"]),
    }, indent=1))


if __name__ == "__main__":
    main()
