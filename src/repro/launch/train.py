"""Training launcher.

Single-host CPU testbed:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --seq 128

TPU pod (per-host, via launch/scripts/tpu_pod.sh): the same entrypoint with
--distributed initializes jax.distributed from the TPU environment and
builds the production mesh.
"""
from __future__ import annotations

import argparse
import json


def main():
    from repro.core.schedule import SCHEDULES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU demo)")
    ap.add_argument("--schedule", default="oases", choices=list(SCHEDULES))
    ap.add_argument("--no-fine-remat", dest="fine_remat",
                    action="store_false")
    ap.add_argument("--planner", action="store_true",
                    help="per-layer TMP degrees from the ILP (factored mesh)")
    ap.add_argument("--tmp-layout", default="auto",
                    choices=["auto", "1d", "2d"],
                    help="partition layout: 1d (classic), 2d (hybrid "
                         "model_x*model_y), auto (follow the mesh; the "
                         "planner searches both spaces)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stages (prepends a 'pipe' mesh "
                         "axis; composes with --mesh dxm specs)")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="interleaved-1F1B virtual stages per device")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="1F1B microbatch count / grad-accumulation steps "
                         "(0 = auto)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/run0")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 2x4) | production | multipod")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()
    import jax

    from repro.configs.base import TrainHParams
    from repro.configs.registry import get_config
    from repro.core.axes import mesh_info
    from repro.launch.mesh import (make_factored_mesh, make_pipeline_mesh,
                                   make_production_mesh, make_smoke_mesh,
                                   parse_mesh_shape)
    from repro.runtime import Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(dtype="float32")

    pp = max(args.pp, 1)
    if pp > 1 and args.mesh in ("production", "multipod", "factored"):
        raise SystemExit(
            f"--pp does not compose with --mesh {args.mesh} yet — use an "
            f"explicit 'dxm' spec (e.g. --pp {pp} --mesh 8x16) or "
            f"--mesh auto")
    if args.mesh == "auto":
        if pp > 1:
            n = len(jax.devices())
            if n % pp:
                raise SystemExit(f"--pp {pp} does not divide the "
                                 f"{n} available devices")
            mesh = make_pipeline_mesh(pp, max(n // pp, 1), 1)
        else:
            mesh = make_smoke_mesh()
    elif args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "factored":
        mesh = make_factored_mesh()
    else:
        # 'dxm' (1D) or 'dxm1xm2' (2D hybrid) device grid; --pp prepends
        # the 'pipe' stage axis
        mesh = parse_mesh_shape(args.mesh, pp=pp)

    hp = TrainHParams(schedule=args.schedule, fine_remat=args.fine_remat,
                      learning_rate=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1),
                      use_planner=args.planner, tmp_layout=args.tmp_layout,
                      microbatch=args.microbatch,
                      virtual_stages=args.virtual_stages)
    degrees = None
    if args.planner:
        from repro.configs.base import ShapeConfig
        from repro.core.planner import plan
        info = mesh_info(mesh)
        # plan for the workload actually being trained, not a fixed table
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        pr = plan(cfg, shape, hp,
                  layout=args.tmp_layout,
                  options=tuple(n for n in (2, 4, 8, 16) if n <= info.tp)
                  or (info.tp,))
        print(f"planner: {pr.summary()}")
        if info.factored:
            degrees = pr.degrees
        else:
            print("planner: mesh is not factored — plan shown for "
                  "inspection only, training uses the uniform layout")
    trainer = Trainer(cfg, mesh, hp, global_batch=args.batch,
                      seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                      degrees=degrees)
    res = trainer.train(args.steps, ckpt_every=args.ckpt_every,
                        seed=args.seed)
    print(json.dumps({
        "final_step": res["final_step"],
        "first_loss": res["losses"][0], "last_loss": res["losses"][-1],
        "slow_steps": len(res["slow_steps"]),
    }, indent=1))


if __name__ == "__main__":
    main()
