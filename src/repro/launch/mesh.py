"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real (1-device) topology.
"""
from __future__ import annotations

import jax

from repro.core import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes,
                            axis_types=compat.auto_axis_types(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_factored_mesh(*, multi_pod: bool = False):
    """Planner-mode mesh: the 16-way model axis factored into binary
    sub-axes so per-layer TMP degrees in {1,2,4,8,16} — 1D ints or 2D
    ``(dx, dy)`` tuples (x = leading sub-axes, y = the next) — are
    expressible."""
    shape = (2, 16, 2, 2, 2, 2) if multi_pod else (16, 2, 2, 2, 2)
    axes = (("pod", "data", "t1", "t2", "t3", "t4") if multi_pod
            else ("data", "t1", "t2", "t3", "t4"))
    return _mk(shape, axes)


def make_2d_mesh(data: int, dx: int, dy: int):
    """Uniform 2D hybrid-partition mesh ``('data','model_x','model_y')``:
    weight width shards over the dx-way intra-node axis, the contraction
    dim over the dy-way inter-node axis (commodity-server placement)."""
    return _mk((data, dx, dy), ("data", "model_x", "model_y"))


def make_pipeline_mesh(pp: int, data: int, model: int):
    """``('pipe','data','model')`` — pipeline stages outermost (on
    commodity clusters the stage boundaries ride the thin inter-node
    links), TMP innermost on the fast lanes."""
    return _mk((pp, data, model), ("pipe", "data", "model"))


_MESH_HELP = ("expected 'DxM' (data x model, e.g. '32x8') or 'DxMxxMy' "
              "(2D hybrid, e.g. '16x8x2'); a pipeline axis is prepended "
              "with pp= / --pp, giving PxDxM")


def parse_mesh_spec(spec: str, *, pp: int = 0):
    """Pure parser (no device construction): ``spec`` -> (shape, axes).

    Friendly-errors every malformed form instead of crashing deep in mesh
    construction: non-integer components, wrong component counts, and bad
    pipeline degrees all name the offending token and the accepted grammar.
    """
    parts = [t.strip() for t in str(spec).split("x")]
    shape = []
    for tok in parts:
        if not tok.isdigit() or int(tok) <= 0:
            raise ValueError(
                f"bad mesh spec {spec!r}: component {tok!r} is not a "
                f"positive integer — {_MESH_HELP}")
        shape.append(int(tok))
    if len(shape) == 2:
        axes = ("data", "model")
    elif len(shape) == 3:
        axes = ("data", "model_x", "model_y")
    else:
        raise ValueError(
            f"bad mesh spec {spec!r}: {len(shape)} component(s) — "
            f"{_MESH_HELP}")
    if pp:
        if not isinstance(pp, int) or pp < 1:
            raise ValueError(
                f"bad pipeline degree pp={pp!r}: must be a positive int")
        if pp > 1:
            shape = [pp] + shape
            axes = ("pipe",) + axes
    return tuple(shape), axes


def parse_mesh_shape(spec: str, *, pp: int = 0):
    """'dxm' -> 1D ('data','model'); 'dxm1xm2' -> 2D mesh; ``pp > 1``
    prepends a 'pipe' axis (PxDxM)."""
    shape, axes = parse_mesh_spec(spec, pp=pp)
    return _mk(shape, axes)


def parse_degrees(spec: str):
    """'8,4x2,16' -> [8, (4, 2), 16]: per-layer TMP degrees, 'AxB' = 2D.

    Validates every token up front (positive power-of-two components) so a
    typo'd plan fails with the grammar instead of a deep axis-algebra
    crash."""
    def _pow2(tok: str, n: int) -> int:
        if n <= 0 or n & (n - 1):
            raise ValueError(
                f"bad degree spec {spec!r}: component {tok!r} — TMP "
                f"degrees must be positive powers of two (paper §4.2)")
        return n

    def _int(tok: str, part: str) -> int:
        if not part.isdigit():
            raise ValueError(
                f"bad degree spec {spec!r}: component {tok!r} is not a "
                f"degree — expected comma-separated entries 'N' (1D) or "
                f"'AxB' (2D), e.g. '8,4x2,16'")
        return int(part)

    out = []
    for tok in (t.strip() for t in str(spec).split(",")):
        if "x" in tok:
            parts = tok.split("x")
            if len(parts) != 2:
                raise ValueError(
                    f"bad degree spec {spec!r}: 2D entry {tok!r} must be "
                    f"exactly 'AxB', e.g. '4x2'")
            out.append((_pow2(tok, _int(tok, parts[0])),
                        _pow2(tok, _int(tok, parts[1]))))
        elif tok:
            out.append(_pow2(tok, _int(tok, tok)))
        else:
            raise ValueError(
                f"bad degree spec {spec!r}: empty entry — expected "
                f"comma-separated 'N' or 'AxB' tokens, e.g. '8,4x2,16'")
    if not out:
        raise ValueError(f"bad degree spec {spec!r}: no entries")
    return out


def make_smoke_mesh(devices=None):
    """1x1 (or all-local-devices) mesh for CPU smoke tests."""
    n = len(devices or jax.devices())
    d = max(1, n // 4) if n >= 4 else 1
    return _mk((d, n // d), ("data", "model"))


# --------------------------------------------------------------------------
# plan desugaring — THE place legacy launcher flags become a ParallelPlan
# --------------------------------------------------------------------------
def resolve_mesh_spec(spec: str = "auto", *, pp: int = 1,
                      multi_pod: bool = False, devices=None):
    """One mesh resolution shared by every launcher: named meshes
    (``auto`` / ``production`` / ``multipod`` / ``factored``) or an
    explicit ``DxM`` / ``DxMxxMy`` grid, with ``pp`` prepending the
    ``pipe`` stage axis."""
    pp = max(pp, 1)
    if spec in ("production", "multipod", "factored"):
        if pp > 1:
            raise SystemExit(
                f"--pp does not compose with --mesh {spec} yet — use an "
                f"explicit 'dxm' spec (e.g. --pp {pp} --mesh 8x16) or "
                f"--mesh auto")
        if spec == "factored":
            return make_factored_mesh(multi_pod=multi_pod)
        return make_production_mesh(multi_pod=multi_pod
                                    or spec == "multipod")
    if spec == "auto":
        if pp > 1:
            n = len(devices or jax.devices())
            if n % pp:
                raise SystemExit(f"--pp {pp} does not divide the "
                                 f"{n} available devices")
            return make_pipeline_mesh(pp, max(n // pp, 1), 1)
        return make_smoke_mesh(devices)
    return parse_mesh_shape(spec, pp=pp)


def mesh_signature(mesh):
    """(shape, axes) of a mesh — what a ParallelPlan records."""
    axes = tuple(mesh.axis_names)
    shape = dict(mesh.shape)
    return tuple(int(shape[a]) for a in axes), axes


def resolve_launch(cfg, hp, *, mesh: str = "auto", pp: int = 1,
                   plan_file: str = "", save_plan: str = "",
                   degrees=None, schedules=None, decode_micro: int = 0,
                   devices=None, log=print):
    """The single plan-desugaring path (train/serve/dryrun all call it):

    * ``--plan plan.json``: the file IS the source of truth — its knobs
      override the legacy flags (``hp`` keeps only the non-parallelism
      fields), its recorded mesh is rebuilt when present, and the legacy
      mesh flags resolve it otherwise;
    * legacy flags: the mesh resolves as before and the scattered knobs
      (schedule, tmp-layout, pp, virtual stages, microbatch, split,
      decode-micro, per-layer degrees) desugar into one ParallelPlan.

    ``--save-plan out.json`` writes the resolved plan either way.
    Returns ``(mesh, plan, hp)`` with ``hp`` already projected through
    the plan (``plan.apply``)."""
    from repro.core.plan import ParallelPlan
    if plan_file:
        plan = ParallelPlan.load(plan_file).validate_for(cfg)
        hp = plan.apply(hp)
        if plan.mesh_shape:
            m = _mk(plan.mesh_shape, plan.mesh_axes)
        else:
            m = resolve_mesh_spec(mesh, pp=plan.pp, devices=devices)
        log(f"[plan] loaded {plan_file}: {plan.summary()}")
    else:
        m = resolve_mesh_spec(mesh, pp=pp, devices=devices)
        shape, axes = mesh_signature(m)
        plan = ParallelPlan.from_hparams(
            hp, cfg.num_layers, degrees=degrees, schedules=schedules,
            mesh_shape=shape, mesh_axes=axes, pp=max(pp, 1),
            decode_micro=decode_micro)
        hp = plan.apply(hp)
    if save_plan:
        plan.save(save_plan)
        log(f"[plan] wrote {save_plan}: {plan.summary()}")
    return m, plan, hp
