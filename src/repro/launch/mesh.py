"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real (1-device) topology.
"""
from __future__ import annotations

import jax

from repro.core import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes,
                            axis_types=compat.auto_axis_types(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_factored_mesh(*, multi_pod: bool = False):
    """Planner-mode mesh: the 16-way model axis factored into binary
    sub-axes so per-layer TMP degrees in {1,2,4,8,16} — 1D ints or 2D
    ``(dx, dy)`` tuples (x = leading sub-axes, y = the next) — are
    expressible."""
    shape = (2, 16, 2, 2, 2, 2) if multi_pod else (16, 2, 2, 2, 2)
    axes = (("pod", "data", "t1", "t2", "t3", "t4") if multi_pod
            else ("data", "t1", "t2", "t3", "t4"))
    return _mk(shape, axes)


def make_2d_mesh(data: int, dx: int, dy: int):
    """Uniform 2D hybrid-partition mesh ``('data','model_x','model_y')``:
    weight width shards over the dx-way intra-node axis, the contraction
    dim over the dy-way inter-node axis (commodity-server placement)."""
    return _mk((data, dx, dy), ("data", "model_x", "model_y"))


def parse_mesh_shape(spec: str):
    """'dxm' -> 1D ('data','model'); 'dxm1xm2' -> 2D mesh."""
    parts = [int(x) for x in spec.split("x")]
    if len(parts) == 2:
        return _mk(tuple(parts), ("data", "model"))
    if len(parts) == 3:
        return make_2d_mesh(*parts)
    raise ValueError(f"mesh spec must be dxm or dxmxm2, got {spec!r}")


def make_smoke_mesh(devices=None):
    """1x1 (or all-local-devices) mesh for CPU smoke tests."""
    n = len(devices or jax.devices())
    d = max(1, n // 4) if n >= 4 else 1
    return _mk((d, n // d), ("data", "model"))
