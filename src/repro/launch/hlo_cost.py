"""Roofline-term extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified:
an 8-iteration scan reports 1/8 the flops), which would corrupt the roofline
for scan-over-layers models.  This walker parses the HLO module, multiplies
loop bodies by their ``known_trip_count``, and accumulates:

* dot flops                  (2 * numel(out) * contracted size)
* HBM traffic estimate       (Σ operand+output bytes of top-level ops at
                              fusion granularity — fusion internals are
                              register/VMEM traffic, not HBM)
* per-chip collective bytes  (ring-model factors per collective kind)

All shapes in the SPMD module are per-shard, so the derived terms are
per-chip seconds directly.  Cross-checked against cost_analysis() on
loop-free modules in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[\\\"{:n\s]*?(\d+)')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

# ops that do not touch HBM / carry no payload themselves
_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "iota", "while", "conditional", "call", "custom-call",
         "partition-id", "replica-id", "rng-get-and-update-state",
         "get-dimension-size", "opt-barrier", "domain",
         "async-start", "async-update", "async-done"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_numel(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES or DTYPE_BYTES[dt] == 0:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


@dataclass
class Op:
    name: str
    kind: str
    shape: str
    rest: str                    # text after '(' — operands + attrs
    called: List[str] = field(default_factory=list)
    trip: int = 1


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        s = re.sub(r"/\*.*?\*/", "", s)   # '/*index=5*/' in tuple shapes
        # computation headers end with '{' and contain no ' = ' (op lines do)
        if s.endswith("{") and " = " not in s:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if s.startswith("}"):
            continue
        om = _OP_RE.match(s)
        if om and cur is not None:
            name, shape, kind, rest = om.groups()
            op = Op(name=name, kind=kind, shape=shape.strip(), rest=rest)
            if kind == "while":
                b = _CALLED_RE.search(rest)
                if b:
                    op.called.append(b.group(1))
                c = _COND_RE.search(rest)
                if c:
                    op.called.append(c.group(1))
                t = _TRIP_RE.search(rest)
                op.trip = int(t.group(1)) if t else 1
            elif kind in ("call", "fusion", "custom-call", "async-start"):
                b = _CALLED_RE.search(rest)
                if b:
                    op.called.append(b.group(1))
            elif kind == "conditional":
                br = _BRANCHES_RE.search(rest)
                if br:
                    op.called.extend(
                        x.strip().lstrip("%") for x in br.group(1).split(","))
            cur.ops.append(op)
            cur.shapes[name] = op.shape
    return comps, entry


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_numel = _shape_numel(op.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    ops_m = re.findall(r"%([\w\.\-]+)", op.rest)
    if not m or not ops_m:
        return 2.0 * out_numel  # fallback
    lhs_shape = shapes.get(ops_m[0], "")
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 2.0 * out_numel
    dims = [int(d) for d in dims_m.group(2).split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * out_numel * k


def _collective_cost(op: Op, default_group: int) -> Tuple[float, float]:
    """Returns (payload_bytes, per_chip_link_bytes) using ring factors."""
    size = _shape_bytes(op.shape)
    n = max(_group_size(op.rest, default_group), 1)
    kind = op.kind.replace("-start", "")
    if kind.startswith("all-reduce"):
        return size, 2.0 * size * (n - 1) / n
    if kind.startswith("all-gather"):
        return size, size * (n - 1) / n            # size = gathered output
    if kind.startswith("reduce-scatter"):
        return size, size * (n - 1)                # size = scattered output
    if kind.startswith("all-to-all") or kind.startswith("ragged"):
        return size, size * (n - 1) / n
    if kind.startswith("collective"):
        return size, size
    return 0.0, 0.0


@dataclass
class HloCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_link_bytes: float = 0.0
    collective_payload_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_by_kind: Dict[str, float] = field(default_factory=dict)

    def to_dict(self):
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_link_bytes": self.collective_link_bytes,
            "collective_payload_bytes": self.collective_payload_bytes,
            "collective_counts": dict(self.collective_counts),
            "collective_by_kind": dict(self.collective_by_kind),
        }

    def roofline_seconds(self, *, peak_flops: float, hbm_bw: float,
                         link_bw: float, mxu_eff: float = 1.0) -> Dict:
        """Roofline step-time estimate from the extracted HLO terms.

        ``serial_s`` charges compute + comm back-to-back (a blocking
        schedule); ``overlapped_s`` is the fused/collective-matmul bound
        ``max(T_compute, T_comm)`` — comm below the compute roofline is
        free when the kernel streams tiles into the ring.  The gap between
        the two is the step time a fused schedule can recover.
        """
        t_compute = max(self.dot_flops / max(peak_flops * mxu_eff, 1.0),
                        self.hbm_bytes / max(hbm_bw, 1.0))
        t_comm = self.collective_link_bytes / max(link_bw, 1.0)
        return {
            "compute_s": t_compute,
            "comm_s": t_comm,
            "serial_s": t_compute + t_comm,
            "overlapped_s": max(t_compute, t_comm),
        }


def analyze(text: str, *, default_group: int = 1) -> HloCost:
    comps, entry = parse_hlo(text)
    counts: Dict[str, float] = defaultdict(float)
    by_kind: Dict[str, float] = defaultdict(float)

    dots_memo: Dict[str, float] = {}

    def cost_of_dots_only(cname: str) -> float:
        if cname in dots_memo:
            return dots_memo[cname]
        comp = comps.get(cname)
        total = 0.0
        if comp:
            for op in comp.ops:
                if op.kind == "dot":
                    total += _dot_flops(op, comp.shapes)
                elif op.called:
                    t = op.trip if op.kind == "while" else 1
                    total += sum(cost_of_dots_only(c) for c in op.called[:1]) * t
        dots_memo[cname] = total
        return total

    def _operands(op: Op) -> List[str]:
        head = op.rest.split("), ")[0]
        return re.findall(r"%([\w\.\-]+)", head)

    def _inplace_corrected_bytes(comp: Computation, op: Op) -> float:
        """HBM traffic of a fusion/op, correcting in-place buffer patterns:
        a dynamic-update-slice touches only the update slice (XLA aliases
        the buffer), and a dynamic-slice reads only the slice — without this
        a scan's stacked-weight reads and carry writes count the full [L,...]
        buffer once per iteration (O(L^2) overcount)."""
        out_b = _shape_bytes(op.shape)
        opnds = _operands(op)
        total = out_b + sum(_shape_bytes(comp.shapes.get(o, ""))
                            for o in opnds)
        if op.kind == "dynamic-update-slice":
            upd = _shape_bytes(comp.shapes.get(opnds[1], "")) if len(opnds) > 1 \
                else 0
            return 2.0 * upd + 64
        if op.kind == "dynamic-slice":
            return 2.0 * out_b + 64
        if op.kind == "fusion" and op.called:
            sub = comps.get(op.called[0])
            if sub is not None:
                for inner in sub.ops:
                    if inner.kind == "dynamic-update-slice":
                        iopnds = _operands(inner)
                        buf = _shape_bytes(sub.shapes.get(iopnds[0], "")) \
                            if iopnds else 0
                        upd = _shape_bytes(sub.shapes.get(iopnds[1], "")) \
                            if len(iopnds) > 1 else 0
                        # buffer appears as fusion operand AND output: drop
                        # both, charge the slice write
                        total -= 2.0 * buf
                        total += 2.0 * upd
                    elif inner.kind == "dynamic-slice":
                        iopnds = _operands(inner)
                        buf = _shape_bytes(sub.shapes.get(iopnds[0], "")) \
                            if iopnds else 0
                        if buf > 4 * _shape_bytes(inner.shape):
                            total -= buf
                            total += 2.0 * _shape_bytes(inner.shape)
        return max(total, 0.0)

    def walk(cname: str, mult: float, acc: HloCost, seen_depth=0):
        comp = comps.get(cname)
        if comp is None or seen_depth > 64:
            return
        for op in comp.ops:
            if op.kind == "while":
                if op.called:
                    walk(op.called[0], mult * op.trip, acc, seen_depth + 1)
                continue
            if op.kind == "call":
                if op.called:
                    walk(op.called[0], mult, acc, seen_depth + 1)
                continue
            if op.kind == "conditional":
                for c in op.called:
                    walk(c, mult, acc, seen_depth + 1)
                continue
            kind_base = op.kind.replace("-start", "")
            if kind_base in COLLECTIVES and not op.kind.endswith("-done") \
                    and not op.kind.endswith("-update"):
                payload, link = _collective_cost(op, default_group)
                acc.collective_payload_bytes += payload * mult
                acc.collective_link_bytes += link * mult
                acc.hbm_bytes += 2 * payload * mult
                acc.collective_counts[kind_base] = \
                    acc.collective_counts.get(kind_base, 0) + mult
                acc.collective_by_kind[kind_base] = \
                    acc.collective_by_kind.get(kind_base, 0.0) + link * mult
                continue
            if op.kind in _SKIP:
                continue
            if op.kind == "dot":
                acc.dot_flops += _dot_flops(op, comp.shapes) * mult
            elif op.kind == "fusion" and op.called:
                acc.dot_flops += cost_of_dots_only(op.called[0]) * mult
            acc.hbm_bytes += _inplace_corrected_bytes(comp, op) * mult

    acc = HloCost()
    if entry is None and comps:
        # fall back: the computation that is not called by anyone
        called = {c for comp in comps.values() for op in comp.ops
                  for c in op.called}
        candidates = [c for c in comps if c not in called]
        entry = candidates[-1] if candidates else list(comps)[-1]
    if entry:
        walk(entry, 1.0, acc)
    return acc
