import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.base import SHAPES, TrainHParams, applicable_shapes  # noqa: E402
from repro.configs.registry import ASSIGNED, get_config, get_shape      # noqa: E402
from repro.core.axes import mesh_info                                   # noqa: E402
from repro.launch import hlo_cost                                       # noqa: E402
from repro.launch.mesh import make_factored_mesh, make_production_mesh  # noqa: E402
from repro.launch.steps import input_specs, step_fn_for                 # noqa: E402

# TPU v5e chip constants (roofline targets; this container only compiles)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
HBM_CAP = 16e9               # bytes


def model_flops_per_chip(cfg, shape, n_chips: int) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens          # fwd + bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:                                        # decode: one token per seq
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips


def parse_degrees(spec: str):
    """'8,4x2,16' -> [8, (4, 2), 16] (validated; see launch/mesh.py)."""
    from repro.launch.mesh import parse_degrees as _parse
    return _parse(spec)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             schedule: str = "oases", fine_remat: bool = True,
             planner_degrees=None, seq_parallel: bool = False,
             seq_shard: int = 1,
             split: int = 2, microbatch: int = 0,
             mesh_shape: str = "", tmp_layout: str = "auto",
             pp: int = 1, virtual_stages: int = 1, hw=None,
             plan_file: str = "", save_plan: str = "",
             plan_only: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "schedule": schedule, "fine_remat": fine_remat,
        "planner": planner_degrees is not None,
        "tmp_layout": tmp_layout, "pp": pp,
    }
    if shape.name not in {s.name for s in applicable_shapes(cfg)}:
        rec["status"] = "SKIP"
        rec["reason"] = ("full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md)")
        return rec

    t0 = time.perf_counter()
    hp = TrainHParams(schedule=schedule, fine_remat=fine_remat,
                      seq_parallel=seq_parallel, seq_shard=seq_shard,
                      split=split,
                      microbatch=microbatch, tmp_layout=tmp_layout,
                      virtual_stages=virtual_stages)
    if plan_file or mesh_shape:
        # the shared plan-desugaring path (launch/mesh.py): an explicit
        # device grid or a ParallelPlan file.  mesh_shape is the
        # hillclimb lever: reshape the 256 chips (e.g. "32x8" = more DP,
        # less TMP; "16x8x2" = a 2D hybrid model grid; --pp prepends the
        # pipeline stage axis).  The baseline table always uses 16x16.
        from repro.launch.mesh import resolve_launch
        mesh, pplan, hp = resolve_launch(
            cfg, hp, mesh=mesh_shape or "auto", pp=pp,
            plan_file=plan_file, save_plan=save_plan,
            degrees=planner_degrees)
        planner_degrees = pplan.planned_degrees
        rec["mesh_shape"] = mesh_shape or "x".join(
            map(str, pplan.mesh_shape))
        rec["plan"] = pplan.summary()
    else:
        from repro.core.plan import ParallelPlan
        from repro.launch.mesh import mesh_signature
        if pp > 1:
            from repro.launch.mesh import make_pipeline_mesh
            # 256 chips: pp stages x dp x 16-way TMP
            if 256 % (pp * 16):
                raise ValueError(
                    f"--pp {pp} does not divide the 256-chip production "
                    f"mesh (pp x 16-way TMP must divide 256 — pick pp in "
                    f"1/2/4/8/16, or pass an explicit --mesh-shape)")
            mesh = make_pipeline_mesh(pp, 256 // (pp * 16), 16)
        else:
            mesh = (make_factored_mesh(multi_pod=multi_pod)
                    if planner_degrees
                    else make_production_mesh(multi_pod=multi_pod))
        mshape, maxes = mesh_signature(mesh)
        pplan = ParallelPlan.from_hparams(
            hp, cfg.num_layers, degrees=planner_degrees,
            mesh_shape=mshape, mesh_axes=maxes, pp=pp)
        rec["plan"] = pplan.summary()
        if save_plan:
            pplan.save(save_plan)
            print(f"[plan] wrote {save_plan}: {pplan.summary()}")
    info = mesh_info(mesh)
    rec["microbatch"] = microbatch
    if plan_only:
        # --save-plan/--plan round-trip smoke (CI): resolve + desugar only
        rec["status"] = "PLAN_ONLY"
        rec["n_chips"] = info.mesh.size
        return rec
    if hw is not None and shape.kind == "train":
        # profile-guided planning: feed the calibrated chip numbers to the
        # joint PP x TMP search and record its decision next to the
        # measured-HLO terms of this cell
        from repro.core.planner import plan_joint
        jp = plan_joint(cfg, shape, hp, hw, virtual_stages=virtual_stages)
        rec["calibrated_joint_plan"] = {
            "pp": jp.pp, "n_micro": jp.n_micro,
            "degrees": [list(d) if isinstance(d, tuple) else d
                        for d in jp.degrees],
            "predicted_ms": round(jp.predicted_s * 1e3, 3),
            "bubble_fraction": round(jp.bubble_fraction, 4),
        }
        print(f"calibrated joint plan: {jp.summary()}")
    inputs = input_specs(cfg, shape, mesh, hp, plan=pplan)
    fn = step_fn_for(cfg, shape, mesh, hp, plan=pplan)
    # donate params+opt (train) / kv-cache (decode): buffers alias in place
    donate = (0, 1) if shape.kind == "train" else \
        ((1,) if shape.kind == "decode" else ())
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*inputs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)                              # proves it fits
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
            ca = ca[0] if ca else {}
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        hc = hlo_cost.analyze(compiled.as_text(), default_group=info.tp)

    n_chips = info.mesh.size
    terms = {
        "compute_s": hc.dot_flops / PEAK_FLOPS,
        "memory_s": hc.hbm_bytes / HBM_BW,
        "collective_s": hc.collective_link_bytes / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(cfg, shape, n_chips)
    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    rec.update({
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "mem": {"argument_bytes": arg_b, "temp_bytes": tmp_b,
                "output_bytes": out_b, "alias_bytes": alias_b,
                "peak_est_bytes": arg_b + tmp_b + out_b - alias_b,
                "fits_16GB": bool(arg_b + tmp_b + out_b - alias_b < HBM_CAP)},
        "xla_cost": {k: ca.get(k, 0.0) for k in ("flops", "bytes accessed")},
        "hlo": hc.to_dict(),
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": mf / hc.dot_flops if hc.dot_flops else 0.0,
        "roofline_fraction": (
            mf / PEAK_FLOPS) / max(terms.values()) if max(terms.values()) else 0.0,
    })
    return rec


def _sweep(args):
    cells = []
    archs = args.arch.split(",") if args.arch else ASSIGNED
    shapes = args.shape.split(",") if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))
    done = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done[(r["arch"], r["shape"], r["mesh"],
                          r.get("schedule", "oases"))] = r
                except json.JSONDecodeError:
                    pass
    for a, s, m in cells:
        key = (a, s, m, args.schedule)
        if key in done and done[key].get("status") in ("OK", "SKIP") \
                and not args.force:
            print(f"[cached] {key} {done[key]['status']}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--mesh", m,
               "--schedule", args.schedule, "--out", args.out]
        if not args.fine_remat:
            cmd.append("--no-fine-remat")
        print(f"[run] {a} x {s} x {m} ...", flush=True)
        t0 = time.perf_counter()
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            tail = (p.stdout + p.stderr).strip().splitlines()[-3:]
            print(f"   -> rc={p.returncode} {time.perf_counter()-t0:.0f}s "
                  + (" | ".join(tail) if p.returncode else ""), flush=True)
            if p.returncode:
                with open(args.out, "a") as f:
                    f.write(json.dumps({
                        "arch": a, "shape": s, "mesh": m,
                        "schedule": args.schedule, "status": "ERROR",
                        "error": "\n".join(tail)}) + "\n")
        except subprocess.TimeoutExpired:
            print("   -> TIMEOUT", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps({
                    "arch": a, "shape": s, "mesh": m,
                    "schedule": args.schedule, "status": "TIMEOUT"}) + "\n")


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--schedule", default="oases")
    ap.add_argument("--no-fine-remat", dest="fine_remat", action="store_false")
    ap.add_argument("--split", type=int, default=2)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--seq-shard", type=int, default=1,
                    help="ring-attention sequence shards per attention "
                         "layer (power of two; must equal the mesh model "
                         "group size — DESIGN.md §12)")
    ap.add_argument("--degrees", default="",
                    help="comma-separated per-layer TMP degrees (planner "
                         "mode); 'AxB' entries are 2D, e.g. 8,4x2,16")
    ap.add_argument("--tmp-layout", default="auto",
                    choices=["auto", "1d", "2d"],
                    help="partition layout (1d classic / 2d hybrid / auto)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="force gradient-accumulation / 1F1B microbatch "
                         "count (0 = auto)")
    ap.add_argument("--mesh-shape", default="",
                    help="override single-pod mesh, e.g. 32x8")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stages (prepends a 'pipe' "
                         "axis to the mesh)")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="interleaved-1F1B virtual stages per device")
    ap.add_argument("--calibrate", action="store_true", default=True,
                    help="profile-guided planner inputs (the DEFAULT: "
                         "HWConfig.from_measurements via the per-host "
                         "calibration cache)")
    ap.add_argument("--no-calibrate", dest="calibrate",
                    action="store_false",
                    help="skip on-device calibration; plan with the stock "
                         "chip numbers")
    ap.add_argument("--plan", default="", metavar="plan.json",
                    help="dry-run an executable ParallelPlan file "
                         "(overrides the legacy parallelism flags)")
    ap.add_argument("--save-plan", default="", metavar="out.json",
                    help="write the resolved ParallelPlan for later "
                         "--plan runs")
    ap.add_argument("--plan-only", action="store_true",
                    help="resolve the mesh + plan (and --save-plan/"
                         "--plan round-trip) without lowering/compiling "
                         "— the CI plan smoke")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    if args.sweep:
        _sweep(args)
        return

    hw_cal = None
    if args.calibrate and not args.plan_only:
        # default-on profile-guided planning (cached per host;
        # --no-calibrate restores the stock chip numbers).  --plan-only
        # resolves meshes without planning, so it skips the profile.
        from repro.core.planner.calibrate import calibrated_hw, describe
        hw_cal = calibrated_hw()
        print("calibrated HWConfig (profile-guided planner inputs):")
        print(json.dumps(describe(hw_cal), indent=1))

    degrees = parse_degrees(args.degrees) if args.degrees else None
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    for m in meshes:
        try:
            rec = run_cell(args.arch, args.shape, multi_pod=(m == "multi"),
                           schedule=args.schedule, fine_remat=args.fine_remat,
                           planner_degrees=degrees, split=args.split,
                           seq_parallel=args.seq_parallel,
                           seq_shard=args.seq_shard,
                           microbatch=args.microbatch,
                           mesh_shape=args.mesh_shape,
                           tmp_layout=args.tmp_layout,
                           pp=args.pp,
                           virtual_stages=args.virtual_stages,
                           hw=hw_cal,
                           plan_file=args.plan, save_plan=args.save_plan,
                           plan_only=args.plan_only)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": m,
                   "schedule": args.schedule, "status": "ERROR",
                   "error": traceback.format_exc()[-2000:]}
            print(traceback.format_exc())
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(json.dumps({k: rec[k] for k in rec
                          if k not in ("hlo", "xla_cost")}, indent=1))


if __name__ == "__main__":
    main()
