"""Serving launcher: batched requests through the continuous-batching
engine, on single-device or TMP / pipeline-parallel meshes.

    # single device (CPU smoke)
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 8 --slots 4

    # 2-way TMP with fused collective-matmul decode
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --mesh 1x2 --schedule fused

    # 2 pipeline stages x 2-way TMP (decode micro-steps stream through
    # the stages)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --pp 2 --mesh 1x2 --schedule fused

    # execute a saved ParallelPlan (e.g. train.py --save-plan / the
    # latency planner's .plan) — one file instead of the flag soup
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --plan plan.json

    # production-throughput path: paged KV blocks + prefix reuse +
    # speculative decoding (greedy, token-identical to undrafted decode)
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --paged --page-size 8 --prefix-cache \
        --draft internlm2-1.8b --spec-k 3
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    from repro.core.schedule import SCHEDULES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-len", type=int, default=0,
                    help="longest admissible prompt (engine admission "
                         "contract); 0 = derive max_seq // 2")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--schedule", default="oases", choices=list(SCHEDULES),
                    help="TMP overlap schedule for the decode matmuls "
                         "('fused' rings the collectives over the slot "
                         "batch)")
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 1x4) | dxm1xm2 (2D hybrid, "
                         "e.g. 1x2x2); --pp prepends a 'pipe' stage axis")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stages: decode micro-steps "
                         "stream through the stages (stage s decodes "
                         "micro-group g while stage s-1 decodes g+1)")
    ap.add_argument("--tmp-layout", default="auto",
                    choices=["auto", "1d", "2d"])
    ap.add_argument("--seq-shard", type=int, default=1,
                    help="recorded in the resolved plan for provenance; "
                         "decode itself always serves head-sharded (the "
                         "KV ring is a training/prefill layout)")
    ap.add_argument("--decode-micro", type=int, default=0,
                    help="decode micro-group count on a pipeline mesh "
                         "(0 = auto: pp * virtual stages)")
    ap.add_argument("--plan", default="", metavar="plan.json",
                    help="execute a ParallelPlan file (e.g. from train.py "
                         "--save-plan or the latency planner); overrides "
                         "the legacy parallelism flags in one shot")
    ap.add_argument("--save-plan", default="", metavar="out.json",
                    help="write the resolved serving ParallelPlan")
    ap.add_argument("--print-plan", default="",
                    choices=["", "commodity", "nvlink"],
                    help="print the latency-objective serving plan "
                         "(plan(objective='latency')) for this arch on a "
                         "fixture HWConfig before serving")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: fixed-size blocks in a shared "
                         "page pool with per-slot block tables "
                         "(serving/paged_cache.py); admission becomes "
                         "reservation-based with cache-full backpressure")
    ap.add_argument("--pages", type=int, default=0,
                    help="physical pages in the pool incl. the null page "
                         "(0 = auto: every slot can still reach max_seq)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (max_seq must divide evenly)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse cached prompt blocks across requests "
                         "(block-granular hashing, refcounted pages, COW "
                         "on first divergent write); requires --paged")
    ap.add_argument("--draft", default="", metavar="CONFIG",
                    help="draft model config for speculative decoding "
                         "(e.g. mamba2-130m; reduced alongside --reduced); "
                         "pair with --spec-k")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft tokens proposed per speculative round "
                         "(greedy acceptance is token-identical to "
                         "undrafted decode; plan_serving picks k per "
                         "cluster fixture)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default="", metavar="DIR",
                    help="write structured telemetry (JSONL) under DIR: "
                         "TTFT, per-token decode latency, queue depth, "
                         "slot occupancy; render with `python -m "
                         "repro.obs.report DIR`")
    ap.add_argument("--telemetry-flush", type=int, default=64,
                    metavar="N",
                    help="JSONL records buffered between file flushes "
                         "(must be positive; 1 = write-through)")
    args = ap.parse_args()

    telemetry = None
    if args.telemetry:
        from repro import obs
        if args.telemetry_flush <= 0:
            raise SystemExit(
                f"--telemetry-flush must be a positive number of records, "
                f"got {args.telemetry_flush} (use 1 for write-through)")
        telemetry = obs.configure(args.telemetry,
                                  flush_every=args.telemetry_flush,
                                  console=print)

    from repro.configs.base import TrainHParams
    from repro.configs.registry import get_config
    from repro.launch.mesh import resolve_launch
    from repro.serving import Request, ServingEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(dtype="float32")

    if args.print_plan:
        from repro.configs.base import ShapeConfig
        from repro.core.planner import COMMODITY_25GBE, NVLINK_BOX, plan
        hw = COMMODITY_25GBE if args.print_plan == "commodity" else NVLINK_BOX
        shape = ShapeConfig("serve_cli", args.max_seq, args.slots, "decode")
        pr = plan(cfg, shape, TrainHParams(schedule=args.schedule), hw,
                  options=tuple(n for n in (2, 4, 8, 16)
                                if n <= hw.n_chips) or (hw.n_chips,),
                  objective="latency")
        print(f"latency planner ({args.print_plan}): {pr.summary()}")

    hp = TrainHParams(schedule=args.schedule, tmp_layout=args.tmp_layout,
                      seq_shard=args.seq_shard)
    mesh, pplan, hp = resolve_launch(cfg, hp, mesh=args.mesh, pp=args.pp,
                                     plan_file=args.plan,
                                     save_plan=args.save_plan,
                                     decode_micro=args.decode_micro)
    draft_cfg = None
    if args.draft:
        draft_cfg = get_config(args.draft)
        if args.reduced:
            draft_cfg = draft_cfg.reduced().replace(dtype="float32")
    eng = ServingEngine(cfg, mesh, slots=args.slots, max_seq=args.max_seq,
                        hp=hp, prefill_len=args.prefill_len or None,
                        plan=pplan, telemetry=telemetry,
                        paged=args.paged, pages=args.pages,
                        page_size=args.page_size,
                        prefix_cache=args.prefix_cache,
                        draft=draft_cfg, spec_k=args.spec_k)
    eng.load(seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        hi = max(min(12, eng.prefill_len + 1), 2)
        plen = int(rng.integers(min(4, hi - 1), hi))
        r = Request(rid=i,
                    prompt=rng.integers(3, cfg.vocab_size, plen,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new_tokens)
        reqs.append(r)
        eng.submit(r)
    stats = eng.run_until_drained()
    if telemetry is not None:
        telemetry.close()
    print(json.dumps({**stats,
                      "mesh": dict(mesh.shape),
                      "schedule": hp.schedule,
                      "plan": pplan.summary(),
                      "prefill_len": eng.prefill_len,
                      "sample_output": reqs[0].out_tokens[:8]}, indent=1))


if __name__ == "__main__":
    main()
