"""Serving launcher: batched requests through the continuous-batching
engine.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs.registry import get_config
    from repro.launch.mesh import make_smoke_mesh
    from repro.serving import Request, ServingEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(dtype="float32")
    mesh = make_smoke_mesh()
    eng = ServingEngine(cfg, mesh, slots=args.slots, max_seq=args.max_seq)
    eng.load(seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        r = Request(rid=i,
                    prompt=rng.integers(3, cfg.vocab_size, plen,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new_tokens)
        reqs.append(r)
        eng.submit(r)
    stats = eng.run_until_drained()
    print(json.dumps({**stats,
                      "sample_output": reqs[0].out_tokens[:8]}, indent=1))


if __name__ == "__main__":
    main()
