from repro.runtime.elastic import (ElasticConfig, ElasticSupervisor,
                                   FaultError, FaultEvent, FaultMonitor,
                                   HeartbeatMonitor, HostLossError,
                                   LinkDegradedError, StragglerEscalation,
                                   Topology, heartbeat_path, mesh_for)
from repro.runtime.trainer import (FailureInjector, StragglerDetector,
                                   Trainer, corrupt_checkpoint,
                                   run_with_restarts)

__all__ = ["ElasticConfig", "ElasticSupervisor", "FailureInjector",
           "FaultError", "FaultEvent", "FaultMonitor", "HeartbeatMonitor",
           "HostLossError", "LinkDegradedError", "StragglerDetector",
           "StragglerEscalation", "Topology", "Trainer",
           "corrupt_checkpoint", "heartbeat_path", "mesh_for",
           "run_with_restarts"]
