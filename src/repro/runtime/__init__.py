from repro.runtime.trainer import (FailureInjector, StragglerDetector,
                                   Trainer, run_with_restarts)

__all__ = ["FailureInjector", "StragglerDetector", "Trainer",
           "run_with_restarts"]
