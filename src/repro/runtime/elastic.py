"""Online elastic training: fault detection -> mid-run ILP replanning ->
in-memory relayout -> continue loss-continuously.

Commodity servers lose hosts, degrade NICs, and grow stragglers as a
matter of course; the paper's planner already knows how to cost a
heterogeneous topology (``HWConfig``), and PR 5's ``relayout_flat`` can
restack parameters exactly across arbitrary plan changes.  This module
closes the loop with a supervisory state machine:

    monitor ──fault──> degrade HWConfig ──> ilp.replan() ──> new mesh
        ^                                                       │
        │                 in-memory relayout (or ckpt restore)  │
        └────────────────── continue training <─────────────────┘

Pieces:

* **Fault taxonomy** — :class:`FaultEvent` + typed :class:`FaultError`
  subclasses (``HostLossError``, ``LinkDegradedError``) the trainer's
  step loop raises, either from the deterministic
  :class:`~repro.runtime.trainer.FailureInjector` (tests/CI chaos) or
  from a pluggable :class:`FaultMonitor`.
* **Monitors** — :class:`HeartbeatMonitor` (staleness of peer liveness
  files) and :class:`StragglerEscalation` (persistent slow steps via the
  existing :class:`~repro.runtime.trainer.StragglerDetector` escalate to
  a replanning fault with the measured slowdown).
* **Topology** — the supervisor's view of surviving hosts/chips and
  measured link health; maps to a degraded ``HWConfig`` for the ILP and
  to the surviving jax device list for the relaunch mesh.
* **ElasticSupervisor** — the loop: bounded replan budget, exponential
  restart backoff, device-to-device state carry via
  ``models/params.relayout_flat`` when the surviving mesh overlaps the
  old one, checkpoint-restore fallback otherwise, and graceful
  degradation to the last-known-good plan when the ILP fails or emits
  something inexecutable.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.core.plan import ParallelPlan

# --------------------------------------------------------------------------
# fault taxonomy
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultEvent:
    """One detected fault: what kind, when, and the measurements the
    supervisor needs to degrade the HWConfig for replanning."""
    kind: str                    # 'host-loss' | 'link-degraded' |
    #                              'straggler' | 'heartbeat-stale' |
    #                              'worker-failure'
    step: int = -1
    host: Optional[int] = None   # lost/stale host index (host-loss kinds)
    link_bw: Optional[float] = None   # measured bytes/s (link-degraded)
    slowdown: float = 1.0        # step-time inflation factor (straggler)
    detail: str = ""

    def describe(self) -> str:
        bits = [self.kind, f"step={self.step}"]
        if self.host is not None:
            bits.append(f"host={self.host}")
        if self.link_bw is not None:
            bits.append(f"bw={self.link_bw / 1e9:.2f}GB/s")
        if self.slowdown != 1.0:
            bits.append(f"slowdown={self.slowdown:.1f}x")
        if self.detail:
            bits.append(self.detail)
        return " ".join(bits)


class FaultError(RuntimeError):
    """A detected topology/health fault.  Carries the :class:`FaultEvent`
    so the supervisor can dispatch on kind; deliberately a RuntimeError
    subclass so legacy ``run_with_restarts`` callers fail loudly with a
    pointer to the elastic supervisor instead of restart-looping a mesh
    that no longer exists."""

    def __init__(self, event: FaultEvent):
        super().__init__(event.describe())
        self.event = event


class HostLossError(FaultError):
    def __init__(self, step: int, host: int, detail: str = ""):
        super().__init__(FaultEvent("host-loss", step=step, host=host,
                                    detail=detail))


class LinkDegradedError(FaultError):
    def __init__(self, step: int, link_bw: float, detail: str = ""):
        super().__init__(FaultEvent("link-degraded", step=step,
                                    link_bw=link_bw, detail=detail))


def fault_from_event(event: FaultEvent) -> FaultError:
    """The typed error a monitor-detected event escalates as."""
    if event.kind == "host-loss":
        return HostLossError(event.step, event.host or 0, event.detail)
    if event.kind == "link-degraded":
        return LinkDegradedError(event.step, event.link_bw or 0.0,
                                 event.detail)
    return FaultError(event)


# --------------------------------------------------------------------------
# pluggable fault monitors
# --------------------------------------------------------------------------
class FaultMonitor:
    """Interface the trainer polls every step.  ``observe_step`` sees each
    completed step's wall time; ``poll`` checks out-of-band state
    (heartbeat files, NIC counters).  Return a :class:`FaultEvent` to
    escalate — the trainer raises it as a :class:`FaultError` for the
    supervisor."""

    def observe_step(self, step: int, dt: float) -> Optional[FaultEvent]:
        return None

    def poll(self, step: int) -> Optional[FaultEvent]:
        return None


@dataclass
class HeartbeatMonitor(FaultMonitor):
    """Watches peer-worker heartbeat files (the atomic JSON the trainer
    writes each step) and escalates hosts whose heartbeat goes stale —
    the supervisor treats a stale host as lost.

    ``paths`` maps host index -> heartbeat file; ``clock`` is injectable
    for deterministic tests."""
    paths: Dict[int, str] = field(default_factory=dict)
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.time
    recorder: object = None          # None -> the process-global recorder
    _reported: set = field(default_factory=set)

    def read(self, path: str) -> Optional[dict]:
        """Parsed heartbeat, or None when missing/half-written (a torn
        non-atomic write must look stale, not crash the monitor)."""
        return read_heartbeat(path)

    def poll(self, step: int) -> Optional[FaultEvent]:
        now = self.clock()
        rec = self.recorder if self.recorder is not None \
            else obs.get_recorder()
        for host, path in self.paths.items():
            if host in self._reported:
                continue
            hb = self.read(path)
            age = now - hb["time"] if hb and "time" in hb else float("inf")
            if age != float("inf"):
                rec.gauge("elastic.heartbeat_age_s", age, host=host,
                          step=step)
                if isinstance(hb.get("step_time_ewma_s"), (int, float)):
                    rec.gauge("elastic.peer_step_ewma_s",
                              hb["step_time_ewma_s"], host=host, step=step)
            if age > self.timeout_s:
                self._reported.add(host)
                return FaultEvent(
                    "heartbeat-stale", step=step, host=host,
                    detail=(f"age={age:.1f}s" if age != float("inf")
                            else "missing"))
        return None


@dataclass
class StragglerEscalation(FaultMonitor):
    """Escalates the existing per-step EWMA/z-score straggler detection
    (:class:`~repro.runtime.trainer.StragglerDetector`) into a replanning
    fault once ``escalate_after`` consecutive steps flag slow — transient
    hiccups stay log lines, a persistently slow peer becomes a measured
    ``slowdown`` the supervisor replans against (AMP-style: the collective
    runs at the slowest peer's pace, so the ILP should re-cost links at
    ``bw / slowdown``).

    With ``peer_paths`` (host index -> heartbeat file, the enriched
    per-host files the trainer writes) the escalation also LOCALIZES the
    straggler: each peer's ``step_time_ewma_s`` is compared, and a host
    whose EWMA exceeds ``slow_factor`` x the median of the others is
    named in the escalated event's ``host`` field — so the supervisor can
    tell a slow host from a globally slow cluster."""
    detector: object = None          # StragglerDetector (default: fresh)
    escalate_after: int = 3
    peer_paths: Dict[int, str] = field(default_factory=dict)
    slow_factor: float = 1.25
    _consecutive: int = 0

    def __post_init__(self):
        if self.detector is None:
            from repro.runtime.trainer import StragglerDetector
            self.detector = StragglerDetector()

    def localize(self) -> Tuple[Optional[int], str]:
        """(slow host, per-host detail) from the peer heartbeats' step-time
        EWMAs; (None, "") when no host stands out (or <2 peers report)."""
        ewma = {}
        for host, path in self.peer_paths.items():
            hb = read_heartbeat(path)
            if hb and isinstance(hb.get("step_time_ewma_s"), (int, float)):
                ewma[host] = float(hb["step_time_ewma_s"])
        if len(ewma) < 2:
            return None, ""
        slow = max(ewma, key=ewma.get)
        rest = sorted(v for h, v in ewma.items() if h != slow)
        peers_med = rest[len(rest) // 2]
        detail = " per-host ewma: " + " ".join(
            f"h{h}={v * 1e3:.1f}ms" for h, v in sorted(ewma.items()))
        if ewma[slow] > self.slow_factor * max(peers_med, 1e-9):
            return slow, detail
        return None, detail

    def observe_step(self, step: int, dt: float) -> Optional[FaultEvent]:
        # mean BEFORE this observation: the healthy baseline the slow
        # step is compared against
        baseline = self.detector.mean or dt
        if self.detector.observe(step, dt):
            self._consecutive += 1
        else:
            self._consecutive = 0
        if self._consecutive >= self.escalate_after:
            self._consecutive = 0
            host, where = self.localize()
            return FaultEvent("straggler", step=step, host=host,
                              slowdown=max(dt / max(baseline, 1e-9), 1.0),
                              detail=f"{self.escalate_after} consecutive "
                                     f"slow steps" + where)
        return None


# --------------------------------------------------------------------------
# topology
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Topology:
    """The supervisor's current view of the cluster: hosts x chips, which
    hosts are lost, and the measured inter-node bandwidth (None = the
    HWConfig's configured value)."""
    n_hosts: int
    chips_per_host: int
    lost_hosts: frozenset = frozenset()
    link_bw_y: Optional[float] = None

    @property
    def alive_hosts(self) -> Tuple[int, ...]:
        return tuple(h for h in range(self.n_hosts)
                     if h not in self.lost_hosts)

    @property
    def n_chips(self) -> int:
        return len(self.alive_hosts) * self.chips_per_host

    def lose(self, host: int) -> "Topology":
        if host not in range(self.n_hosts) or host in self.lost_hosts:
            raise ValueError(f"host {host} is not an alive host of "
                             f"{self.n_hosts}x{self.chips_per_host}")
        lost = frozenset(self.lost_hosts | {host})
        if len(lost) >= self.n_hosts:
            raise ValueError("cannot lose the last host")
        return replace(self, lost_hosts=lost)

    def degrade_link(self, bw: float) -> "Topology":
        return replace(self, link_bw_y=max(float(bw), 1.0))

    def devices(self, all_devices: Optional[Sequence] = None) -> List:
        """Surviving jax devices: host h owns the contiguous slice
        ``[h*cph, (h+1)*cph)`` of the launch-time device list."""
        if all_devices is None:
            import jax
            all_devices = jax.devices()
        cph = self.chips_per_host
        out: List = []
        for h in self.alive_hosts:
            out.extend(all_devices[h * cph:(h + 1) * cph])
        return out

    def degraded_hw(self, hw) -> "object":
        """The ILP's view of what survived (``HWConfig.degrade``)."""
        return hw.degrade(n_chips=self.n_chips,
                          node_size=min(self.chips_per_host, self.n_chips),
                          link_bw_y=self.link_bw_y)


# --------------------------------------------------------------------------
# plan layout descriptors + state carry
# --------------------------------------------------------------------------
def plan_layout(plan: ParallelPlan) -> Dict:
    """The relayout descriptor (models/params.relayout_flat) of the
    parameter-tree layout a plan trains under."""
    if plan.grouping_signature()[0] == "grouped":
        layout = {"degrees": list(plan.degrees),
                  "schedules": list(plan.schedules)}
        if plan.has_seq_layers:
            # ring-attention seq shards break scan groups exactly like a
            # schedule change does (models/params.plan_groups)
            layout["seqs"] = list(plan.seqs)
        return layout
    # interleaving depth only stacks the params under a pipe axis —
    # normalize v to 1 at pp == 1, mirroring grouping_signature()
    return {"pp": plan.pp,
            "virtual_stages": plan.virtual_stages if plan.pp > 1 else 1}


# every params-like subtree of the (params, opt) state tuple: the three
# optimizer moments AND the grad-compress error-feedback buffers (a
# params-shaped tree when compression is on; the plain None leaf passes
# through the relayout as static either way)
STATE_PREFIXES = ("[0]", "[1]['master']", "[1]['m']", "[1]['v']",
                  "[1]['err']")


def state_remap(cfg, src_meta: Dict, dst_meta: Dict):
    """A flat-leaf ``{keystr: array} -> {keystr: array}`` transform that
    relayouts every params-like subtree of a (params, opt) state tuple
    from the ``src_meta`` plan layout to ``dst_meta`` — shared by the
    checkpoint-restore path (``Trainer._plan_remap``) and the in-memory
    elastic state carry (:meth:`ElasticSupervisor._carry_state`)."""
    from repro.models import params as prm

    def remap(by_key: Dict) -> Dict:
        out = {k: v for k, v in by_key.items()
               if not any(k.startswith(p) for p in STATE_PREFIXES)}
        for p in STATE_PREFIXES:
            sub = {k[len(p):]: v for k, v in by_key.items()
                   if k.startswith(p)}
            if not sub:
                continue
            for k2, v2 in prm.relayout_flat(cfg, sub, src_meta,
                                            dst_meta).items():
                out[p + k2] = v2
        return out

    return remap


def mesh_for(topology: Topology, plan: Optional[ParallelPlan] = None,
             *, default_tp: int = 0, devices: Optional[Sequence] = None):
    """A launch mesh over the surviving devices.

    A plan whose recorded ``mesh_shape`` fits the surviving chip count is
    honored exactly — including a shape using only a SUBSET of the
    survivors (the replanning ILP may decide 4 well-connected chips beat
    6 with a straggler; the first ``prod(mesh_shape)`` surviving devices
    are used).  Otherwise a plain ``(data, model)`` mesh with
    ``tp = default_tp`` (or the largest power of two <= the survivors)
    and everything else data-parallel."""
    from repro.core import compat

    devs = topology.devices(devices)
    n = len(devs)
    if plan is not None and plan.mesh_shape \
            and math.prod(plan.mesh_shape) <= n:
        return compat.make_mesh(
            tuple(plan.mesh_shape), tuple(plan.mesh_axes),
            axis_types=compat.auto_axis_types(len(plan.mesh_shape)),
            devices=devs[:math.prod(plan.mesh_shape)])
    tp = default_tp or 2 ** int(math.log2(n))
    tp = min(tp, n)
    while n % tp:
        tp //= 2
    return compat.make_mesh((n // tp, tp), ("data", "model"),
                            axis_types=compat.auto_axis_types(2),
                            devices=devs)


# --------------------------------------------------------------------------
# the supervisor
# --------------------------------------------------------------------------
@dataclass
class ElasticConfig:
    """Supervisor knobs."""
    max_replans: int = 3         # bounded replan budget per run
    max_restarts: int = 3        # plain worker-failure restarts
    backoff_s: float = 0.05      # restart backoff base (exponential)
    backoff_factor: float = 2.0
    replan_options: Tuple[int, ...] = (2, 4, 8, 16)
    replan_time_limit: float = 5.0
    restartable: Tuple = (RuntimeError,)


class ElasticSupervisor:
    """The fault-handling training loop.

    ``make_trainer(topology, plan)`` builds a Trainer for the surviving
    topology under ``plan`` (None = the caller's launch-time default);
    the supervisor owns WHEN to rebuild, with what plan, and how state
    crosses the boundary.  ``hw`` is the healthy-cluster HWConfig the
    degraded views derive from; ``shape``/``hp`` describe the workload
    for the replanning ILP.
    """

    def __init__(self, make_trainer, *, topology: Topology, cfg, shape,
                 hp, hw=None, econfig: Optional[ElasticConfig] = None,
                 log_fn: Callable[[str], None] = print,
                 telemetry=None):
        from repro.core.planner import costmodel as cm
        self.make_trainer = make_trainer
        self.topology = topology
        self.cfg = cfg
        self.shape = shape
        self.hp = hp
        self.hw = hw or cm.V5E.degrade(
            n_chips=topology.n_chips, node_size=topology.chips_per_host)
        self.ec = econfig or ElasticConfig()
        self.log = log_fn
        # same convention as Trainer: structured events with log_fn as the
        # console sink, so "[elastic] ..." lines keep printing by default
        self.rec = (telemetry if telemetry is not None
                    else obs.Recorder(console=log_fn))
        self.plan: Optional[ParallelPlan] = None  # None = launch default
        self.events: List[FaultEvent] = []
        self.replans = 0
        self.restarts = 0
        # after a fault the successor trainer is built eagerly (the state
        # relayout needs its specs); the next loop iteration reuses it
        # instead of compiling twice
        self._prebuilt = None

    # ---- replanning ------------------------------------------------------
    def _replan(self, event: FaultEvent,
                last_good: Optional[ParallelPlan]) -> None:
        """Re-run the ILP against the degraded topology; on failure (or
        budget exhaustion) degrade gracefully to the last-known-good plan
        clamped to the survivors."""
        from repro.core.planner import ilp

        hw_d = self.topology.degraded_hw(self.hw)
        if event.kind == "straggler" and event.slowdown > 1.0:
            hw_d = hw_d.degrade(bw_scale=1.0 / event.slowdown)
        if self.replans >= self.ec.max_replans:
            self.rec.event(
                "elastic.replan_exhausted", budget=self.ec.max_replans,
                msg=f"[elastic] replan budget exhausted "
                    f"({self.ec.max_replans}); keeping last-known-good")
            self.plan = self._fallback_plan(last_good)
            return
        t0 = time.perf_counter()
        try:
            pr = ilp.replan(self.cfg, self.shape, self.hp, hw_d,
                            options=self.ec.replan_options,
                            time_limit=self.ec.replan_time_limit)
            new_plan = pr.plan.validate_for(self.cfg)
            if math.prod(new_plan.mesh_shape or (0,)) > self.topology.n_chips:
                raise ValueError(
                    f"replanned mesh {new_plan.mesh_shape} exceeds the "
                    f"{self.topology.n_chips} surviving chips")
            self.replans += 1
            self.plan = new_plan
            dur = time.perf_counter() - t0
            self.rec.observe("elastic.replan_s", dur, step=event.step)
            self.rec.event(
                "elastic.replan", kind=event.kind, step=event.step,
                dur_s=round(dur, 4), plan=new_plan.summary(),
                msg=f"[elastic] replanned after {event.kind}: "
                    f"{pr.summary()} -> {new_plan.summary()}")
        except Exception as e:
            self.rec.observe("elastic.replan_s",
                             time.perf_counter() - t0, step=event.step)
            self.rec.event(
                "elastic.replan_failed", kind=event.kind, step=event.step,
                msg=f"[elastic] replan failed ({e!r}); degrading to "
                    f"last-known-good plan")
            self.plan = self._fallback_plan(last_good)

    def _fallback_plan(self, last_good: Optional[ParallelPlan]
                       ) -> Optional[ParallelPlan]:
        """Last-known-good, clamped to the surviving chip count: keep the
        schedules, shrink tp to the largest power of two that fits."""
        n = self.topology.n_chips
        if last_good is None:
            return None
        tp = 2 ** int(math.log2(n))
        if last_good.mesh_shape and math.prod(last_good.mesh_shape) <= n:
            return last_good
        return ParallelPlan.from_hparams(
            self.hp, last_good.num_layers,
            schedules=[last_good.primary_schedule] * last_good.num_layers,
            mesh_shape=(n // tp, tp), mesh_axes=("data", "model"))

    # ---- state carry -----------------------------------------------------
    def _carry_state(self, trainer, dst_trainer):
        """Device-to-device continuation: export the faulted trainer's
        live state, relayout it into the new trainer's parameter layout,
        and land it on the surviving mesh.  Returns the (params, opt,
        step) tuple for ``dst_trainer.train(state=...)``, or None when
        there is nothing to carry / the relayout fails (-> checkpoint
        restore)."""
        exported = trainer.export_state()
        if exported is None:
            return None
        try:
            with self.rec.span("elastic.state_carry_s"):
                state = dst_trainer.import_state(exported)
            self.rec.event(
                "elastic.state_carry", step=exported["step"],
                msg=f"[elastic] carried live state in-memory to step "
                    f"{exported['step']} "
                    f"({exported['sig'][0]} -> "
                    f"{dst_trainer.plan.grouping_signature()[0]})")
            return state
        except Exception as e:
            self.rec.event(
                "elastic.state_carry_failed",
                msg=f"[elastic] in-memory relayout failed ({e!r}); "
                    f"falling back to checkpoint restore")
            return None

    # ---- the loop --------------------------------------------------------
    def run(self, total_steps: int, *, ckpt_every: int = 50,
            seed: int = 0) -> Dict:
        losses: List[float] = []
        state = None
        while True:
            if self._prebuilt is not None:
                trainer, self._prebuilt = self._prebuilt, None
            else:
                trainer = self.make_trainer(self.topology, self.plan)
            if self.plan is None:
                # launch default = first last-known-good
                self.plan = trainer.plan
            try:
                res = trainer.train(total_steps, ckpt_every=ckpt_every,
                                    seed=seed, state=state)
                losses.extend(res["losses"])
                return {"losses": losses, "final_step": res["final_step"],
                        "slow_steps": res["slow_steps"],
                        "events": list(self.events),
                        "replans": self.replans,
                        "restarts": self.restarts,
                        "plan": self.plan,
                        "topology": self.topology}
            except (KeyboardInterrupt, SystemExit):
                raise
            except FaultError as e:
                ev = e.event
                self.events.append(ev)
                losses.extend(trainer.run_losses)
                self.rec.counter("elastic.faults", kind=ev.kind)
                self.rec.event(
                    "elastic.fault", kind=ev.kind, step=ev.step,
                    host=ev.host, slowdown=round(ev.slowdown, 3),
                    msg=f"[elastic] fault: {ev.describe()}")
                last_good = self.plan
                if ev.kind in ("host-loss", "heartbeat-stale"):
                    try:
                        self.topology = self.topology.lose(ev.host or 0)
                    except ValueError as te:
                        self.rec.event(
                            "elastic.unsurvivable",
                            msg=f"[elastic] unsurvivable: {te}")
                        raise e from None
                elif ev.kind == "link-degraded" and ev.link_bw:
                    self.topology = self.topology.degrade_link(ev.link_bw)
                self._replan(ev, last_good)
                new_trainer = self.make_trainer(self.topology, self.plan)
                state = self._carry_state(trainer, new_trainer)
                # hand the already-built trainer to the next iteration
                self._prebuilt = new_trainer
            except self.ec.restartable as e:
                self.restarts += 1
                losses.extend(trainer.run_losses)
                self.events.append(FaultEvent("worker-failure",
                                              detail=repr(e)))
                self.rec.counter("elastic.restarts")
                if self.restarts > self.ec.max_restarts:
                    raise
                wait = self.ec.backoff_s * \
                    self.ec.backoff_factor ** (self.restarts - 1)
                self.rec.event(
                    "elastic.restart", attempt=self.restarts,
                    msg=f"[elastic] worker failed ({e}); restart "
                        f"{self.restarts}/{self.ec.max_restarts} "
                        f"after {wait * 1e3:.0f} ms backoff")
                time.sleep(wait)
                state = None                 # restore from checkpoint
            if trainer.checkpointer.failed_saves:
                n_failed = trainer.checkpointer.failed_saves
                self.rec.event(
                    "elastic.ckpt_write_failures", count=n_failed,
                    msg=f"[elastic] note: {n_failed} failed "
                        f"checkpoint-write attempts so far")


def heartbeat_path(ckpt_dir: str) -> str:
    """Where a trainer writes its liveness file (atomic tmp+rename)."""
    return os.path.join(ckpt_dir, "heartbeat.json")


def read_heartbeat(path: str) -> Optional[dict]:
    """Parsed heartbeat JSON, or None when missing/half-written (a torn
    non-atomic write must look stale, not crash the reader)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
