"""Fault-tolerant training driver.

Responsibilities at 1000+-node scale (all exercised by tests on CPU):
* checkpoint/restart — async sharded checkpoints, resume from latest on
  (re)start, including after injected failures;
* straggler detection — per-step wall-time EWMA + z-score; slow steps are
  logged and surfaced to the orchestrator hook;
* elastic re-mesh — on resume the runner may bring a different mesh (e.g. a
  pod dropped); restore re-shards parameters and the data pipeline seeks to
  the restored step (no replay);
* heartbeats — a liveness file an external supervisor can watch.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint import store
from repro.configs.base import ArchConfig, TrainHParams
from repro.core.axes import mesh_info
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch import steps as steps_mod
from repro.models import params as prm
from repro.optim import adamw


@dataclass
class StragglerDetector:
    alpha: float = 0.1
    z_threshold: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 5:
            sd = math.sqrt(self.var) if self.var > 0 else 1e-9
            z = (dt - self.mean) / sd
            slow = z > self.z_threshold
        else:
            slow = False
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.n += 1
        if slow:
            self.slow_steps.append((step, dt))
        return slow


@dataclass
class FailureInjector:
    """Deterministic failure injection for FT tests."""
    fail_at_steps: tuple = ()

    def check(self, step: int):
        if step in self.fail_at_steps:
            raise RuntimeError(f"injected failure at step {step}")


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh, hp: TrainHParams, *,
                 global_batch: int, seq_len: int, ckpt_dir: str,
                 injector: Optional[FailureInjector] = None,
                 log_fn: Callable[[str], None] = print,
                 degrees=None, plan=None):
        from repro.core.plan import ParallelPlan
        from repro.launch.mesh import mesh_signature
        self.cfg = cfg
        self.mesh = mesh
        info = mesh_info(mesh)
        schedules = None
        if plan is not None:
            hp, degrees, schedules = steps_mod.unpack_plan(cfg, hp, plan,
                                                           degrees)
        else:
            # legacy callers: desugar the loose (hp, degrees) threading so
            # the checkpoint manifest ALWAYS records an executable plan
            mshape, maxes = mesh_signature(mesh)
            plan = ParallelPlan.from_hparams(
                hp, cfg.num_layers, degrees=degrees, mesh_shape=mshape,
                mesh_axes=maxes, pp=info.pp)
        self.plan = plan
        # one shared resolution with build_train_step: planner mode sees the
        # extra-dp-adjusted microbatcher; a pipeline mesh folds gradient
        # accumulation into the 1F1B schedule (hp.microbatch = n_micro)
        self.hp = steps_mod.resolve_for_mesh(cfg, info, hp, global_batch,
                                             seq_len, degrees)
        self.degrees = degrees
        self.schedules = schedules
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ckpt_dir = ckpt_dir
        self.injector = injector or FailureInjector()
        self.log = log_fn
        self.straggler = StragglerDetector()
        self.checkpointer = store.AsyncCheckpointer(ckpt_dir)

        self.step_fn, self.specs = steps_mod.build_train_step(
            cfg, mesh, self.hp, global_batch=global_batch, seq_len=seq_len,
            degrees=degrees, schedules=schedules)
        # buffer donation deadlocks XLA:CPU's intra-process collective
        # rendezvous (execution only — the dry-run donates at compile time);
        # enable it on real accelerators.
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        self.step_fn = jax.jit(self.step_fn, donate_argnums=donate)
        self.info = info

    # ---- state ----
    def _shardings(self):
        psh = prm.shardings_tree(self.specs, self.mesh)
        osp = adamw.opt_state_specs(self.specs, self.info,
                                    zero1=self.hp.zero1)
        osh = {
            "master": prm.shardings_tree(osp["master"], self.mesh),
            "m": prm.shardings_tree(osp["m"], self.mesh),
            "v": prm.shardings_tree(osp["v"], self.mesh),
            "step": jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()),
            "err": None,
        }
        return psh, osh

    def init_state(self, seed: int = 0):
        params = prm.init_params(self.specs, jax.random.PRNGKey(seed))
        opt = adamw.init_opt_state(params, self.specs, self.info,
                                   zero1=self.hp.zero1)
        psh, osh = self._shardings()
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt = jax.tree_util.tree_map(
            lambda v, s: v if v is None or s is None else jax.device_put(v, s),
            opt, osh, is_leaf=lambda x: x is None)
        return params, opt, 0

    @staticmethod
    def _plan_layout(plan) -> Dict:
        """The relayout descriptor (models/params.relayout_flat) of the
        parameter-tree layout a plan trains under."""
        if plan.grouping_signature()[0] == "grouped":
            return {"degrees": list(plan.degrees),
                    "schedules": list(plan.schedules)}
        # interleaving depth only stacks the params under a pipe axis —
        # normalize v to 1 at pp == 1, mirroring grouping_signature()
        return {"pp": plan.pp,
                "virtual_stages": plan.virtual_stages if plan.pp > 1 else 1}

    def _plan_remap(self, metadata: Dict):
        """Cross-plan elastic resume: when the checkpoint's recorded plan
        trains under a different parameter-tree grouping than the current
        one (grouped planner layouts vs the stacked layout, including
        mixed-schedule -> global-schedule transitions), return a
        flat-leaf remap that restacks the canonical layer order into the
        current layout.  Stacked -> stacked pp changes keep the existing
        pure-reshape path (store.restore)."""
        from repro.core.plan import ParallelPlan
        cur_sig = self.plan.grouping_signature()
        saved_d = metadata.get("plan")
        if saved_d is not None:
            saved = ParallelPlan.from_dict(saved_d)
            src_sig = saved.grouping_signature()
            src_meta = self._plan_layout(saved)
        else:                       # pre-plan checkpoint: stacked layout
            pp = metadata.get("pp", 1)
            v = metadata.get("virtual_stages", 1) if pp > 1 else 1
            src_sig = ("stacked", pp, v)
            src_meta = {"pp": pp, "virtual_stages": v}
        if src_sig == cur_sig:
            return None, None
        if src_sig[0] == "stacked" and cur_sig[0] == "stacked":
            return None, src_sig    # pure [v, pp, n/S] reshape suffices
        dst_meta = self._plan_layout(self.plan)
        # every params-like subtree of (params, opt): the three optimizer
        # moments AND the grad-compress error-feedback buffers (a
        # params-shaped tree when compression is on; the plain None leaf
        # passes through the relayout as static either way)
        prefixes = ("[0]", "[1]['master']", "[1]['m']", "[1]['v']",
                    "[1]['err']")

        def remap(by_key):
            out = {k: v for k, v in by_key.items()
                   if not any(k.startswith(p) for p in prefixes)}
            for p in prefixes:
                sub = {k[len(p):]: v for k, v in by_key.items()
                       if k.startswith(p)}
                if not sub:
                    continue
                for k2, v2 in prm.relayout_flat(self.cfg, sub, src_meta,
                                                dst_meta).items():
                    out[p + k2] = v2
            return out

        return remap, src_sig

    def restore_or_init(self, seed: int = 0):
        last = store.latest_step(self.ckpt_dir)
        params, opt, start = self.init_state(seed)
        if last is None:
            return params, opt, 0
        psh, osh = self._shardings()
        remap, src_sig = self._plan_remap(
            store.read_manifest(self.ckpt_dir, last).get("metadata", {}))
        (params, opt), meta = store.restore(
            self.ckpt_dir, last, (params, opt), shardings=(psh, osh),
            remap=remap)
        src = meta.get("mesh_axes")
        self.log(f"[trainer] restored step {last} "
                 f"(elastic mesh={tuple(self.mesh.shape.values())}"
                 f" pp={self.info.pp}"
                 + (f" <- {src} pp={meta.get('pp', 1)}" if src else "")
                 + (f", plan relayout {src_sig[0]} -> "
                    f"{self.plan.grouping_signature()[0]}"
                    if remap is not None else "")
                 + ")")
        return params, opt, last

    def _heartbeat(self, step: int):
        with open(os.path.join(self.ckpt_dir, "heartbeat.json"), "w") as f:
            json.dump({"step": step, "time": time.time()}, f)

    # ---- main loop ----
    def train(self, total_steps: int, *, ckpt_every: int = 50,
              seed: int = 0) -> Dict:
        os.makedirs(self.ckpt_dir, exist_ok=True)
        params, opt, start = self.restore_or_init(seed)
        # on a pipeline mesh the batch stays flat — the 1F1B schedule slices
        # its own microbatches inside the step (steps.py)
        dcfg = DataConfig(global_batch=self.global_batch,
                          seq_len=self.seq_len,
                          vocab_size=self.cfg.vocab_size,
                          microbatch=(self.hp.microbatch
                                      if self.info.pp == 1 else 0))
        ctx_shape = ((self.global_batch, self.cfg.context_len,
                      self.cfg.context_dim or self.cfg.d_model)
                     if self.cfg.context_len else None)
        data = Prefetcher(dcfg, self.mesh, start_step=start,
                          ctx_shape=ctx_shape)
        losses = []
        try:
            for step, batch in data:
                if step >= total_steps:
                    break
                t0 = time.time()
                self.injector.check(step)
                params, opt, metrics = self.step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if self.straggler.observe(step, dt):
                    self.log(f"[straggler] step {step} took {dt:.2f}s "
                             f"(ewma {self.straggler.mean:.2f}s)")
                losses.append(loss)
                self._heartbeat(step)
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    # plan-aware manifest: the executable ParallelPlan (and
                    # the source mesh/pp) travel with the checkpoint so
                    # elastic restores validate/relayout across plan
                    # changes (PP <-> pure TMP, grouped <-> stacked,
                    # mixed-schedule <-> global-schedule)
                    self.checkpointer.save(
                        step + 1, (params, opt),
                        metadata={"loss": loss,
                                  "mesh_axes": {k: int(v) for k, v in
                                                self.mesh.shape.items()},
                                  "pp": self.info.pp,
                                  "virtual_stages": self.hp.virtual_stages,
                                  "plan": self.plan.to_dict()})
                if step % 10 == 0:
                    self.log(f"[trainer] step {step} loss {loss:.4f} "
                             f"{dt*1e3:.0f} ms")
        finally:
            data.close()
            self.checkpointer.wait()
        return {"losses": losses, "final_step": step + 1,
                "slow_steps": self.straggler.slow_steps}


def run_with_restarts(make_trainer: Callable[[], Trainer], total_steps: int,
                      *, max_restarts: int = 3, ckpt_every: int = 5) -> Dict:
    """Supervisor loop: restart-from-checkpoint on worker failure.  On a real
    cluster this is the job scheduler; here it doubles as the FT test
    harness (tests inject failures and assert loss continuity)."""
    attempts = 0
    while True:
        trainer = make_trainer()
        try:
            return trainer.train(total_steps, ckpt_every=ckpt_every)
        except RuntimeError as e:
            attempts += 1
            trainer.log(f"[supervisor] worker failed ({e}); "
                        f"restart {attempts}/{max_restarts}")
            if attempts > max_restarts:
                raise
