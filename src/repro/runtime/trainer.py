"""Fault-tolerant training driver.

Responsibilities at 1000+-node scale (all exercised by tests on CPU):
* checkpoint/restart — async sharded checkpoints with integrity checksums
  and bounded write retry; resume from the newest INTACT checkpoint on
  (re)start, including after injected failures and corrupted shards;
* straggler detection — per-step wall-time EWMA + z-score; slow steps are
  logged, and pluggable monitors (runtime/elastic.py) can escalate
  persistent stragglers into replanning faults;
* elastic re-mesh — on resume the runner may bring a different mesh (e.g. a
  pod dropped); restore re-shards parameters and the data pipeline seeks to
  the restored step (no replay).  The ElasticSupervisor additionally carries
  live state device-to-device across mid-run plan changes (export_state /
  import_state) so a topology fault doesn't cost a checkpoint round-trip;
* heartbeats — a liveness file an external supervisor can watch, written
  atomically (tmp+rename) so a watcher never reads a half-written JSON.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs
from repro.checkpoint import store
from repro.configs.base import ArchConfig, TrainHParams
from repro.core.axes import mesh_info
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch import steps as steps_mod
from repro.models import params as prm
from repro.optim import adamw
from repro.runtime import elastic as el


@dataclass
class StragglerDetector:
    alpha: float = 0.1
    z_threshold: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    warmup: int = 5                  # steps before the z-test arms
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= self.warmup:
            sd = math.sqrt(self.var) if self.var > 0 else 1e-9
            z = (dt - self.mean) / sd
            slow = z > self.z_threshold
        else:
            slow = False
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.n += 1
        if slow:
            self.slow_steps.append((step, dt))
        return slow


@dataclass
class FailureInjector:
    """Deterministic failure injection for FT/elastic tests and CI chaos.

    Modes (all one-shot: a fired event is consumed so the post-fault
    continuation does not re-trip it when it revisits the step):

    * ``fail_at_steps``       — generic worker failure (RuntimeError), the
                                legacy restart-from-checkpoint path;
    * ``host_loss``           — ``(step, host)`` pairs raising
                                :class:`~repro.runtime.elastic.HostLossError`;
    * ``link_degrade``        — ``(step, bytes_per_s)`` pairs raising
                                :class:`~repro.runtime.elastic.LinkDegradedError`
                                with the measured degraded bandwidth;
    * ``ckpt_fail_saves``     — the first N checkpoint writes raise a
                                transient ``OSError`` (exercises the
                                AsyncCheckpointer retry path);
    * ``corrupt_at_steps``    — checkpoints at these steps are bit-flipped
                                AFTER the atomic commit (exercises the
                                integrity-verify + intact-fallback path).
    """
    fail_at_steps: tuple = ()
    host_loss: tuple = ()            # ((step, host), ...)
    link_degrade: tuple = ()         # ((step, bytes_per_s), ...)
    ckpt_fail_saves: int = 0
    corrupt_at_steps: tuple = ()
    _fired: set = field(default_factory=set)
    _saves_failed: int = 0

    def _once(self, tag) -> bool:
        if tag in self._fired:
            return False
        self._fired.add(tag)
        return True

    def check(self, step: int):
        if step in self.fail_at_steps and self._once(("fail", step)):
            raise RuntimeError(f"injected failure at step {step}")
        for s, host in self.host_loss:
            if step == s and self._once(("host", s)):
                raise el.HostLossError(step, int(host), "injected")
        for s, bw in self.link_degrade:
            if step == s and self._once(("link", s)):
                raise el.LinkDegradedError(step, float(bw), "injected")

    def wrap_save(self, save_fn=store.save):
        """A ``store.save``-compatible callable with this injector's
        checkpoint-write faults applied (wired into AsyncCheckpointer)."""
        if not (self.ckpt_fail_saves or self.corrupt_at_steps):
            return save_fn

        def wrapped(ckpt_dir, step, tree, **kw):
            if self._saves_failed < self.ckpt_fail_saves:
                self._saves_failed += 1
                raise OSError(
                    f"injected transient checkpoint-write error "
                    f"({self._saves_failed}/{self.ckpt_fail_saves})")
            path = save_fn(ckpt_dir, step, tree, **kw)
            if step in self.corrupt_at_steps and self._once(("corrupt",
                                                             step)):
                corrupt_checkpoint(path)
            return path

        return wrapped


def corrupt_checkpoint(path: str):
    """Bit-flip the committed shard of a checkpoint directory — the
    deterministic stand-in for torn writes / bit rot.  The flip lands in
    the member-data region of the npz so ``store.restore`` sees a crc32
    (or zip-CRC) mismatch, not a missing file."""
    shard = os.path.join(path, "shard_0.npz")
    size = os.path.getsize(shard)
    with open(shard, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0xFF]))


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh, hp: TrainHParams, *,
                 global_batch: int, seq_len: int, ckpt_dir: str,
                 injector: Optional[FailureInjector] = None,
                 monitors: Sequence[el.FaultMonitor] = (),
                 log_fn: Callable[[str], None] = print,
                 degrees=None, plan=None,
                 telemetry=None, host_id: int = 0):
        from repro.core.plan import ParallelPlan
        from repro.launch.mesh import mesh_signature
        self.cfg = cfg
        self.mesh = mesh
        info = mesh_info(mesh)
        schedules = None
        if plan is not None:
            hp, degrees, schedules = steps_mod.unpack_plan(cfg, hp, plan,
                                                           degrees)
        else:
            # legacy callers: desugar the loose (hp, degrees) threading so
            # the checkpoint manifest ALWAYS records an executable plan
            mshape, maxes = mesh_signature(mesh)
            plan = ParallelPlan.from_hparams(
                hp, cfg.num_layers, degrees=degrees, mesh_shape=mshape,
                mesh_axes=maxes, pp=info.pp)
        self.plan = plan
        # one shared resolution with build_train_step: planner mode sees the
        # extra-dp-adjusted microbatcher; a pipeline mesh folds gradient
        # accumulation into the 1F1B schedule (hp.microbatch = n_micro)
        self.hp = steps_mod.resolve_for_mesh(cfg, info, hp, global_batch,
                                             seq_len, degrees)
        self.degrees = degrees
        self.schedules = schedules
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ckpt_dir = ckpt_dir
        self.injector = injector or FailureInjector()
        self.monitors = tuple(monitors)
        self.log = log_fn
        # structured telemetry (repro.obs).  Default: an in-memory recorder
        # whose console sink is log_fn, so the familiar "[trainer] ..."
        # lines keep printing while structured payloads ride along; pass
        # obs.NULL to disable entirely, or a JSONL-sinking Recorder
        # (launch/train.py --telemetry <dir>) to persist the run.
        self.rec = (telemetry if telemetry is not None
                    else obs.Recorder(console=log_fn))
        self.host_id = host_id
        self.straggler = StragglerDetector()
        base_save = self.injector.wrap_save()

        def _timed_save(ckpt_dir, step, tree, **kw):
            # runs on the AsyncCheckpointer worker thread — Recorder's file
            # buffer is lock-protected for exactly this caller
            t0 = time.perf_counter()
            path = base_save(ckpt_dir, step, tree, **kw)
            self.rec.observe("trainer.ckpt_write_s",
                             time.perf_counter() - t0, step=step)
            return path

        self.checkpointer = store.AsyncCheckpointer(
            ckpt_dir, save_fn=_timed_save)
        self.run_losses: list = []       # losses of the current train() call
        self._live_state = None          # (params, opt, next_step) on device

        self.step_fn, self.specs = steps_mod.build_train_step(
            cfg, mesh, self.hp, global_batch=global_batch, seq_len=seq_len,
            degrees=degrees, schedules=schedules, plan=self.plan)
        # buffer donation deadlocks XLA:CPU's intra-process collective
        # rendezvous (execution only — the dry-run donates at compile time);
        # enable it on real accelerators.
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        self.step_fn = jax.jit(self.step_fn, donate_argnums=donate)
        self.info = info

    # ---- state ----
    def _shardings(self):
        psh = prm.shardings_tree(self.specs, self.mesh)
        osp = adamw.opt_state_specs(self.specs, self.info,
                                    zero1=self.hp.zero1)
        osh = {
            "master": prm.shardings_tree(osp["master"], self.mesh),
            "m": prm.shardings_tree(osp["m"], self.mesh),
            "v": prm.shardings_tree(osp["v"], self.mesh),
            "step": jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()),
            "err": None,
        }
        return psh, osh

    def init_state(self, seed: int = 0):
        params = prm.init_params(self.specs, jax.random.PRNGKey(seed))
        opt = adamw.init_opt_state(params, self.specs, self.info,
                                   zero1=self.hp.zero1)
        psh, osh = self._shardings()
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt = jax.tree_util.tree_map(
            lambda v, s: v if v is None or s is None else jax.device_put(v, s),
            opt, osh, is_leaf=lambda x: x is None)
        return params, opt, 0

    def _plan_remap(self, metadata: Dict):
        """Cross-plan elastic resume: when the checkpoint's recorded plan
        trains under a different parameter-tree grouping than the current
        one (grouped planner layouts vs the stacked layout, including
        mixed-schedule -> global-schedule transitions), return a
        flat-leaf remap that restacks the canonical layer order into the
        current layout.  Stacked -> stacked pp changes keep the existing
        pure-reshape path (store.restore)."""
        from repro.core.plan import ParallelPlan
        cur_sig = self.plan.grouping_signature()
        saved_d = metadata.get("plan")
        if saved_d is not None:
            saved = ParallelPlan.from_dict(saved_d)
            src_sig = saved.grouping_signature()
            src_meta = el.plan_layout(saved)
        else:                       # pre-plan checkpoint: stacked layout
            pp = metadata.get("pp", 1)
            v = metadata.get("virtual_stages", 1) if pp > 1 else 1
            src_sig = ("stacked", pp, v)
            src_meta = {"pp": pp, "virtual_stages": v}
        if src_sig == cur_sig:
            return None, None
        if src_sig[0] == "stacked" and cur_sig[0] == "stacked":
            return None, src_sig    # pure [v, pp, n/S] reshape suffices
        remap = el.state_remap(self.cfg, src_meta,
                               el.plan_layout(self.plan))
        return remap, src_sig

    def restore_or_init(self, seed: int = 0):
        """Resume from the newest INTACT checkpoint: a corrupted or torn
        write (store.CorruptCheckpointError) falls back to the previous
        step instead of crashing — or silently loading garbage."""
        params, opt, start = self.init_state(seed)
        psh, osh = self._shardings()
        for last in reversed(store.all_steps(self.ckpt_dir)):
            try:
                remap, src_sig = self._plan_remap(
                    store.read_manifest(self.ckpt_dir, last)
                    .get("metadata", {}))
                (params, opt), meta = store.restore(
                    self.ckpt_dir, last, (params, opt),
                    shardings=(psh, osh), remap=remap)
            except store.CorruptCheckpointError as e:
                self.rec.event(
                    "trainer.ckpt_corrupt", step=last,
                    msg=f"[trainer] checkpoint step {last} corrupt "
                        f"({e}); falling back to previous intact "
                        f"checkpoint")
                continue
            src = meta.get("mesh_axes")
            self.rec.event(
                "trainer.restore", step=last,
                relayout=remap is not None,
                msg=f"[trainer] restored step {last} "
                    f"(elastic mesh={tuple(self.mesh.shape.values())}"
                    f" pp={self.info.pp}"
                    + (f" <- {src} pp={meta.get('pp', 1)}" if src else "")
                    + (f", plan relayout {src_sig[0]} -> "
                       f"{self.plan.grouping_signature()[0]}"
                       if remap is not None else "")
                    + ")")
            return params, opt, last
        return params, opt, 0

    # ---- live-state carry (ElasticSupervisor) ----
    def export_state(self) -> Optional[Dict]:
        """Flat host snapshot of the live (params, opt) state for an
        in-memory carry across a topology change: ``{"flat": {keystr:
        np.ndarray | None}, "step": next_step, "sig"/"layout": the source
        plan's grouping}``.  None when no step has completed yet (the
        supervisor then restores from checkpoint)."""
        if self._live_state is None:
            return None
        params, opt, next_step = self._live_state
        leaves, _ = jax.tree_util.tree_flatten_with_path(
            (params, opt), is_leaf=lambda x: x is None)
        flat = {jax.tree_util.keystr(kp):
                (None if v is None else np.asarray(jax.device_get(v)))
                for kp, v in leaves}
        return {"flat": flat, "step": next_step,
                "sig": self.plan.grouping_signature(),
                "layout": el.plan_layout(self.plan)}

    def import_state(self, exported: Dict):
        """Land an exported live state on THIS trainer's mesh/plan:
        relayout the flat leaves across the plan-layout change (grouped
        <-> stacked <-> pipeline stacks), then device_put against this
        trainer's shardings.  Returns the ``state=`` tuple for
        :meth:`train`."""
        flat = exported["flat"]
        if exported["sig"] != self.plan.grouping_signature():
            remap = el.state_remap(self.cfg, exported["layout"],
                                   el.plan_layout(self.plan))
            flat = remap(flat)
        params, opt, _ = self.init_state()
        psh, osh = self._shardings()
        leaves, treedef = jax.tree_util.tree_flatten_with_path(
            (params, opt), is_leaf=lambda x: x is None)
        shard_leaves = jax.tree_util.tree_leaves(
            (psh, osh), is_leaf=lambda x: x is None)
        out = []
        for (kp, like), sh in zip(leaves, shard_leaves):
            key = jax.tree_util.keystr(kp)
            arr = flat.get(key)
            if arr is None:
                out.append(None)
                continue
            like_shape = tuple(getattr(like, "shape", arr.shape))
            if tuple(arr.shape) != like_shape:
                arr = arr.reshape(like_shape)   # [v,pp,n/S] <-> [n] stacks
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        params, opt = jax.tree_util.tree_unflatten(treedef, out)
        return params, opt, exported["step"]

    def _heartbeat(self, step: int, dt: Optional[float] = None,
                   loss: Optional[float] = None):
        """Atomic liveness write: tmp + rename, so a watching supervisor
        (HeartbeatMonitor) never reads a half-written JSON.

        Beyond liveness the file now carries per-host step metrics
        (step_time_s / step_time_ewma_s / loss) so a cross-host watcher
        (elastic.StragglerEscalation with peer heartbeats) can localize
        WHICH host is slow, not just that somebody is."""
        hb: Dict = {"step": step, "time": time.time(), "host": self.host_id}
        if dt is not None:
            hb["step_time_s"] = dt
            hb["step_time_ewma_s"] = self.straggler.mean
        if loss is not None:
            hb["loss"] = loss
        path = el.heartbeat_path(self.ckpt_dir)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(hb, f)
        os.replace(tmp, path)

    def _overlap_report(self, step: int):
        """End-of-run overlap-efficiency probe (repro.obs.probe): decompose
        the median measured step time against the calibrated cost model's
        per-layer-group prediction and emit overlap.group / residual /
        calibration_stale telemetry.  Only runs when the recorder has a
        JSONL sink (--telemetry) — the probe calls calibrated_hw, which
        micro-benches this host on a cache miss, a cost the default
        in-memory recorder must never pay."""
        if getattr(self.rec, "out_dir", None) is None:
            return
        h = getattr(self.rec, "hists", {}).get("trainer.step_time_s")
        if not h or len(h) < 2:
            return
        xs = sorted(list(h)[1:])        # drop the compile step
        med = xs[len(xs) // 2]
        try:
            from repro.core.planner.calibrate import calibrated_hw, describe
            from repro.core.planner.costmodel import ShapeConfig
            hw = calibrated_hw(n_chips=max(int(self.mesh.devices.size), 1))
            degrees = [self.info.tp if d is None else d
                       for d in self.plan.degrees]
            probe = obs.OverlapProbe.for_run(
                self.cfg, ShapeConfig("probe", self.seq_len,
                                      self.global_batch, "train"),
                self.hp, hw, degrees, list(self.plan.schedules),
                hw_note=describe(hw))
            probe.report(med, self.rec, step=step)
        except Exception as e:   # the probe must never kill a finished run
            self.rec.event("overlap.error",
                           msg=f"[overlap] probe failed: {e!r}")

    # ---- main loop ----
    def train(self, total_steps: int, *, ckpt_every: int = 50,
              seed: int = 0, state: Optional[Tuple] = None) -> Dict:
        """Run to ``total_steps``.  ``state=(params, opt, start)`` skips
        the checkpoint restore — the ElasticSupervisor's in-memory
        continuation path (import_state's return value)."""
        os.makedirs(self.ckpt_dir, exist_ok=True)
        if state is not None:
            params, opt, start = state
        else:
            params, opt, start = self.restore_or_init(seed)
        # on a pipeline mesh the batch stays flat — the 1F1B schedule slices
        # its own microbatches inside the step (steps.py)
        dcfg = DataConfig(global_batch=self.global_batch,
                          seq_len=self.seq_len,
                          vocab_size=self.cfg.vocab_size,
                          microbatch=(self.hp.microbatch
                                      if self.info.pp == 1 else 0))
        ctx_shape = ((self.global_batch, self.cfg.context_len,
                      self.cfg.context_dim or self.cfg.d_model)
                     if self.cfg.context_len else None)
        data = Prefetcher(dcfg, self.mesh, start_step=start,
                          ctx_shape=ctx_shape)
        self.run_losses = []
        losses = self.run_losses
        step = start
        try:
            for step, batch in data:
                if step >= total_steps:
                    break
                # perf_counter: the step timer feeds the straggler
                # detector — time.time() is non-monotonic and an NTP slew
                # mid-step reads as a phantom straggler
                t0 = time.perf_counter()
                self.injector.check(step)
                with obs.trace_annotation("train_step"):
                    params, opt, metrics = self.step_fn(params, opt, batch)
                    loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.rec.observe("trainer.step_time_s", dt, step=step)
                self.rec.gauge("trainer.tokens_per_s",
                               self.global_batch * self.seq_len / dt,
                               step=step)
                self.rec.gauge("trainer.loss", loss, step=step)
                if self.straggler.observe(step, dt):
                    self.rec.event(
                        "trainer.straggler", step=step,
                        dt_s=round(dt, 4),
                        ewma_s=round(self.straggler.mean, 4),
                        msg=f"[straggler] step {step} took {dt:.2f}s "
                            f"(ewma {self.straggler.mean:.2f}s)")
                losses.append(loss)
                self._live_state = (params, opt, step + 1)
                self._heartbeat(step, dt, loss)
                for mon in self.monitors:
                    ev = mon.observe_step(step, dt) or mon.poll(step)
                    if ev is not None:
                        raise el.fault_from_event(ev)
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    # plan-aware manifest: the executable ParallelPlan (and
                    # the source mesh/pp) travel with the checkpoint so
                    # elastic restores validate/relayout across plan
                    # changes (PP <-> pure TMP, grouped <-> stacked,
                    # mixed-schedule <-> global-schedule)
                    self.checkpointer.save(
                        step + 1, (params, opt),
                        metadata={"loss": loss,
                                  "mesh_axes": {k: int(v) for k, v in
                                                self.mesh.shape.items()},
                                  "pp": self.info.pp,
                                  "virtual_stages": self.hp.virtual_stages,
                                  "plan": self.plan.to_dict()})
                if step % 10 == 0:
                    self.rec.event(
                        "trainer.step", step=step,
                        msg=f"[trainer] step {step} loss {loss:.4f} "
                            f"{dt*1e3:.0f} ms")
            self._overlap_report(step)
        finally:
            data.close()
            try:
                self.checkpointer.wait()
            except OSError as e:
                # an exhausted-retry async write must not mask the loop's
                # own (more informative) fault — surface it as a log +
                # counter the supervisor inspects
                self.rec.counter("trainer.ckpt_write_failed")
                self.rec.event(
                    "trainer.ckpt_write_failed",
                    msg=f"[trainer] checkpoint write failed after "
                        f"retries: {e}")
            self.rec.flush()
        return {"losses": losses, "final_step": step + 1,
                "slow_steps": self.straggler.slow_steps}


def run_with_restarts(make_trainer: Callable[[], Trainer], total_steps: int,
                      *, max_restarts: int = 3, ckpt_every: int = 5,
                      restartable: Tuple = (RuntimeError,),
                      backoff_s: float = 0.0,
                      backoff_factor: float = 2.0) -> Dict:
    """Supervisor loop: restart-from-checkpoint on worker failure.  On a real
    cluster this is the job scheduler; here it doubles as the FT test
    harness (tests inject failures and assert loss continuity).

    ``restartable`` is the exception tuple worth a same-mesh restart
    (default: RuntimeError only — an AssertionError or a shape bug is a
    code defect, not a fault).  ``KeyboardInterrupt``/``SystemExit`` are
    never restartable, and neither is a topology fault
    (:class:`~repro.runtime.elastic.FaultError`): a mesh that lost a host
    cannot be restarted into existence — that is the ElasticSupervisor's
    job.  Restarts back off exponentially (``backoff_s *
    backoff_factor**attempt``) so a crash-looping worker doesn't hammer
    shared checkpoint storage."""
    attempts = 0
    while True:
        trainer = make_trainer()
        # duck-typed: FT tests drive this loop with fake trainers that
        # only expose .train/.log
        rec = getattr(trainer, "rec", None) \
            or obs.Recorder(console=trainer.log)
        try:
            return trainer.train(total_steps, ckpt_every=ckpt_every)
        except (KeyboardInterrupt, SystemExit):
            raise
        except el.FaultError as e:
            rec.event(
                "supervisor.fault", kind=e.event.kind,
                msg=f"[supervisor] topology fault ({e}) is not "
                    f"restartable on the same mesh — use "
                    f"runtime.elastic.ElasticSupervisor")
            raise
        except restartable as e:
            attempts += 1
            rec.counter("supervisor.restarts")
            rec.event(
                "supervisor.restart", attempt=attempts,
                msg=f"[supervisor] worker failed ({e}); "
                    f"restart {attempts}/{max_restarts}")
            if attempts > max_restarts:
                raise
            if backoff_s > 0:
                time.sleep(backoff_s * backoff_factor ** (attempts - 1))
