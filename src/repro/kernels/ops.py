"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests; real-TPU deployments hit the compiled kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru as _rglru
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssd as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rms.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "block_w",
                                             "interpret"))
def rglru(x, params, *, block_t: int = 64, block_w: int = 512,
          interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rglru.rglru(x, params, block_t=block_t, block_w=block_w,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A_log, B, C, D, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd(x, dt, A_log, B, C, D, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_k",
                                             "interpret"))
def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 128,
            block_k: int = 512, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gmm.moe_gmm(x, w, block_c=block_c, block_f=block_f,
                        block_k=block_k, interpret=interpret)
