"""Block-size autotuning for the fused collective-matmul kernel family.

The tile matmul at the heart of every ring kernel
(:func:`repro.kernels.collective_matmul.pallas_tile_matmul`) takes
``block_m/n/k`` — MXU utilisation and VMEM pressure both hinge on them,
and the best choice is shape- and platform-dependent.  This module owns
that choice:

* :func:`tuned_blocks` returns the ``(bm, bn, bk)`` to use for an
  ``[m, k] @ [k, n]`` matmul, cached per ``(shape, platform)`` in memory
  and on disk (``REPRO_TUNE_CACHE`` env var, default
  ``~/.cache/repro-oases/pallas_tiles.json``) so the search runs once per
  host, not once per process.
* On TPU the candidates are timed for real: each ``pallas_call`` variant
  runs a BLOCKED warm-up (compile + first dispatch synced — an un-synced
  warm-up queues ahead of the first timed repeat under async dispatch and
  corrupts the measurement) and then a min-of-repeats
  ``time.perf_counter()`` loop.
* Off TPU the kernels run in interpret mode, where wall clock measures
  the emulator rather than the tiling — candidates are NOT timed; the
  clipped heuristic default is returned (and cached, so tests can assert
  the cache path without platform-dependent timing).

Explicit ``block_*`` arguments to the kernels always bypass the tuner.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax

Blocks = Tuple[int, int, int]

# heuristic fallback (also the non-TPU answer): MXU-aligned tiles small
# enough that x/w/acc fit VMEM at every candidate shape
DEFAULT_BLOCKS: Blocks = (128, 128, 512)

# candidate grid, clipped to the problem dims; kept deliberately small —
# the cache makes the search once-per-host, but a cold host still pays it
CAND_M = (128, 256, 512)
CAND_N = (128, 256, 512)
CAND_K = (256, 512, 1024)

# per-core VMEM is ~16 MB; leave headroom for double-buffered input
# tiles (the pipeline keeps 2 of each in flight) and the fp32 accumulator
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_MEM_CACHE: Dict[str, Blocks] = {}


def cache_path() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-oases",
                        "pallas_tiles.json")


def _cache_key(m: int, k: int, n: int, dtype, platform: str) -> str:
    return f"{platform}|m{m}k{k}n{n}|{jax.numpy.dtype(dtype).name}"


def _load_disk() -> Dict[str, List[int]]:
    try:
        with open(cache_path()) as f:
            d = json.load(f)
        return d if isinstance(d, dict) else {}
    except (OSError, ValueError):
        return {}


def _store_disk(entries: Dict[str, List[int]]) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(entries, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                      # cache is an optimisation, never fatal


def _clip(blocks: Blocks, m: int, k: int, n: int) -> Blocks:
    bm, bn, bk = blocks
    return (min(bm, m), min(bn, n), min(bk, k))


def _vmem_bytes(bm: int, bn: int, bk: int, itemsize: int) -> int:
    # double-buffered fp32 input tiles + fp32 accumulator + output tile
    return 2 * (bm * bk + bk * bn) * 4 + bm * bn * 4 + bm * bn * itemsize


def candidates(m: int, k: int, n: int, itemsize: int = 4) -> List[Blocks]:
    """The clipped, VMEM-feasible, deduplicated candidate tile sets."""
    seen, out = set(), []
    for bm in CAND_M:
        for bn in CAND_N:
            for bk in CAND_K:
                c = _clip((bm, bn, bk), m, k, n)
                if c in seen:
                    continue
                seen.add(c)
                if _vmem_bytes(*c, itemsize=itemsize) <= VMEM_BUDGET_BYTES:
                    out.append(c)
    return out or [_clip(DEFAULT_BLOCKS, m, k, n)]


def _time_candidate(m: int, k: int, n: int, dtype, blocks: Blocks,
                    repeats: int) -> float:
    from repro.kernels.collective_matmul import pallas_tile_matmul
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(key, (k, n), jnp.float32).astype(dtype)
    bm, bn, bk = blocks

    def run():
        return pallas_tile_matmul(x, w, block_m=bm, block_n=bn,
                                  block_k=bk)

    # block the warm-up: compile + first dispatch must finish before the
    # timed loop (async dispatch would otherwise queue it ahead of the
    # first repeat)
    jax.block_until_ready(run())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def tuned_blocks(m: int, k: int, n: int, dtype="float32",
                 platform: Optional[str] = None,
                 repeats: int = 3) -> Blocks:
    """The ``(block_m, block_n, block_k)`` to use for ``[m,k] @ [k,n]``.

    Cached per ``(shape, dtype, platform)``; TPU answers are measured,
    non-TPU answers are the clipped heuristic (interpret-mode timing
    would measure the emulator, not the tiling).
    """
    platform = platform or jax.default_backend()
    key = _cache_key(m, k, n, dtype, platform)
    hit = _MEM_CACHE.get(key)
    if hit is not None:
        return hit
    disk = _load_disk()
    raw = disk.get(key)
    if isinstance(raw, list) and len(raw) == 3:
        blocks = _clip(tuple(int(v) for v in raw), m, k, n)
        _MEM_CACHE[key] = blocks
        return blocks
    if platform != "tpu":
        blocks = _clip(DEFAULT_BLOCKS, m, k, n)
    else:
        itemsize = jax.numpy.dtype(dtype).itemsize
        timed = []
        for c in candidates(m, k, n, itemsize=itemsize):
            try:
                timed.append((_time_candidate(m, k, n, dtype, c, repeats),
                              c))
            except Exception:     # a candidate the compiler rejects
                continue
        blocks = (min(timed)[1] if timed
                  else _clip(DEFAULT_BLOCKS, m, k, n))
    _MEM_CACHE[key] = blocks
    disk[key] = list(blocks)
    _store_disk(disk)
    return blocks
