"""Ring attention: sequence-parallel flash attention whose KV blocks
circulate the TMP ring (ROADMAP item 2; Liu et al.'s blockwise ring
transformers meeting the fused-collective machinery of
:mod:`repro.kernels.collective_matmul`).

Q stays sequence-local; (K, V) rotate around the ring one neighbour hop
per step, and each step folds the arriving KV block into an online-softmax
carry (exactly :func:`repro.models.attention.chunked_attention`'s update).
The per-step transfer depends only on the *previous* step's block, so the
KV hop overlaps the current block's QK^T/PV compute the same way
``collective_matmul`` overlaps matmul tiles.

Three execution backends, selected by :func:`backend`:

* ``ref``    — ``lax.all_gather`` the KV shards, then ``chunked_attention``:
  the numerics oracle and the fallback for multi-axis (factored-mesh)
  groups or a degenerate ring.
* ``ring``   — ``lax.ppermute`` rotation + per-block online softmax: runs
  on every platform (what the 8-virtual-device CI tier validates).
* ``pallas`` — a single TPU kernel per device with the KV hop as a
  double-buffered in-kernel ``make_async_remote_copy`` (same semaphore
  protocol as ``_rs_ring_kernel``); forward only — the backward runs the
  ppermute ring.

Causal masking across shards: absolute positions ride the ring next to the
KV block, and each step's update is wrapped in a ``lax.cond`` on a
block-level visibility test (min KV position vs max Q position, and the
sliding-window analogue), so a shard skips the QK^T/PV FLOPs of remote
blocks that are entirely in its future — preserving the ~2x causal FLOP
saving at ring granularity.  The mask itself is still applied elementwise
inside the update, so the skip is a pure FLOP optimization.

Gradients: a custom VJP runs a *second* ring.  dQ accumulates locally
(Q never moves); (dK, dV) buffers travel WITH the rotating KV shard and
arrive back at their home device after the n-th hop — n ppermutes
backward, mirroring the n-1 forward.  Cotangents follow the
partial-cotangent convention of :mod:`repro.core.tmp` (per-shard dK/dV;
the shard_map boundary psums replicated-parameter grads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params
from repro.core.tmp import Axes, axes_index, axes_size
from repro.kernels.collective_matmul import _ring_perm
from repro.models.attention import NEG_INF, chunked_attention


# --------------------------------------------------------------------------
# backend selection
# --------------------------------------------------------------------------
def backend(axes: Axes, *, use_pallas: bool = False) -> str:
    """Pick the execution backend for a sequence-sharded attention call.

    The ring needs a single mesh axis (``lax.ppermute``); factored-mesh
    multi-axis groups and degenerate rings fall back to the gather
    reference, which is always correct.
    """
    if len(axes) != 1:
        return "ref"
    if axes_size(axes) <= 1:
        return "ref"
    if use_pallas and jax.default_backend() == "tpu":
        return "pallas"
    return "ring"


# --------------------------------------------------------------------------
# shared block math (mirrors chunked_attention's scan step)
# --------------------------------------------------------------------------
def _valid_mask(qp, pb, causal: bool, window: Optional[int]):
    """qp [b, sq], pb [b, ck] absolute positions (-1 = padding) ->
    [b, 1, 1, sq, ck] bool."""
    pbb = pb[:, None, None, None, :]
    qpb = qp[:, None, None, :, None]
    valid = pbb >= 0
    if causal:
        valid &= pbb <= qpb
    if window is not None:
        valid &= pbb > qpb - window
    return valid


def _step_needed(qp, pb, causal: bool, window: Optional[int]):
    """Scalar block-visibility test: False iff NO (q, kv) pair in this
    ring step can attend — the ``lax.cond`` skip that keeps the causal
    FLOP saving.  Conservative (range-based), so it may admit a block the
    elementwise mask then zeroes; never the reverse."""
    big = jnp.int32(1 << 30)
    pb_min = jnp.min(jnp.where(pb >= 0, pb, big))
    needed = jnp.any(pb >= 0)
    if causal:
        needed = jnp.logical_and(needed, pb_min <= jnp.max(qp))
    if window is not None:
        needed = jnp.logical_and(needed, jnp.max(pb) > jnp.min(qp) - window)
    return needed


def _block_update(qs, kb, vb, qp, pb, acc, m, l, *, causal, window, softcap):
    """One online-softmax block: qs [b,sq,kvh,g,hd] f32 pre-scaled;
    kb/vb [b,ck,kvh,hd]; carry acc [b,kvh,g,sq,hd], m/l [b,kvh,g,sq]."""
    s = jnp.einsum("bqkgh,bckh->bkgqc", qs, kb.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(_valid_mask(qp, pb, causal, window), s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqc,bckh->bkgqh", p, vb.astype(jnp.float32))
    return acc * corr[..., None] + pv, m_new, l_new


def _finalize(acc, m, l, b, sq, h, hd, dtype):
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sq, h, hd).astype(dtype), m + jnp.log(l_safe)


# --------------------------------------------------------------------------
# ppermute ring (every platform; the CI-validated path)
# --------------------------------------------------------------------------
def _ring_forward(q, k, v, qp, kvp, axes, causal, window, softcap, scale):
    """-> (out [b,sq,h,hd], lse [b,kvh,g,sq] f32)."""
    axis, n = axes[0], axes_size(axes)
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qs = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, hd)
    acc = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    m = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, g, sq), jnp.float32)
    perm = _ring_perm(n, reverse=True)
    cur = (k, v, kvp)           # step s holds shard (idx + s) % n
    for s in range(n):
        # start the hop BEFORE the block compute: the transfer depends only
        # on the previous step, so it completes under this block's FLOPs
        nxt = (tuple(lax.ppermute(t, axis, perm) for t in cur)
               if s < n - 1 else None)
        kb, vb, pb = cur
        acc, m, l = lax.cond(
            _step_needed(qp, pb, causal, window),
            lambda ops, kb=kb, vb=vb, pb=pb: _block_update(
                qs, kb, vb, qp, pb, *ops,
                causal=causal, window=window, softcap=softcap),
            lambda ops: ops,
            (acc, m, l))
        cur = nxt
    return _finalize(acc, m, l, b, sq, h, hd, q.dtype)


def _ring_backward(res, do, axes, causal, window, softcap, scale):
    """The reverse ring: dQ local, (dK, dV) travel with the KV shard and
    are home after n hops."""
    q, k, v, qp, kvp, out, lse = res
    axis, n = axes[0], axes_size(axes)
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qs = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, hd)
    dof = do.astype(jnp.float32).reshape(b, sq, kvh, g, hd)
    outf = out.astype(jnp.float32).reshape(b, sq, kvh, g, hd)
    # D_i = sum_j P_ij dP_ij = <do_i, o_i> — global, yet locally computable
    delta = jnp.einsum("bqkgh,bqkgh->bkgq", dof, outf)
    dq = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    perm = _ring_perm(n, reverse=True)
    cur = (k, v, kvp,
           jnp.zeros(k.shape, jnp.float32), jnp.zeros(v.shape, jnp.float32))
    for s in range(n):
        kb, vb, pb, dkb, dvb = cur

        def blk(ops, kb=kb, vb=vb, pb=pb):
            dq_c, dk_c, dv_c = ops
            kf = kb.astype(jnp.float32)
            z = jnp.einsum("bqkgh,bckh->bkgqc", qs, kf)
            if softcap:
                zc = softcap * jnp.tanh(z / softcap)
                damp = 1.0 - jnp.square(zc / softcap)
            else:
                zc = z
            valid = _valid_mask(qp, pb, causal, window)
            p = jnp.where(valid, jnp.exp(zc - lse[..., None]), 0.0)
            dp = jnp.einsum("bqkgh,bckh->bkgqc", dof,
                            vb.astype(jnp.float32))
            ds = p * (dp - delta[..., None])
            if softcap:
                ds = ds * damp
            dq_blk = jnp.einsum("bkgqc,bckh->bqkgh", ds, kf) * scale
            dk_blk = jnp.einsum("bkgqc,bqkgh->bckh", ds, qs)  # qs has scale
            dv_blk = jnp.einsum("bkgqc,bqkgh->bckh", p, dof)
            return dq_c + dq_blk, dk_c + dk_blk, dv_c + dv_blk

        dq, dkb, dvb = lax.cond(
            _step_needed(qp, pb, causal, window), blk, lambda ops: ops,
            (dq, dkb, dvb))
        if s < n - 1:
            cur = tuple(lax.ppermute(t, axis, perm)
                        for t in (kb, vb, pb, dkb, dvb))
        else:
            # n-th hop carries only the finished (dK, dV) home
            dkb, dvb = (lax.ppermute(t, axis, perm) for t in (dkb, dvb))
    return (dq.reshape(b, sq, h, hd).astype(q.dtype),
            dkb.astype(k.dtype), dvb.astype(v.dtype))


# --------------------------------------------------------------------------
# Pallas TPU forward: in-kernel RDMA double-buffering
# --------------------------------------------------------------------------
def _ring_attn_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                      kbuf, vbuf, acc, m_scr, l_scr,
                      ksend, krecv, vsend, vrecv, ack_sem, *,
                      n_dev: int, axis_name: str, causal: bool,
                      window: Optional[int], softcap: float):
    """grid = (n_dev,) sequential: step s folds KV shard (i+s) mod n into
    the online-softmax carry and STARTS the hop of the current buffer to
    the LEFT neighbour without waiting — the RDMA completes under step
    s+1's QK^T/PV.  Same 2-slot protocol as ``_rs_ring_kernel``: the
    payload passes through ``kbuf/vbuf[slot = s % 2]``, the receiver acks
    consumption to its RIGHT (the sender) before the sender reuses the
    landing slot, and every semaphore is zero at kernel exit
    (sends s∈[0,n-2]; acks emitted and consumed s∈[1,n-2]).

    Assumes contiguous sequence sharding (positions derived from the ring
    index); the ppermute path handles arbitrary positions.
    """
    s = pl.program_id(0)
    slot, prev = s % 2, (s - 1) % 2
    my_id = jax.lax.axis_index(axis_name)
    left = (my_id - 1) % n_dev
    right = (my_id + 1) % n_dev
    b, sk, kvh, hd = k_ref.shape
    sq = q_ref.shape[3]

    @pl.when(s == 0)
    def _start():
        # neighbours must have entered the kernel before any RDMA lands
        bsem = pltpu.get_barrier_semaphore()
        for nb in (left, right):
            pltpu.semaphore_signal(bsem, inc=1, device_id=nb,
                                   device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(bsem, 2)
        kbuf[0] = k_ref[...]
        vbuf[0] = v_ref[...]
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    @pl.when(s > 0)
    def _landed():
        pltpu.semaphore_wait(krecv[slot], 1)    # this step's KV arrived
        pltpu.semaphore_wait(vrecv[slot], 1)
        pltpu.semaphore_wait(ksend[prev], 1)    # drain our s-1 sends
        pltpu.semaphore_wait(vsend[prev], 1)

        @pl.when(s <= n_dev - 2)
        def _ack():
            # kbuf/vbuf[prev] free: the right neighbour's step-s send
            # targets exactly that slot on us
            pltpu.semaphore_signal(ack_sem[prev], inc=1, device_id=right,
                                   device_id_type=pltpu.DeviceIdType.LOGICAL)

    @pl.when(s < n_dev - 1)
    def _hop():
        @pl.when(s >= 1)
        def _flow_control():
            # left must have consumed (and drained) the slot we target
            pltpu.semaphore_wait(ack_sem[(s + 1) % 2], 1)

        for buf, ssem, rsem in ((kbuf, ksend, krecv), (vbuf, vsend, vrecv)):
            pltpu.make_async_remote_copy(
                src_ref=buf.at[slot],
                dst_ref=buf.at[(s + 1) % 2],
                send_sem=ssem.at[slot],
                recv_sem=rsem.at[(s + 1) % 2],
                device_id=(left,),
                device_id_type=pltpu.DeviceIdType.LOGICAL,
            ).start()           # NO wait: overlaps this block's compute

    src = (my_id + s) % n_dev   # which KV shard sits in kbuf[slot]
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, src * sk <= my_id * sq + sq - 1)
    if window is not None:
        run = jnp.logical_and(run, src * sk + sk - 1 > my_id * sq - window)

    @pl.when(run)
    def _compute():
        kf = kbuf[slot].astype(jnp.float32)
        vf = vbuf[slot].astype(jnp.float32)
        sc = jnp.einsum("bkgqh,bckh->bkgqc", q_ref[...], kf)
        if softcap:
            sc = softcap * jnp.tanh(sc / softcap)
        qpos = my_id * sq + lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        kpos = src * sk + lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        valid = jnp.ones((sq, sk), jnp.bool_)
        if causal:
            valid = jnp.logical_and(valid, kpos <= qpos)
        if window is not None:
            valid = jnp.logical_and(valid, kpos > qpos - window)
        sc = jnp.where(valid[None, None, None], sc, NEG_INF)
        m_old = m_scr[...]
        m_new = jnp.maximum(m_old, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc[...] = (acc[...] * corr[..., None]
                    + jnp.einsum("bkgqc,bckh->bkgqh", p, vf))
        m_scr[...] = m_new

    @pl.when(s == n_dev - 1)
    def _finish():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = acc[...] / l_safe[..., None]
        lse_ref[...] = m_scr[...] + jnp.log(l_safe)


def pallas_ring_forward(q, k, v, axes: Axes, *, causal=True, window=None,
                        softcap=0.0, scale=None):
    """TPU forward of the KV ring; -> (out [b,sq,h,hd], lse [b,kvh,g,sq])."""
    axis, n = axes[0], axes_size(axes)
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    qs = (q.astype(jnp.float32) * scale).reshape(
        b, sq, kvh, g, hd).transpose(0, 2, 3, 1, 4)       # [b,kvh,g,sq,hd]
    out, lse = pl.pallas_call(
        functools.partial(_ring_attn_kernel, n_dev=n, axis_name=axis,
                          causal=causal, window=window, softcap=softcap),
        grid=(n,),
        in_specs=[
            pl.BlockSpec(qs.shape, lambda s: (0, 0, 0, 0, 0)),
            pl.BlockSpec(k.shape, lambda s: (0, 0, 0, 0)),
            pl.BlockSpec(v.shape, lambda s: (0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec(qs.shape, lambda s: (0, 0, 0, 0, 0)),
            pl.BlockSpec(qs.shape[:4], lambda s: (0, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(qs.shape, jnp.float32),
            jax.ShapeDtypeStruct(qs.shape[:4], jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2,) + k.shape, k.dtype),       # KV ring double-buf
            pltpu.VMEM((2,) + v.shape, v.dtype),
            pltpu.VMEM(qs.shape, jnp.float32),         # acc
            pltpu.VMEM(qs.shape[:4], jnp.float32),     # m
            pltpu.VMEM(qs.shape[:4], jnp.float32),     # l
            pltpu.SemaphoreType.DMA((2,)),             # k send
            pltpu.SemaphoreType.DMA((2,)),             # k recv
            pltpu.SemaphoreType.DMA((2,)),             # v send
            pltpu.SemaphoreType.DMA((2,)),             # v recv
            pltpu.SemaphoreType.REGULAR((2,)),         # consumption ack
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
            collective_id=1),   # distinct from the fused-matmul ring
    )(qs, k, v)
    o = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    return o, lse


# --------------------------------------------------------------------------
# custom VJP
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _ring_attention(axes, causal, window, softcap, scale, use_pallas,
                    q, k, v, q_positions, kv_positions):
    out, _ = _ra_fwd(axes, causal, window, softcap, scale, use_pallas,
                     q, k, v, q_positions, kv_positions)
    return out


def _ra_fwd(axes, causal, window, softcap, scale, use_pallas,
            q, k, v, q_positions, kv_positions):
    if use_pallas:
        out, lse = pallas_ring_forward(q, k, v, axes, causal=causal,
                                       window=window, softcap=softcap,
                                       scale=scale)
    else:
        out, lse = _ring_forward(q, k, v, q_positions, kv_positions, axes,
                                 causal, window, softcap, scale)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _ra_bwd(axes, causal, window, softcap, scale, use_pallas, res, do):
    dq, dk, dv = _ring_backward(res, do, axes, causal, window, softcap,
                                scale)
    _, _, _, qp, kvp, _, _ = res
    return (dq, dk, dv,
            np.zeros(qp.shape, jax.dtypes.float0),
            np.zeros(kvp.shape, jax.dtypes.float0))


_ring_attention.defvjp(_ra_fwd, _ra_bwd)


# --------------------------------------------------------------------------
# public entry
# --------------------------------------------------------------------------
def ring_attention(q, k, v, *, axes: Axes, causal: bool = True,
                   window: Optional[int] = None, softcap: float = 0.0,
                   scale: Optional[float] = None, q_positions=None,
                   kv_positions=None, use_pallas: bool = False):
    """Sequence-sharded attention over the ring formed by ``axes``.

    q [b, sq_local, h, hd]; k, v [b, sk_local, kvh, hd]; positions are
    ABSOLUTE (defaulting to the contiguous shard of ``arange``); padding
    KV rows carry position -1.  Returns [b, sq_local, h, hd].
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = float(scale if scale is not None else hd ** -0.5)
    if q_positions is None:
        q_positions = (axes_index(axes) * sq
                       + jnp.arange(sq, dtype=jnp.int32))[None, :]
    if kv_positions is None:
        kv_positions = (axes_index(axes) * sk
                        + jnp.arange(sk, dtype=jnp.int32))[None, :]
    if q_positions.ndim == 1:
        q_positions = q_positions[None, :]
    if kv_positions.ndim == 1:
        kv_positions = kv_positions[None, :]
    q_positions = jnp.broadcast_to(q_positions, (b, sq)).astype(jnp.int32)
    kv_positions = jnp.broadcast_to(kv_positions, (b, sk)).astype(jnp.int32)

    be = backend(axes, use_pallas=use_pallas)
    if be == "ref":
        kg, vg = k, v
        pg = kv_positions
        if axes:
            kg = lax.all_gather(k, axes, axis=1, tiled=True)
            vg = lax.all_gather(v, axes, axis=1, tiled=True)
            pg = lax.all_gather(kv_positions, axes, axis=1, tiled=True)
        return chunked_attention(q, kg, vg, causal=causal,
                                 window=window, softcap=softcap,
                                 q_positions=q_positions, kv_positions=pg,
                                 scale=scale)
    return _ring_attention(tuple(axes), causal, window, float(softcap),
                           scale, be == "pallas",
                           q, k, v, q_positions, kv_positions)
