"""Pure-jnp oracles for every Pallas kernel (the dry-run/CPU compute path
also routes through these via models/)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.rglru import rglru_scan as _rglru_scan
from repro.models.ssd import ssd_sequential as _ssd_sequential


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, softcap: float = 0.0,
                  scale: Optional[float] = None):
    """Dense softmax attention oracle.  q [b,sq,h,hd]; k,v [b,sk,kvh,hd]."""
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = hd ** -0.5 if scale is None else scale
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rglru_ref(x, params, h0=None):
    """x [b, s, w]; params dict of [w] gate vectors (see models/rglru)."""
    return _rglru_scan(x, params, h0)


def ssd_ref(x, dt, A_log, B, C, D, h0=None):
    """Sequential-scan SSD oracle (exact)."""
    return _ssd_sequential(x, dt, A_log, B, C, D, h0)


def moe_gmm_ref(x, w):
    """Grouped matmul oracle: x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
