"""Grouped (per-expert) matmul Pallas kernel for the MoE dispatch path.

Grid (expert, row_block, col_block, k_block); k sequential with a VMEM
accumulator, so each [C, D] x [D, F] expert product streams K in
MXU-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    acc_scr[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 128,
            block_k: int = 512, interpret: bool = False):
    """x [E, C, D] @ w [E, D, F] -> [E, C, F] per expert."""
    e, c, d = x.shape
    _, _, f = w.shape
    bc = min(block_c, c)
    bf = min(block_f, f)
    bk = min(block_k, d)
    assert c % bc == 0 and f % bf == 0 and d % bk == 0, (c, f, d)
    nk = d // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(e, c // bc, f // bf, nk),
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda ei, ci, fi, ki: (ei, ci, ki)),
            pl.BlockSpec((1, bk, bf), lambda ei, ci, fi, ki: (ei, ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ei, ci, fi, ki: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
