"""Pallas TPU flash attention (the compute hot-spot every Oases TMP block
overlaps around).

Tiling: grid (batch, q_head, q_block, kv_block); the kv_block dimension is
sequential ('arbitrary') and carries the online-softmax state in VMEM
scratch — the accumulator never round-trips HBM (this is precisely the
traffic that dominates the memory roofline term of the pure-jnp ref path).
Causal/local blocks that are fully masked are skipped with ``pl.when`` —
on the MXU this realizes the ~2x causal FLOP saving the ref path cannot.

Supports GQA (kv head = q head // group), sliding-window (local) masking
and gemma2-style attention-logit softcap.  Validated against
:mod:`repro.kernels.ref` in interpret mode on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: float, bq: int, bk: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    run = True
    if causal:
        run = jnp.logical_and(run, q_start + bq - 1 >= k_start)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ()))))

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q [b, sq, h, hd]; k, v [b, sk, kvh, hd] -> [b, sq, h, hd]."""
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    group = h // kvh
    scale = hd ** -0.5 if scale is None else scale

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k

    qt = q.transpose(0, 2, 1, 3)       # [b, h, sq, hd]
    kt = k.transpose(0, 2, 1, 3)       # [b, kvh, sk, hd]
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, sq_p // bq, sk_p // bk)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, seq_k=sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m (running max)
            pltpu.VMEM((bq,), jnp.float32),      # l (running denom)
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :sq]
    return out


# ---------------------------------------------------------------------------
# paged decode attention (serving path)
# ---------------------------------------------------------------------------
def _paged_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, softcap: float,
                  page: int, nb: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(pi * page <= pos_ref[bi])
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [g, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [page, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [page, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [g, page]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page), 1)
        s = jnp.where(k_pos <= pos_ref[bi], s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ()))))

    @pl.when(pi == nb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_flash_decode(q, k_pages, v_pages, tables, pos, *,
                       softcap: float = 0.0, scale: Optional[float] = None,
                       interpret: bool = False):
    """Single-token decode attention reading the KV cache through a block
    table — the Pallas counterpart of
    :func:`repro.models.attention.paged_decode_attention`.

    q [b, 1, h, hd]; k_pages/v_pages [P, page, kvh, hd];
    tables [b, nb] int32 (physical page of logical block i); pos [b].

    The block table rides in as a *scalar-prefetch* operand
    (``PrefetchScalarGridSpec``): the kv ``index_map`` dereferences
    ``tables[bi, pi]`` so the pipeline DMAs exactly the pages each slot
    maps — the gather never materializes a dense [b, S] cache view in HBM.
    The page axis is sequential ('arbitrary') and carries online-softmax
    state in VMEM scratch; pages wholly beyond ``pos`` are skipped.
    Unmapped table entries point at the reserved null page 0 and are
    position-masked.  Global attention only (ring buffers stay dense);
    small head dims are interpret-mode exact but would want lane padding
    on real hardware."""
    b, _, h, hd = q.shape
    npages, page, kvh, _ = k_pages.shape
    nb = tables.shape[1]
    g = h // kvh
    scale = hd ** -0.5 if scale is None else scale

    qt = q.reshape(b, kvh, g, hd)
    flat_tables = tables.reshape(-1).astype(jnp.int32)
    kern = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                             page=page, nb=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, ki, pi, tbl, p_: (bi, ki, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, ki, pi, tbl, p_, n=nb:
                         (tbl[bi * n + pi], 0, ki, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, ki, pi, tbl, p_, n=nb:
                         (tbl[bi * n + pi], 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, pi, tbl, p_: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),       # m (running max)
            pltpu.VMEM((g,), jnp.float32),       # l (running denom)
            pltpu.VMEM((g, hd), jnp.float32),    # acc
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(flat_tables, pos.astype(jnp.int32), qt, k_pages, v_pages)
    return out.reshape(b, 1, h, hd)
