"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

Each kernel module is a ``pl.pallas_call`` with explicit BlockSpec VMEM
tiling; ``ops.py`` holds the jit'd public wrappers (interpret=True off-TPU)
and ``ref.py`` the pure-jnp oracles the tests sweep against.
"""
