"""RG-LRU Pallas kernel (RecurrentGemma's sequential hot loop).

Grid (width_blocks, time_blocks); the time dimension is sequential
('arbitrary') and the per-width-block recurrent state h lives in VMEM
scratch across time blocks — the HBM traffic is exactly x-in / y-out.
Within a block the recurrence runs as an unrolled elementwise chain over
bt steps (VPU work, no MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

C_CONST = 8.0


def _kernel(x_ref, wa_ref, ba_ref, wx_ref, bx_ref, ap_ref, o_ref, h_scr, *,
            bt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)           # [bt, bw]
    wa = wa_ref[...].astype(jnp.float32)
    ba = ba_ref[...].astype(jnp.float32)
    wx = wx_ref[...].astype(jnp.float32)
    bx = bx_ref[...].astype(jnp.float32)
    ap = ap_ref[...].astype(jnp.float32)

    r = jax.nn.sigmoid(x * wa[None] + ba[None])
    i = jax.nn.sigmoid(x * wx[None] + bx[None])
    log_a = -C_CONST * jax.nn.softplus(ap)[None] * r          # [bt, bw]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * x)

    h = h_scr[...]
    ys = []
    for t in range(bt):                       # unrolled within the block
        h = a[t] * h + gated[t]
        ys.append(h)
    h_scr[...] = h
    o_ref[0] = jnp.stack(ys).astype(o_ref.dtype)


def rglru(x, params, *, block_t: int = 64, block_w: int = 512,
          interpret: bool = False):
    """x [b, s, w] (conv'd input branch); params: w_a/b_a/w_x/b_x/a_param [w].
    Returns (y [b, s, w], h_last [b, w])."""
    b, s, w = x.shape
    bt = min(block_t, s)
    bw = min(block_w, w)
    assert s % bt == 0 and w % bw == 0, (s, bt, w, bw)

    def one_batch(xb):
        y = pl.pallas_call(
            functools.partial(_kernel, bt=bt),
            grid=(w // bw, s // bt),
            in_specs=[
                pl.BlockSpec((1, bt, bw), lambda wi, ti: (0, ti, wi)),
                pl.BlockSpec((bw,), lambda wi, ti: (wi,)),
                pl.BlockSpec((bw,), lambda wi, ti: (wi,)),
                pl.BlockSpec((bw,), lambda wi, ti: (wi,)),
                pl.BlockSpec((bw,), lambda wi, ti: (wi,)),
                pl.BlockSpec((bw,), lambda wi, ti: (wi,)),
            ],
            out_specs=pl.BlockSpec((1, bt, bw), lambda wi, ti: (0, ti, wi)),
            out_shape=jax.ShapeDtypeStruct((1, s, w), x.dtype),
            scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(xb[None], params["w_a"], params["b_a"], params["w_x"],
          params["b_x"], params["a_param"])
        return y[0]

    y = jax.vmap(one_batch)(x)
    return y, y[:, -1].astype(jnp.float32)
