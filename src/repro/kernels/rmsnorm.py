"""Fused RMSNorm Pallas kernel — removes one HBM round-trip per TMP block
boundary (the norm feeds the column-parallel matmuls directly).

Grid over row blocks; the feature dim stays whole in VMEM (d_model values
up to 8k rows x 16k cols tile fine on v5e with (block_rows, d) <= ~2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x [..., d]; scale [d]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
