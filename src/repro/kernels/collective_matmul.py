"""Fused collective-matmul kernels: TMP collectives streamed through the
matmul hot path (paper §3 taken to kernel granularity).

The repo's four schedules express comm/compute overlap as *program
structure* and rely on XLA's latency-hiding scheduler.  This module is the
next level down: the collective is decomposed into a ring whose per-step
transfer is data-dependent ONLY on the previous step's tile matmul, so the
overlap is guaranteed by construction rather than hoped for.  Three fusions:

* ``matmul → reduce-scatter``  (row-parallel exit, SP mode): each ring step
  matmuls one output chunk and forwards the partial sum to the right
  neighbour while the next chunk's matmul runs.
* ``matmul → all-reduce``      (row-parallel exit, Megatron mode): the ring
  reduce-scatter above, whose matmuls all hide in the scatter phase,
  followed by a ring all-gather of the reduced chunk (same total link bytes
  as an AllReduce).
* ``all-gather → matmul``      (column-parallel entry, SP mode): shards are
  consumed by the matmul as they arrive; supports a *list* of weights so
  one ring feeds all of a block's entry projections (wq/wk/wv or wg/wu).

Three execution backends, selected by :func:`backend`:

* ``ref``    — ``jnp.dot`` + ``lax.psum``/``psum_scatter``/``all_gather``:
  the numerics oracle, and the fallback for multi-axis (factored-mesh)
  groups or non-divisible shapes.
* ``ring``   — the decomposition written with ``lax.ppermute`` +
  ``jax.lax.dot``: runs on every platform (this is what CPU tests and the
  8-virtual-device equivalence subprocesses validate), and on TPU already
  guarantees per-step independence in the emitted HLO.
* ``pallas`` — a single Pallas kernel per device: tile matmuls on the MXU
  with the ring transfer as a double-buffered ``make_async_remote_copy``
  that overlaps the next tile's compute (TPU only).

Gradients follow the partial-cotangent convention of :mod:`repro.core.tmp`:
the SP pair (`fused_allgather_matmul`/`fused_matmul_reducescatter`) are
custom-VJPs whose backward is itself a fused ring (AG→matmul transposes to
matmul→RS and vice versa, so the backward pass overlaps too);
``fused_matmul_allreduce`` is deliberately left transparent to autodiff —
like ``reduce_from_tmp`` it must stay visible to the fine-remat policy,
and JAX's transpose of the ring is automatically the reversed (still
overlapped) ring.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params
from repro.core.tmp import Axes, axes_index, axes_size

# --------------------------------------------------------------------------
# backend selection
# --------------------------------------------------------------------------


def backend(axes: Axes, size_along_dim: int, *,
            use_pallas: bool = False) -> str:
    """Pick the execution backend for a fused op.

    Ring fusion needs a single mesh axis (``lax.ppermute`` ring) and a
    divisible chunk dim; everything else falls back to the reference
    (blocking-collective) path.  The fallback is always correct for the
    all-reduce and all-gather flavours; reduce-scatter semantics require
    the divisibility regardless of backend (``psum_scatter`` tiled), which
    ``_dispatch_rs`` checks explicitly.
    """
    if len(axes) != 1:       # no axes, or a multi-axis (factored) group
        return "ref"
    n = axes_size(axes)
    if n <= 1:
        return "ref"
    if size_along_dim % n != 0:
        return "ref"
    if use_pallas and jax.default_backend() == "tpu":
        return "pallas"
    return "ring"


def _ring_perm(n: int, reverse: bool = False):
    if reverse:
        return [(j, (j - 1) % n) for j in range(n)]
    return [(j, (j + 1) % n) for j in range(n)]


# --------------------------------------------------------------------------
# reference path (numerics oracle + fallback)
# --------------------------------------------------------------------------
def matmul_allreduce_ref(x, w, axes: Axes):
    y = jnp.dot(x, w)
    return lax.psum(y, axes) if axes else y


def matmul_reducescatter_ref(x, w, axes: Axes, scatter_dim: int):
    y = jnp.dot(x, w)
    return (lax.psum_scatter(y, axes, scatter_dimension=scatter_dim,
                             tiled=True) if axes else y)


def allgather_matmul_ref(x, ws: Sequence, axes: Axes, gather_dim: int):
    h = lax.all_gather(x, axes, axis=gather_dim, tiled=True) if axes else x
    return tuple(jnp.dot(h, w) for w in ws)


# --------------------------------------------------------------------------
# ring decomposition (platform-independent fused path)
# --------------------------------------------------------------------------
def ring_matmul_reducescatter(x, w, axes: Axes, scatter_dim: int):
    """Row-parallel ``x @ w`` fused with a ring reduce-scatter of the output
    along ``scatter_dim``.

    Chunk schedule: at step s device i computes its local contribution to
    output chunk ``(i - 1 - s) mod n`` and adds it to the partial sum
    arriving from the left; after n steps (n-1 hops) device i holds the
    fully reduced chunk i.  The step-s matmul is independent of the
    in-flight step-(s-1) transfer — the overlap window.
    """
    return _ring_rs_multi(((x, w),), axes, scatter_dim)


def _ring_rs_multi(pairs, axes: Axes, scatter_dim: int):
    """Ring reduce-scatter of ``sum_k x_k @ w_k`` — one ring carries the
    summed partials, so a multi-weight backward (dx of the fused AG-matmul)
    pays the link bytes once instead of once per weight."""
    axis = axes[0]
    n = axes_size(axes)
    idx = axes_index(axes)
    chunk = pairs[0][0].shape[scatter_dim] // n

    def contrib(s):
        c = (idx - 1 - s) % n
        out = None
        for xk, wk in pairs:
            xc = lax.dynamic_slice_in_dim(xk, c * chunk, chunk,
                                          axis=scatter_dim)
            t = jnp.dot(xc, wk)
            out = t if out is None else out + t
        return out

    accum = contrib(0)
    for s in range(1, n):
        arriving = lax.ppermute(accum, axis, _ring_perm(n))
        accum = arriving + contrib(s)   # dot is independent of the permute
    return accum


def ring_allgather(y_chunk, axes: Axes, dim: int):
    """Plain ring all-gather of a local chunk along ``dim`` (the cool-down
    phase of the fused all-reduce; no compute left to hide)."""
    axis = axes[0]
    n = axes_size(axes)
    idx = axes_index(axes)
    chunk = y_chunk.shape[dim]
    full = y_chunk.shape[:dim] + (chunk * n,) + y_chunk.shape[dim + 1:]
    out = jnp.zeros(full, y_chunk.dtype)
    cur = y_chunk
    for s in range(n):
        src = (idx - s) % n           # after s reverse hops we hold chunk src
        out = lax.dynamic_update_slice_in_dim(out, cur, src * chunk, axis=dim)
        if s < n - 1:
            cur = lax.ppermute(cur, axis, _ring_perm(n))
    return out


def ring_matmul_allreduce(x, w, axes: Axes, scatter_dim: int):
    """Fused ``matmul → all-reduce`` = overlapped ring reduce-scatter (all
    matmul flops hide in the scatter phase) + ring all-gather (same total
    link bytes as a plain ring AllReduce: 2K(n-1)/n)."""
    y_chunk = ring_matmul_reducescatter(x, w, axes, scatter_dim)
    return ring_allgather(y_chunk, axes, scatter_dim)


def ring_allgather_matmul(x, ws: Sequence, axes: Axes, gather_dim: int,
                          *, contract: Sequence = ()):
    """Column-parallel entry: gathered shards of ``x`` are consumed by the
    matmul(s) as they arrive.  One ring feeds every weight in ``ws``.

    At step s device i holds shard ``(i + s) mod n`` (received from the
    right neighbour) and immediately matmuls it into the output row block
    while the next shard is in flight.

    ``contract``: optional full-size tensors to contract against the
    SAME rotating shards — entry j accumulates
    ``einsum('...f,...r->fr', contract[j][chunk src], shard)``, i.e.
    ``contract[j].T @ AG(x)`` without a second gather.  This is how the
    fused backward produces dw on the dx ring: the bytes go around once.
    Returns ``outs`` alone, or ``(outs, contracted)`` when ``contract``
    is non-empty.
    """
    axis = axes[0]
    n = axes_size(axes)
    idx = axes_index(axes)
    chunk = x.shape[gather_dim]

    outs = []
    for w in ws:
        full = (x.shape[:gather_dim] + (chunk * n,)
                + x.shape[gather_dim + 1:-1] + (w.shape[-1],))
        outs.append(jnp.zeros(full, jnp.result_type(x.dtype, w.dtype)))
    contracted = [None] * len(contract)

    cur = x
    for s in range(n):
        nxt = (lax.ppermute(cur, axis, _ring_perm(n, reverse=True))
               if s < n - 1 else None)
        src = (idx + s) % n
        for k, w in enumerate(ws):
            outs[k] = lax.dynamic_update_slice_in_dim(
                outs[k], jnp.dot(cur, w), src * chunk, axis=gather_dim)
        for j, f in enumerate(contract):
            fc = lax.dynamic_slice_in_dim(f, src * chunk, chunk,
                                          axis=gather_dim)
            t = jnp.einsum("...f,...r->fr", fc, cur)
            contracted[j] = t if contracted[j] is None else contracted[j] + t
        cur = nxt
    if contract:
        return tuple(outs), tuple(contracted)
    return tuple(outs)


# --------------------------------------------------------------------------
# Pallas TPU kernels: the ring transfer as in-kernel double-buffered RDMA
# --------------------------------------------------------------------------
def _mm_tile_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    """Tiled matmul microkernel shared by the ring kernels' compute step:
    grid (m_tiles, n_tiles, k_tiles), fp32 VMEM accumulator."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def pallas_tile_matmul(x, w, *, block_m: Optional[int] = None,
                       block_n: Optional[int] = None,
                       block_k: Optional[int] = None,
                       interpret: Optional[bool] = None):
    """2-D tiled matmul ``[m, k] @ [k, n]`` — the per-ring-step compute of
    the collective kernels, exposed standalone so CPU tests can validate
    the tiling/accumulation in interpret mode.

    Block sizes left as ``None`` resolve through the autotuner
    (:func:`repro.kernels.autotune.tuned_blocks`, cached per shape and
    platform); explicit arguments always win."""
    interpret = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    m, k = x.shape
    k2, nn = w.shape
    assert k == k2, (x.shape, w.shape)
    if block_m is None or block_n is None or block_k is None:
        from repro.kernels.autotune import tuned_blocks
        tm, tn, tk = tuned_blocks(m, k, nn, dtype=x.dtype)
        block_m = tm if block_m is None else block_m
        block_n = tn if block_n is None else block_n
        block_k = tk if block_k is None else block_k
    bm, bn, bk = min(block_m, m), min(block_n, nn), min(block_k, k)
    pad_m, pad_n, pad_k = (-m) % bm, (-nn) % bn, (-k) % bk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    mp, kp, np_ = m + pad_m, k + pad_k, nn + pad_n
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_tile_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
    if pad_m or pad_n:
        out = out[:m, :nn]
    return out


def _rs_ring_kernel(x_ref, w_ref, o_ref, accum, cbuf, send_sem,
                    recv_sem, ack_sem, *, n_dev: int, axis_name: str):
    """Fused matmul→reduce-scatter, one device's kernel body.

    grid = (n_dev,) sequential ('arbitrary'): step s computes the tile
    matmul for output chunk (i-1-s) mod n — the caller pre-rolls x chunks
    so step s reads static block s — accumulates the partial arriving from
    the left, and STARTS the forward to the right without waiting: the
    transfer completes under step s+1's matmul.  That deferred wait is the
    whole point of the kernel.

    Buffering/flow control (everything 2-slot, slot = s % 2):

    * ``accum[slot]``  — this step's partial sum; the slot is reused at
      s+2, by which time the s-send's local readout has been drained
      (``send_sem`` waited one step late, which does not block overlap —
      it only gates on the NIC having read the buffer, not on delivery).
    * ``cbuf[slot]``   — landing buffer on the receiver.  The receiver
      acks consumption (remote ``semaphore_signal`` to its LEFT) before
      the sender reuses the slot at s+2 — without the ack a fast sender
      two steps ahead could clobber an unconsumed partial.
    """
    s = pl.program_id(0)
    slot, prev = s % 2, (s - 1) % 2
    my_id = jax.lax.axis_index(axis_name)
    left = (my_id - 1) % n_dev
    right = (my_id + 1) % n_dev

    @pl.when(s == 0)
    def _barrier():
        # neighbours must have entered the kernel before any RDMA lands
        bsem = pltpu.get_barrier_semaphore()
        for nb in (left, right):
            pltpu.semaphore_signal(bsem, inc=1, device_id=nb,
                                   device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(bsem, 2)

    partial_sum = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())))

    @pl.when(s == 0)
    def _first():
        accum[0] = partial_sum

    @pl.when(s > 0)
    def _rest():
        pltpu.semaphore_wait(recv_sem[prev], 1)     # left's partial landed
        accum[slot] = cbuf[prev] + partial_sum
        # cbuf[prev] is free again: ack the sender (our left neighbour)
        pltpu.semaphore_signal(ack_sem[prev], inc=1, device_id=left,
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(send_sem[prev], 1)     # drain our s-1 send

    @pl.when(s < n_dev - 1)
    def _forward():
        @pl.when(s >= 2)
        def _flow_control():
            # right neighbour must have consumed our s-2 payload from this
            # slot (its ack) before we overwrite it
            pltpu.semaphore_wait(ack_sem[slot], 1)

        rdma = pltpu.make_async_remote_copy(
            src_ref=accum.at[slot],
            dst_ref=cbuf.at[slot],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[slot],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()                # NO wait: overlaps step s+1's matmul

    @pl.when(s == n_dev - 1)
    def _finish():
        o_ref[...] = accum[slot].astype(o_ref.dtype)
        # Drain outstanding acks so every semaphore is zero at kernel
        # exit.  Ledger: n-1 sends each draw one ack; _flow_control
        # consumed one per step for s in [2, n-2] (n-3 of them), leaving
        # the acks of the last two sends (steps n-3 and n-2) — one for
        # n_dev == 2 — outstanding here.
        pltpu.semaphore_wait(ack_sem[(n_dev - 2) % 2], 1)
        if n_dev >= 3:
            pltpu.semaphore_wait(ack_sem[(n_dev - 3) % 2], 1)


def pallas_matmul_reducescatter(x, w, axes: Axes, scatter_dim: int):
    """TPU path of the fused matmul→reduce-scatter.

    The scatter dim is moved to the front and the n chunks are reordered
    locally (flip + roll by the device index) so the kernel's step-s block
    is a STATIC slice — the kernel then runs the ring with in-kernel RDMA
    and no dynamic VMEM indexing.
    """
    axis = axes[0]
    n = axes_size(axes)
    idx = axes_index(axes)
    d_out = w.shape[-1]
    k = x.shape[-1]
    xm = jnp.moveaxis(x, scatter_dim, 0)          # [S, ..., K]
    mid = xm.shape[1:-1]
    s_full = xm.shape[0]
    rows = s_full * math.prod(mid)
    x2 = xm.reshape(rows, k)
    chunk = rows // n
    # local chunk order for step s is (i-1-s) mod n == flip-then-roll-by-i
    x2 = x2.reshape(n, chunk, k)[::-1]
    x2 = jnp.roll(x2, idx, axis=0).reshape(rows, k)
    out = pl.pallas_call(
        functools.partial(_rs_ring_kernel, n_dev=n, axis_name=axis),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((chunk, k), lambda s: (s, 0)),
            pl.BlockSpec((k, d_out), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, d_out), lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((chunk, d_out), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, chunk, d_out), jnp.float32),    # accum (2-slot)
            pltpu.VMEM((2, chunk, d_out), jnp.float32),    # ring double-buf
            pltpu.SemaphoreType.DMA((2,)),                 # send
            pltpu.SemaphoreType.DMA((2,)),                 # recv
            pltpu.SemaphoreType.REGULAR((2,)),             # consumption ack
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
            collective_id=0),
    )(x2, w)
    out = out.reshape((s_full // n,) + mid + (d_out,))
    return jnp.moveaxis(out, 0, scatter_dim)


# --------------------------------------------------------------------------
# public fused ops (gradient-aware)
# --------------------------------------------------------------------------
def _dispatch_rs(x, w, axes: Axes, scatter_dim: int, use_pallas: bool):
    n = axes_size(axes)
    if n > 1 and x.shape[scatter_dim] % n != 0:
        # no backend can save this: tiled reduce-scatter semantics need an
        # even split (psum_scatter would raise deeper with a worse message)
        raise ValueError(
            f"matmul→reduce-scatter: scatter dim {scatter_dim} of size "
            f"{x.shape[scatter_dim]} is not divisible by the TMP group "
            f"size {n}")
    be = backend(axes, x.shape[scatter_dim], use_pallas=use_pallas)
    if be == "ref":
        return matmul_reducescatter_ref(x, w, axes, scatter_dim)
    if be == "pallas":
        return pallas_matmul_reducescatter(x, w, axes, scatter_dim)
    return ring_matmul_reducescatter(x, w, axes, scatter_dim)


def _dispatch_ag(x, ws, axes: Axes, gather_dim: int, use_pallas: bool):
    # the AG ring needs no divisibility check (every device holds an equal
    # shard by construction) — only a single-axis ring of size > 1
    if len(axes) != 1 or axes_size(axes) <= 1:
        return allgather_matmul_ref(x, ws, axes, gather_dim)
    # pallas AG-matmul rides the ring path until the dedicated kernel lands
    # on a TPU runway; the ring already guarantees per-step independence.
    return ring_allgather_matmul(x, ws, axes, gather_dim)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fused_matmul_reducescatter(x, w, axes: Axes, scatter_dim: int = 1,
                               use_pallas: bool = False):
    """Row-parallel ``x @ w`` + ring reduce-scatter along ``scatter_dim``
    (the SP-mode block exit).  Backward is the transposed fused ring:
    ``dx = AG(g)-matmul`` ring, ``dw`` accumulated per arriving shard —
    consistent with the partial-cotangent convention (``psum_scatter``
    transposes to ``all_gather``, cf. ``sp_reduce_scatter``)."""
    return _dispatch_rs(x, w, axes, scatter_dim, use_pallas)


def _rs_fwd(x, w, axes, scatter_dim, use_pallas):
    return _dispatch_rs(x, w, axes, scatter_dim, use_pallas), (x, w)


def _rs_bwd(axes, scatter_dim, use_pallas, res, g):
    x, w = res
    if not axes or axes_size(axes) == 1:
        return jnp.dot(g, w.T).astype(x.dtype), \
            jnp.einsum("...k,...d->kd", x, g).astype(w.dtype)
    if len(axes) != 1:
        # multi-axis (factored-mesh) fallback: blocking collectives
        g_full = lax.all_gather(g, axes, axis=scatter_dim, tiled=True)
        return jnp.dot(g_full, w.T).astype(x.dtype), \
            jnp.einsum("...k,...d->kd", x, g_full).astype(w.dtype)
    # ONE ring: as each g shard arrives it feeds both the dx matmul and
    # the dw contraction against x's matching chunk — the cotangent's
    # bytes go around once, both gradients overlap the transfer.  dw stays
    # a per-shard partial: the shard_map boundary psums parameter
    # cotangents over replicated axes (partial-cotangent convention).
    (dx,), (dw,) = ring_allgather_matmul(g, (w.T,), axes, scatter_dim,
                                         contract=(x,))
    return dx.astype(x.dtype), dw.astype(w.dtype)


fused_matmul_reducescatter.defvjp(_rs_fwd, _rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def fused_allgather_matmul(x, ws, axes: Axes, gather_dim: int = 1,
                           use_pallas: bool = False):
    """Column-parallel block entry (SP mode): ring all-gather of ``x``
    along ``gather_dim`` with each arriving shard immediately consumed by
    every matmul in ``ws``.  Returns one output per weight.

    Backward: ``dx`` is a fused matmul→reduce-scatter ring (the transpose
    of AG under the partial-cotangent convention, cf. ``sp_all_gather``);
    ``dw_k`` re-gathers ``x`` ring-wise (Megatron-SP style: the sharded
    input is the residual, halving saved activations vs caching the
    gathered tensor)."""
    return _dispatch_ag(x, tuple(ws), axes, gather_dim, use_pallas)


def _ag_fwd(x, ws, axes, gather_dim, use_pallas):
    return _dispatch_ag(x, tuple(ws), axes, gather_dim, use_pallas), (x, ws)


def _ag_bwd(axes, gather_dim, use_pallas, res, gs):
    x, ws = res
    if not axes or axes_size(axes) == 1:
        dx = sum(jnp.dot(g, w.T) for g, w in zip(gs, ws))
        dws = tuple(jnp.einsum("...k,...d->kd", x, g).astype(w.dtype)
                    for g, w in zip(gs, ws))
        return dx.astype(x.dtype), dws
    if len(axes) != 1 or gs[0].shape[gather_dim] % axes_size(axes) != 0:
        # fallback: blocking collectives
        dx = lax.psum_scatter(
            sum(jnp.dot(g, w.T) for g, w in zip(gs, ws)), axes,
            scatter_dimension=gather_dim, tiled=True)
        x_full = lax.all_gather(x, axes, axis=gather_dim, tiled=True)
        dws = tuple(jnp.einsum("...k,...d->kd", x_full, g).astype(w.dtype)
                    for g, w in zip(gs, ws))
        return dx.astype(x.dtype), dws
    # dx: ONE reduce-scatter ring carrying the summed per-chunk partials
    # sum_k g_k @ w_k^T (reduce-scatter is linear — k rings would move the
    # same bytes k times)
    dx = _ring_rs_multi(tuple((g, w.T) for g, w in zip(gs, ws)), axes,
                        gather_dim)
    # dw_k: re-gather x ring-wise, contracting each arriving shard with
    # every g_k chunk while the next shard is in flight (Megatron-SP
    # residual economy: the sharded input is the residual, and the
    # contraction hides the gather)
    _, dws = ring_allgather_matmul(x, (), axes, gather_dim, contract=gs)
    dws = tuple(dw.T.astype(w.dtype) for dw, w in zip(dws, ws))
    return dx.astype(x.dtype), dws


fused_allgather_matmul.defvjp(_ag_fwd, _ag_bwd)


def fused_matmul_allreduce(x, w, axes: Axes, *, scatter_dim: int = 1,
                           use_pallas: bool = False):
    """Row-parallel ``x @ w`` + AllReduce as an overlapped RS+AG ring.

    Deliberately NOT a custom_vjp (mirrors ``reduce_from_tmp``): the ring
    is plain linear jax, so the fine-remat ``save_only_these_names`` policy
    sees through it — with the output checkpoint-named by the caller the
    recompute replays no collective — and JAX's transpose of the ring is
    automatically the reversed, still-overlapped ring.
    """
    n = axes_size(axes)
    if n <= 1:
        return jnp.dot(x, w)
    be = backend(axes, x.shape[scatter_dim], use_pallas=use_pallas)
    if be == "ref":
        return matmul_allreduce_ref(x, w, axes)
    if be == "pallas":
        y_chunk = pallas_matmul_reducescatter(x, w, axes, scatter_dim)
        return ring_allgather(y_chunk, axes, scatter_dim)
    return ring_matmul_allreduce(x, w, axes, scatter_dim)
