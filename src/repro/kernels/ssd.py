"""Mamba2 SSD (state-space duality) Pallas kernel.

Grid (batch, head, chunk); the chunk dimension is sequential and the
inter-chunk state S [hd, n] rides in VMEM scratch.  Per chunk:

  intra:  Y += tril(C B^T * seg_decay) @ (dt*X)     (quadratic inside chunk)
  inter:  Y += exp(cumlog_a) * (C @ S^T)
  state:  S  = chunk_decay * S + (decay_to_end * dt * X)^T @ B

This is the TPU-native chunking of the SSD recurrence: MXU-sized [Q, hd] x
[hd, n] tiles, no sequential elementwise scan in the hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _kernel(x_ref, dta_ref, dtx_ref, b_ref, c_ref, o_ref, s_scr, *, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    dta = dta_ref[0, 0].astype(jnp.float32)           # [q] log-decay
    xdt = dtx_ref[0, 0].astype(jnp.float32)           # [q, hd] dt*x
    B = b_ref[0].astype(jnp.float32)                  # [q, n]
    C = c_ref[0].astype(jnp.float32)                  # [q, n]

    la = jnp.cumsum(dta)                              # [q]
    la_last = la[-1]

    # intra-chunk quadratic
    seg = jnp.exp(la[:, None] - la[None, :])          # [q, q]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = jnp.where(iota_j <= iota_i, seg, 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))     # [q, q]
    y = jax.lax.dot_general(cb * seg, xdt, (((1,), (0,)), ((), ())))

    # inter-chunk from carried state
    S = s_scr[...]                                    # [hd, n]
    y += jnp.exp(la)[:, None] * jax.lax.dot_general(
        C, S, (((1,), (1,)), ((), ())))               # [q, hd]

    # state update
    decay_to_end = jnp.exp(la_last - la)              # [q]
    s_scr[...] = (jnp.exp(la_last) * S
                  + jax.lax.dot_general(
                      xdt * decay_to_end[:, None], B,
                      (((0,), (0,)), ((), ()))))      # [hd, n]
    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd(x, dt, A_log, B, C, D, *, chunk: int = 128,
        interpret: bool = False):
    """x [b, s, h, p]; dt [b, s, h] (post-softplus); A_log [h]; B, C [b, s, n];
    D [h].  Returns y [b, s, h, p] (final state not returned — training path;
    decode uses models/ssd.ssd_step)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0

    a = -jnp.exp(A_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a[None, None, :]            # [b, s, h]
    dtx = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # layouts: per (batch, head): x [s, p]; B/C shared across heads
    dta_t = dta.transpose(0, 2, 1)                             # [b, h, s]
    dtx_t = dtx.transpose(0, 2, 1, 3)                          # [b, h, s, p]

    y = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(b, h, s // q),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, q), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dtx_t, dta_t, dtx_t, B, C)
    y = y.transpose(0, 2, 1, 3)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)
