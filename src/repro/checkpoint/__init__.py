from repro.checkpoint.store import (AsyncCheckpointer,
                                    CorruptCheckpointError, all_steps,
                                    latest_intact_step, latest_step, restore,
                                    save, verify)

__all__ = ["AsyncCheckpointer", "CorruptCheckpointError", "all_steps",
           "latest_intact_step", "latest_step", "restore", "save", "verify"]
