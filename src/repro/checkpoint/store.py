"""Sharded checkpointing with async writes, atomic commit, keep-last-k GC,
integrity checksums, and reshard-on-load (elastic restarts).

Layout:
  <dir>/step_<n>.tmp/            while writing
  <dir>/step_<n>/                after atomic rename (commit point)
      manifest.json              step, tree structure, leaf shapes/dtypes,
                                 per-leaf crc32 checksums
      shard_<i>.npz              leaf arrays (host's addressable shards)

On a multi-host cluster each host writes its addressable shards; this
container is single-host, so the full arrays land in one shard file.  The
restore path re-shards to whatever mesh the restarted job brings — pods can
be dropped/added between runs (elastic scaling).

Integrity: ``save`` records a crc32 of every leaf's bytes in the manifest;
``restore`` verifies and raises :class:`CorruptCheckpointError` on any
mismatch (or unreadable shard/manifest), so a torn write or bit-rot never
silently loads garbage.  ``latest_intact_step``/the trainer's
``restore_or_init`` walk back to the newest checkpoint that verifies.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint failed integrity verification (checksum mismatch,
    unreadable shard, or missing/garbled manifest).  Restore paths catch
    this to fall back to the previous intact checkpoint instead of
    crashing — or worse, silently training on corrupted state."""


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), v) for kp, v in leaves], treedef


def _crc(arr: np.ndarray) -> int:
    """crc32 of a leaf's raw bytes (contiguous view, so the checksum is a
    pure function of values + dtype + shape order)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save(ckpt_dir: str, step: int, tree, *, metadata: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous sharded save with atomic rename."""
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "metadata": metadata or {},
                "time": time.time()}
    arrays = {}
    for i, (key, v) in enumerate(leaves):
        if v is None:
            manifest["leaves"].append({"key": key, "none": True})
            continue
        arr = np.asarray(jax.device_get(v))
        arrays[f"a{i}"] = arr
        manifest["leaves"].append(
            {"key": key, "name": f"a{i}", "shape": list(arr.shape),
             "dtype": str(arr.dtype), "crc32": _crc(arr)})
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # commit point
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_shard(d: str) -> Tuple[dict, Any]:
    """(manifest, npz data) of a checkpoint dir, with unreadable files
    normalized to :class:`CorruptCheckpointError`."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"checkpoint {d} is unreadable: {e!r}") from e
    return manifest, data


def _verified_leaves(d: str) -> Tuple[dict, dict]:
    """(manifest, {keystr: array | None}) of a checkpoint, verifying
    per-leaf crc32 checksums where the manifest records them
    (pre-integrity checkpoints load unchecked)."""
    manifest, data = _load_shard(d)
    by_key = {}
    for leaf in manifest["leaves"]:
        if leaf.get("none"):
            by_key[leaf["key"]] = None
            continue
        try:
            arr = data[leaf["name"]]
        except Exception as e:
            raise CorruptCheckpointError(
                f"checkpoint {d} shard is corrupt at leaf "
                f"{leaf['key']}: {e!r}") from e
        want = leaf.get("crc32")
        if want is not None and _crc(arr) != want:
            raise CorruptCheckpointError(
                f"checkpoint {d} failed integrity check: leaf "
                f"{leaf['key']} crc32 {_crc(arr):#010x} != recorded "
                f"{want:#010x}")
        by_key[leaf["key"]] = arr
    return manifest, by_key


def verify(ckpt_dir: str, step: int) -> bool:
    """True iff the checkpoint at ``step`` passes integrity verification."""
    try:
        _verified_leaves(os.path.join(ckpt_dir, f"step_{step}"))
        return True
    except CorruptCheckpointError:
        return False


def latest_intact_step(ckpt_dir: str) -> Optional[int]:
    """The newest step whose checkpoint verifies — the safe restore
    target when the newest write may be torn or bit-rotted."""
    for s in reversed(all_steps(ckpt_dir)):
        if verify(ckpt_dir, s):
            return s
    return None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The checkpoint's manifest (step, leaves, metadata — including the
    ParallelPlan the run trained under) without loading any arrays; the
    elastic-resume path reads this first to decide whether a cross-plan
    relayout is needed."""
    with open(os.path.join(ckpt_dir, f"step_{step}",
                           "manifest.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None, remap=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like_tree``; device_put with the
    (possibly different) target shardings — the elastic reshard path.

    ``remap``: optional ``{keystr: array} -> {keystr: array}`` transform
    applied to the loaded leaves before matching — the cross-plan
    relayout hook (runtime/trainer.py builds it from the manifest's plan
    vs the current one via models/params.relayout_flat).

    Raises :class:`CorruptCheckpointError` (never returns garbage) when
    the checkpoint fails its manifest crc32 integrity check."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    manifest, by_key = _verified_leaves(d)
    if remap is not None:
        by_key = remap(by_key)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        like_tree, is_leaf=lambda x: x is None)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(leaves))
    out = []
    for (kp, like), sh in zip(leaves, shard_leaves):
        key = jax.tree_util.keystr(kp)
        arr = by_key.get(key)
        if arr is None:
            out.append(None)
            continue
        like_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != like_shape:
            # same element count, different LEADING stacking only: the
            # pipeline-stage layout [v, pp, n/S, ...] row-major-flattens to
            # the canonical [n, ...] layer order (models/params.py), so
            # PP <-> non-PP elastic re-meshes are a pure reshape.  Require
            # the per-layer (trailing) dims to match and one side's
            # remainder to be a single stack dim — anything else (e.g. a
            # transposed weight from a config edit) must fail loudly, not
            # restore scrambled.
            a, b = tuple(arr.shape), like_shape

            def _restack_ok(a, b):
                # the two valid relations between a leaf's layouts: flat
                # [n, *w] vs stage-stacked [v, pp, n/S, *w] (rank +2) and
                # stacked vs stacked with different (pp, v) (equal rank >
                # 3, same per-layer dims) — a transposed weight matches
                # neither and fails loudly
                if len(a) == len(b) + 2:
                    return a[3:] == b[1:] and \
                        int(np.prod(a[:3])) == b[0]
                if len(b) == len(a) + 2:
                    return _restack_ok(b, a)
                return (len(a) == len(b) > 3 and a[3:] == b[3:]
                        and int(np.prod(a[:3])) == int(np.prod(b[:3])))

            if not _restack_ok(a, b):
                raise ValueError(
                    f"checkpoint leaf {key} has shape {tuple(arr.shape)}, "
                    f"restore target wants {like_shape} — not a pipeline-"
                    f"stage restacking; the checkpoint does not match "
                    f"this model/mesh")
            arr = arr.reshape(like_shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        elif hasattr(like, "sharding"):
            out.append(jax.device_put(arr, like.sharding))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


class AsyncCheckpointer:
    """Background-thread writer: snapshot to host, return immediately.

    Transient I/O errors (``OSError``) are retried ``retries`` times with
    exponential backoff before the exception is stashed for the next
    ``wait()``; every failed attempt increments ``failed_saves``, a
    counter an external supervisor can inspect to escalate persistent
    storage trouble (runtime/elastic.py).  ``save_fn`` is injectable so
    fault-injection tests can make writes flaky or corrupt committed
    shards deterministically."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3, *,
                 retries: int = 2, backoff_s: float = 0.05,
                 save_fn=None):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self.retries = retries
        self.backoff_s = backoff_s
        self.failed_saves = 0              # cumulative failed write attempts
        self._save_fn = save_fn or save
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda v: None if v is None else np.asarray(jax.device_get(v)),
            tree, is_leaf=lambda x: x is None)

        def work():
            for attempt in range(self.retries + 1):
                try:
                    self._save_fn(self.ckpt_dir, step, host_tree,
                                  metadata=metadata,
                                  keep_last=self.keep_last)
                    return
                except OSError as e:       # transient I/O: retry w/ backoff
                    self.failed_saves += 1
                    if attempt == self.retries:
                        self._error = e    # surfaced on next wait()
                        return
                    time.sleep(self.backoff_s * (2 ** attempt))
                except BaseException as e:  # non-I/O: don't retry
                    self.failed_saves += 1
                    self._error = e
                    return

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e
