"""granite-8b — llama-arch dense GQA code model [arXiv:2405.04324; hf]."""
from repro.configs.base import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    rope_theta=10_000_000.0,
    source="arXiv:2405.04324; hf",
)
