"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attn, 1 attn per
3-layer block [arXiv:2402.19427; unverified]."""
from repro.configs.base import ArchConfig, LOCAL_ATTN, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,                    # 12 full (rglru, rglru, local) blocks + 2
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,                   # MQA on the local-attn layers
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    window=2048,
    rglru_width=4096,
    tie_embeddings=True,
    source="arXiv:2402.19427; unverified",
)
