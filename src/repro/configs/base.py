"""Architecture and shape configuration dataclasses.

Every assigned architecture is a frozen ``ArchConfig``; every assigned input
shape is a ``ShapeConfig``.  The dry-run sweeps the cross product.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer kinds used in ``layer_pattern`` (repeating cycle over the stack).
GLOBAL_ATTN = "global"      # full causal self attention
LOCAL_ATTN = "local"        # sliding-window causal self attention
RGLRU = "rglru"             # RG-LRU recurrent block (Griffin / RecurrentGemma)
SSD = "ssd"                 # Mamba2 state-space-duality mixer
CROSS_ATTN = "cross"        # self-attn + cross-attn to encoder/vision states


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # 'ep'  -> experts sharded over the model axis (needs E % tp == 0)
    # 'tmp' -> all experts on every chip, expert d_ff sharded over model axis
    sharding: str = "ep"
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | hybrid | vlm | audio | moe | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    layer_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)
    window: int = 4096               # local attention window
    attn_softcap: float = 0.0        # gemma2 attention logit softcap
    final_softcap: float = 0.0       # gemma2 final logit softcap
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    # SSM (mamba2) params
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # RG-LRU params
    rglru_width: int = 0             # 0 -> d_model
    # encoder/decoder (whisper) — decoder uses num_layers
    encoder_layers: int = 0
    # cross-attn context (vision/audio frontend stub)
    context_len: int = 0             # number of frontend embedding tokens
    context_dim: int = 0             # frontend embedding dim (0 -> d_model)
    tie_embeddings: bool = False
    post_norms: bool = False         # gemma2 sandwich norms
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def padded_vocab(self, multiple: int = 256) -> int:
        return _round_up(self.vocab_size, multiple)

    @property
    def is_attention_free(self) -> bool:
        return all(k in (RGLRU, SSD) for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer is full (global/cross) attention -> can run 500k."""
        return all(k in (RGLRU, SSD, LOCAL_ATTN) for k in self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate total parameter count (used for 6ND MODEL_FLOPS)."""
        hd = self.resolved_head_dim
        d = self.d_model
        per_layer = 0
        n_pattern = len(self.layer_pattern)
        for kind in self.layer_pattern:
            p = 2 * d  # two norms
            if kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN):
                p += d * self.num_heads * hd           # q
                p += 2 * d * self.num_kv_heads * hd    # k, v
                p += self.num_heads * hd * d           # o
                if kind == CROSS_ATTN:
                    p *= 2  # extra cross-attention projections
            elif kind == RGLRU:
                w = self.rglru_width or d
                p += 2 * d * w + w * d   # in (x,gate) + out proj
                p += 3 * w               # recurrent gates (a, input gate, diag)
                p += 2 * w * self.window // self.window  # conv-ish, negligible
            elif kind == SSD:
                dinner = self.ssm_expand * d
                nheads = dinner // self.ssm_headdim
                p += d * (2 * dinner + 2 * self.ssm_state + nheads)  # in_proj
                p += dinner * d                                       # out_proj
                p += dinner + 2 * self.ssm_state                      # conv/dt
            if self.moe is not None:
                p += d * self.moe.num_experts                         # router
                p += self.moe.num_experts * 3 * d * self.d_ff         # experts
            elif kind != SSD or self.d_ff:
                p += 3 * d * self.d_ff                                # swiglu
            per_layer += p
        total = self.num_layers * per_layer // n_pattern * n_pattern
        # handle non-divisible stacks: scale per-layer average
        total = round(self.num_layers * per_layer / n_pattern)
        total += self.padded_vocab() * d            # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab() * d        # lm head
        if self.encoder_layers:
            enc_per = 2 * d + 2 * (d * self.num_heads * hd
                                   + d * self.num_kv_heads * hd) // 1
            enc_per += self.num_heads * hd * d + 3 * d * self.d_ff
            total += self.encoder_layers * enc_per
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        expert_p = self.num_layers * self.moe.num_experts * 3 * self.d_model * self.d_ff
        active_expert_p = self.num_layers * self.moe.top_k * 3 * self.d_model * self.d_ff
        return int(full - expert_p + active_expert_p)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 * len(self.layer_pattern)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            window=64,
            context_len=min(self.context_len, 16) if self.context_len else 0,
            context_dim=64 if self.context_dim else 0,
            encoder_layers=min(self.encoder_layers, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32,
            rglru_width=128 if self.rglru_width else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(self.moe, num_experts=4, top_k=2)
            kw["d_ff"] = 64
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(arch: ArchConfig):
    """Shapes that are well-defined for this arch; others are recorded SKIPs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.sub_quadratic:
        out.append(LONG_500K)
    return out


@dataclass(frozen=True)
class TrainHParams:
    """Run-level hyper-parameters (config system for the launcher)."""
    schedule: str = "oases"          # megatron | wang | merak | oases | fused
    fine_remat: bool = True          # §3.2 fine-grained recomputation
    use_planner: bool = False        # per-layer TMP degrees from the ILP
    # execution layout: auto (follow the mesh/degrees) | 1d (flatten a
    # multi-axis model group) | 2d.  The planner's SEARCH space is chosen
    # separately via plan(layout=...).
    tmp_layout: str = "auto"
    split: int = 2                   # sub-batch split factor (paper: 2)
    seq_parallel: bool = False       # beyond-paper: AG/RS sequence-parallel TMP
    seq_shard: int = 1               # ring-attention sequence shards (1 = off;
    #                                  must equal the mesh model-group size)
    remat: bool = True
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    zero1: bool = True
    grad_compress: bool = False       # int8 + error feedback on cross-pod axis
    microbatch: int = 0               # 0 = no accumulation; on a pipeline
    #                                   mesh this is the 1F1B microbatch
    #                                   count (0 = auto ~2*pp*v)
    virtual_stages: int = 1           # interleaved-1F1B chunks per device
    use_pallas: bool = False          # swap in TPU Pallas kernels
    loss_chunk: int = 512             # chunked vocab-parallel xent seq chunk

    def __post_init__(self):
        # validate at construction: an unknown schedule string used to
        # fall silently through the effective_split/TmpCtx branches to
        # megatron-like behaviour (core/plan.py names the valid set)
        from repro.core.plan import TMP_LAYOUTS, validate_schedule
        validate_schedule(self.schedule)
        if self.tmp_layout not in TMP_LAYOUTS:
            raise ValueError(
                f"unknown tmp_layout {self.tmp_layout!r}: valid layouts "
                f"are {', '.join(TMP_LAYOUTS)}")
        s = self.seq_shard
        if not isinstance(s, int) or isinstance(s, bool) or s < 1 \
                or s & (s - 1):
            raise ValueError(
                f"bad seq_shard {s!r}: ring-attention sequence shards "
                f"must be a positive power-of-two int (1 = off)")
