from repro.configs.base import (
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    TrainHParams,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    applicable_shapes,
)

__all__ = [
    "ArchConfig",
    "MoEConfig",
    "ShapeConfig",
    "TrainHParams",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "applicable_shapes",
]
