"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, applicable_shapes
from repro.configs import (
    internlm2_20b,
    granite_8b,
    internlm2_1_8b,
    gemma2_9b,
    recurrentgemma_9b,
    llama32_vision_11b,
    whisper_small,
    moonshot_16b_a3b,
    granite_moe_3b,
    mamba2_130m,
    gpt_oases,
)

_ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        internlm2_20b,
        granite_8b,
        internlm2_1_8b,
        gemma2_9b,
        recurrentgemma_9b,
        llama32_vision_11b,
        whisper_small,
        moonshot_16b_a3b,
        granite_moe_3b,
        mamba2_130m,
    )
}

# The paper's own models are addressable too (benchmarks use them).
for _k, (_cfg, *_rest) in {**gpt_oases.PAPER_TABLE4, **gpt_oases.PAPER_TABLE5}.items():
    _ARCHS[_cfg.name] = _cfg
for _cfg in gpt_oases.SERVING_MODELS.values():
    _ARCHS[_cfg.name] = _cfg

ASSIGNED = [
    "internlm2-20b",
    "granite-8b",
    "internlm2-1.8b",
    "gemma2-9b",
    "recurrentgemma-9b",
    "llama-3.2-vision-11b",
    "whisper-small",
    "moonshot-v1-16b-a3b",
    "granite-moe-3b-a800m",
    "mamba2-130m",
]


def get_config(name: str) -> ArchConfig:
    try:
        return _ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}") from None


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def list_archs():
    return list(ASSIGNED)


def all_cells():
    """All 40 assigned (arch x shape) cells; skipped cells flagged."""
    cells = []
    for a in ASSIGNED:
        cfg = get_config(a)
        app = {s.name for s in applicable_shapes(cfg)}
        for sname, shape in SHAPES.items():
            cells.append((cfg, shape, sname in app))
    return cells
