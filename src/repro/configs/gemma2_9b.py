"""gemma2-9b — local/global alternating attention + logit softcaps
[arXiv:2408.00118; hf]."""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, LOCAL_ATTN

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=(LOCAL_ATTN, GLOBAL_ATTN),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    post_norms=True,
    source="arXiv:2408.00118; hf",
)
