"""The paper's own model table (Appendix B, Tables 4–5), used by the
benchmark harness to reproduce Figures 2/4/5 and Tables 2/3/6.

The end-to-end models are decoder-only transformers denoted H<hidden>-L<layers>;
seq_len 1024; 32 GPUs. GPT-18.4B / GPT-39.1B are the PMP experiments.
"""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, ShapeConfig


def _gpt(name, hidden, layers, heads):
    return ArchConfig(
        name=name,
        family="dense",
        num_layers=layers,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,           # paper models are MHA
        d_ff=4 * hidden,
        vocab_size=50304,             # GPT-2 vocab padded
        layer_pattern=(GLOBAL_ATTN,),
        source="Oases paper, Appendix B Table 4/5",
    )


# Table 4: (hidden, layers, heads, TMP, DP, global batch)
PAPER_TABLE4 = {
    "gpt-h1024": (_gpt("gpt-h1024", 1024, 24, 16), 2, 16, 256),
    "gpt-h2048": (_gpt("gpt-h2048", 2048, 24, 32), 4, 8, 128),
    "gpt-h3072": (_gpt("gpt-h3072", 3072, 24, 48), 4, 8, 32),
    "gpt-h4096": (_gpt("gpt-h4096", 4096, 16, 64), 4, 8, 32),
    "gpt-h6144": (_gpt("gpt-h6144", 6144, 16, 96), 8, 4, 8),
    "gpt-h8192": (_gpt("gpt-h8192", 8192, 8, 128), 8, 4, 8),
    "gpt-h12288": (_gpt("gpt-h12288", 12288, 4, 192), 8, 4, 8),
}

# Table 5: complete-model PMP experiments.
PAPER_TABLE5 = {
    "gpt-18.4b": (_gpt("gpt-18.4b", 6144, 40, 48), 4, 4, 2),   # (cfg, PMP, TMP, DP)
    "gpt-39.1b": (_gpt("gpt-39.1b", 8192, 48, 64), 4, 8, 1),
}

# Serving-path fixtures (not in the paper's tables): a deep decode target
# whose per-layer collective latency floor dominates the step on commodity
# links, and the small draft model the speculative-decoding planner weighs
# against it (tests/test_planner_golden.py pins the spec_k choices these
# produce per cluster fixture).
SERVING_MODELS = {
    "gpt-serve-h4096": _gpt("gpt-serve-h4096", 4096, 64, 32),
    "gpt-draft-h2048": _gpt("gpt-draft-h2048", 2048, 12, 16),
}

PAPER_SEQ_LEN = 1024


def paper_shape(global_batch: int) -> ShapeConfig:
    return ShapeConfig(f"paper_b{global_batch}", PAPER_SEQ_LEN, global_batch, "train")
