"""moonshot-v1-16b-a3b (Moonlight) — MoE 64 experts top-6, expert-parallel
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,                  # MHA
    d_ff=1408,                        # per-expert FFN width
    vocab_size=163840,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    moe=MoEConfig(num_experts=64, top_k=6, sharding="ep"),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
