"""llama-3.2-vision-11b — text backbone with cross-attn image layers every
5th layer. The vision tower is a STUB: input_specs() supplies precomputed
patch embeddings [hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ArchConfig, CROSS_ATTN, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN, GLOBAL_ATTN, GLOBAL_ATTN, GLOBAL_ATTN, CROSS_ATTN),
    rope_theta=500_000.0,
    context_len=6404,                 # 4 tiles x 1601 patches (stubbed frontend)
    context_dim=4096,                 # already projected to d_model
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
