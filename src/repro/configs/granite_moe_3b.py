"""granite-moe-3b-a800m — 40 experts top-8; experts TMP-sharded (40 % 16 != 0
so EP over the 16-way model axis is impossible — see DESIGN.md)
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ArchConfig, GLOBAL_ATTN, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                         # per-expert FFN width
    vocab_size=49155,
    head_dim=64,
    layer_pattern=(GLOBAL_ATTN,),
    moe=MoEConfig(num_experts=40, top_k=8, sharding="tmp"),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
