"""whisper-small — encoder-decoder; conv frontend is a STUB (precomputed
frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig, CROSS_ATTN

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,                    # decoder layers (every layer cross-attends)
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,                  # MHA
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    layer_pattern=(CROSS_ATTN,),
    context_len=1500,                 # 30 s of audio at 50 Hz after conv stub
    context_dim=768,
    source="arXiv:2212.04356; unverified",
)
