"""mamba2-130m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig, SSD

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                           # attention-free, no separate FFN
    vocab_size=50280,
    layer_pattern=(SSD,),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
