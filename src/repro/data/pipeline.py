"""Deterministic synthetic LM data pipeline.

Production-shaped: host-sharded (each host materializes only its shard),
seeded per (step, host) so a restarted/elastic worker can resume mid-stream
without replay, with double-buffered prefetch and optional sequence packing.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.axes import batch_pspec, mesh_info


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 1234
    microbatch: int = 0          # reshape to [n, B/n, ...] when > 1
    pack: bool = True            # synth docs packed to seq_len with EOS
    eos_id: int = 2


def _host_tokens(cfg: DataConfig, step: int, start: int, count: int):
    """Deterministic tokens for rows [start, start+count) of global batch."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, start]))
    toks = rng.integers(3, cfg.vocab_size, size=(count, cfg.seq_len + 1),
                        dtype=np.int32)
    if cfg.pack:
        # synthetic doc boundaries every ~512 tokens
        doc_len = rng.integers(256, 1024)
        toks[:, ::max(int(doc_len), 1)] = cfg.eos_id
    return toks


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Whole global batch on one host (single-host container)."""
    toks = _host_tokens(cfg, step, 0, cfg.global_batch)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.microbatch > 1:
        n = cfg.microbatch
        batch = {k: v.reshape(n, cfg.global_batch // n, cfg.seq_len)
                 for k, v in batch.items()}
    return batch


class Prefetcher:
    """Double-buffered background prefetch onto device."""

    def __init__(self, cfg: DataConfig, mesh, start_step: int = 0,
                 ctx_shape=None, depth: int = 2):
        self.cfg = cfg
        self.mesh = mesh
        info = mesh_info(mesh)
        micro_b = cfg.global_batch // max(cfg.microbatch, 1)
        bp = batch_pspec(info, micro_b)
        entries = ((None,) if cfg.microbatch > 1 else ()) + tuple(bp)
        self.sharding = NamedSharding(mesh, P(*entries))
        self.ctx_shape = ctx_shape
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        # host-side generation only — the device_put happens on the consumer
        # thread (concurrent multi-threaded dispatch can deadlock XLA:CPU's
        # intra-process collective rendezvous; on TPU pods the transfer would
        # be a separate DMA engine anyway)
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            if self.ctx_shape is not None:
                rng = np.random.default_rng([self.cfg.seed, step, 7])
                ctx = rng.standard_normal(self.ctx_shape).astype(np.float32)
                if self.cfg.microbatch > 1:
                    n = self.cfg.microbatch
                    ctx = ctx.reshape((n, ctx.shape[0] // n) + ctx.shape[1:])
                batch["ctx"] = ctx * 0.02
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    step += 1
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        dev = {k: jax.device_put(v, self.sharding) for k, v in batch.items()}
        return step, dev

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
