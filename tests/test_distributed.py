"""Multi-device integration tests.  Each spawns a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the in-process tests
must keep the real 1-device topology)."""
import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

SCRIPTS = os.path.join(os.path.dirname(__file__), "_scripts")


def _run(name, timeout=900):
    p = subprocess.run([sys.executable, os.path.join(SCRIPTS, name)],
                       capture_output=True, text=True, timeout=timeout,
                       env=subprocess_env())
    out = p.stdout + p.stderr
    assert p.returncode == 0, out[-3000:]
    lines = [l for l in p.stdout.splitlines()
             if l.startswith(("PASS", "FAIL"))]
    assert lines, out[-2000:]
    bad = [l for l in lines if l.startswith("FAIL")]
    assert not bad, "\n".join(lines)
    return lines


@pytest.mark.slow
def test_tmp_equivalence_and_schedules():
    lines = _run("equivalence.py")
    assert len(lines) >= 8          # 7 archs + schedule agreement


@pytest.mark.slow
def test_fine_remat_removes_recompute_collectives():
    _run("remat_counts.py")


@pytest.mark.slow
def test_fault_tolerant_restart():
    _run("ft_restart.py")


@pytest.mark.slow
def test_elastic_remesh_resume():
    _run("elastic.py")


@pytest.mark.slow
def test_sequence_parallel_equivalence():
    lines = _run("sp_equivalence.py")
    assert len(lines) >= 5
