"""Multi-device integration tests — the first-class ``multidevice`` tier.

Each test spawns a subprocess from tests/_scripts/ (all of which import
the shared ``runner`` harness, which sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax loads; the
in-process tests must keep the real 1-device topology).  Run the tier with
``pytest -m multidevice``; the tests are also marked ``slow`` so the
default fast loop can deselect them.
"""
import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

SCRIPTS = os.path.join(os.path.dirname(__file__), "_scripts")

multidevice = pytest.mark.multidevice


def _run(name, timeout=900):
    p = subprocess.run([sys.executable, os.path.join(SCRIPTS, name)],
                       capture_output=True, text=True, timeout=timeout,
                       env=subprocess_env())
    out = p.stdout + p.stderr
    assert p.returncode == 0, out[-3000:]
    lines = [ln for ln in p.stdout.splitlines()
             if ln.startswith(("PASS", "FAIL"))]
    assert lines, out[-2000:]
    bad = [ln for ln in lines if ln.startswith("FAIL")]
    assert not bad, "\n".join(lines)
    return lines


@multidevice
@pytest.mark.slow
def test_tmp_equivalence_and_schedules():
    lines = _run("equivalence.py")
    assert len(lines) >= 8          # 7 archs + schedule agreement


@multidevice
@pytest.mark.slow
def test_2d_hybrid_equivalence():
    """2x2 model-mesh 2D forward+grad vs the single-device oracle, plus
    mixed 1D/2D planner degrees on the factored mesh (PR acceptance)."""
    lines = _run("equivalence_2d.py", timeout=1800)
    assert len(lines) >= 26         # 7 archs x 3 schedules + plan cases


@multidevice
@pytest.mark.slow
def test_plan_equivalence():
    """Executable-ParallelPlan tier: heterogeneous per-layer
    (degree, schedule) strategies — mixed schedules at mesh-uniform
    degrees on a plain mesh, MoE interplay, and mixed (degree, schedule)
    plans on the factored mesh — are loss- AND grad-identical to the
    1-device oracle (PR acceptance)."""
    lines = _run("plan_equivalence.py", timeout=1800)
    assert len(lines) >= 8


@multidevice
@pytest.mark.slow
def test_fine_remat_removes_recompute_collectives():
    _run("remat_counts.py")


@multidevice
@pytest.mark.slow
def test_fault_tolerant_restart():
    _run("ft_restart.py")


@multidevice
@pytest.mark.slow
def test_elastic_remesh_resume():
    _run("elastic.py")


@multidevice
@pytest.mark.slow
def test_elastic_replan():
    """Online elasticity (PR acceptance): injected mid-run host loss
    triggers ILP replanning + in-memory relayout with loss continuity
    against an uninterrupted oracle; a link-bandwidth fault replans
    without chip loss; a corrupted checkpoint shard resumes from the
    previous intact checkpoint."""
    lines = _run("elastic_replan.py", timeout=1800)
    assert len(lines) >= 4


@multidevice
@pytest.mark.slow
def test_telemetry_end_to_end():
    """Telemetry tier (PR acceptance): a short TMP training run with a
    JSONL sink yields a schema-valid trace with step-time histograms,
    async-checkpoint write latency, the overlap probe's per-layer-group
    measured-vs-modeled exposed-communication events, and the enriched
    per-host heartbeat the straggler localizer consumes."""
    lines = _run("telemetry_run.py")
    assert len(lines) >= 8


@multidevice
@pytest.mark.slow
def test_sequence_parallel_equivalence():
    lines = _run("sp_equivalence.py")
    assert len(lines) >= 5


@multidevice
@pytest.mark.slow
def test_ring_attention_equivalence():
    """Ring attention (PR acceptance, DESIGN.md §12): the 8-way KV-ring
    kernel matches the 1-device oracle forward AND backward (custom-VJP
    reverse ring) across fp32/bf16 x causal/sliding-window/GQA/softcap
    and uneven sequence tiles; the stacked and grouped (mixed per-layer
    seqs) model paths are loss/grad-identical to the unsharded model;
    unsatisfiable shard factors raise instead of silently degrading."""
    lines = _run("ring_equivalence.py", timeout=1800)
    assert len(lines) >= 18


@multidevice
@pytest.mark.slow
def test_pipeline_equivalence():
    """Interleaved-1F1B PP x TMP vs the single-device oracle: pp in {2,4}
    x tmp in {1,2} x {megatron,oases,fused}, plus virtual stages, a second
    arch family and PP x 2D hybrid (PR acceptance)."""
    lines = _run("pipeline_equivalence.py", timeout=1800)
    assert len(lines) >= 14


@multidevice
@pytest.mark.slow
def test_serving_equivalence():
    """Sharded greedy decode through the continuous-batching engine is
    token-identical to the single-device oracle: pp in {1,2} x tmp in
    {1,2} x {megatron,oases,fused}, plus the 2D hybrid decode layout,
    explicit micro-group counts, an indivisible slot count, gemma2,
    and the serving-at-scale grid — paged KV (incl. the pp decode
    stream), prefix reuse with COW, speculative decoding vs the
    undrafted oracle, and the combined paged+prefix+spec path
    (PR acceptance)."""
    lines = _run("serving_equivalence.py", timeout=1800)
    assert len(lines) >= 30
