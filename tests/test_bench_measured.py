"""Measured bench tier, calibration cache, drift/ranking gate, autotune.

The measured tier (ROADMAP item 3) exists to stop the modeled perf gate
from grading its own homework: these tests pin the gate logic itself
(ranking agreement/disagreement on checked-in fixtures, dry-run
provenance, measured-section tolerance), the calibration plumbing the
planner entry points now use by default, and the timing hygiene the
measurements rely on (perf_counter, blocked warm-ups, tuner caching).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)        # the benchmarks package

from benchmarks import bench_diff                           # noqa: E402
from benchmarks import measured as measured_mod             # noqa: E402

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
AGREE = os.path.join(FIXTURES, "bench_ranking_agree.json")
DISAGREE = os.path.join(FIXTURES, "bench_ranking_disagree.json")


def _load(path):
    with open(path) as f:
        return json.load(f)


# -------------------------------------------------------------------------
# ranking gate (bench_diff --ranking)
# -------------------------------------------------------------------------
def test_ranking_agreeing_fixture_passes():
    assert bench_diff.check_ranking(_load(AGREE), margin=0.25) == []


def test_ranking_disagreeing_fixture_fails():
    errors = bench_diff.check_ranking(_load(DISAGREE), margin=0.25)
    assert errors, "a 2x modeled-vs-measured order flip must be flagged"
    assert any("ranking flip" in e for e in errors)


def test_ranking_margin_turns_flips_into_ties():
    # at an absurd margin every pair is a tie — no ordering signal left
    assert bench_diff.check_ranking(_load(DISAGREE), margin=10.0) == []


def test_ranking_requires_measured_points():
    errors = bench_diff.check_ranking({"dry_run": True}, margin=0.25)
    assert errors and "no measured section" in errors[0]
    errors = bench_diff.check_ranking(
        {"measured": {"points": [{"key": "only-one",
                                  "modeled_tok_s": 1.0,
                                  "measured_tok_s": 1.0}]}}, margin=0.25)
    assert errors and "at least 2" in errors[0]


def test_ranking_cli_exit_codes():
    env = dict(os.environ)
    rc_ok = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "bench_diff.py"),
         "--ranking", AGREE], capture_output=True, env=env).returncode
    rc_bad = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "bench_diff.py"),
         "--ranking", DISAGREE], capture_output=True, env=env).returncode
    assert rc_ok == 0 and rc_bad != 0


# -------------------------------------------------------------------------
# two-file diff: provenance + measured tolerance
# -------------------------------------------------------------------------
def test_provenance_mismatch_fails_loudly():
    base = {"dry_run": False, "tokens_per_s": {"m": 1.0}}
    cand = {"dry_run": True, "tokens_per_s": {"m": 1.0}}
    errors = bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                             modeled_only=False)
    assert errors and "provenance mismatch" in errors[0]
    # the modeled smoke explicitly opts out of the provenance check
    assert bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                           modeled_only=True) == []


def test_modeled_only_skips_measured_section():
    base = _load(AGREE)
    cand = {"dry_run": True, "tag": "x", "time": 1}
    assert bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                           modeled_only=True) == []


def test_measured_section_diffs_under_loose_tolerance():
    base = _load(AGREE)
    cand = json.loads(json.dumps(base))
    # 30% wall-clock drift: within the 50% measured tolerance, far
    # outside the 2% modeled one
    cand["measured"]["points"][0]["measured_tok_s"] *= 1.3
    # host/calibration metadata legitimately differs and is never diffed
    cand["measured"]["host"]["hostname"] = "elsewhere"
    cand["measured"]["hw_calibrated"]["peak_flops"] = 7e13
    assert bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                           modeled_only=False) == []
    cand["measured"]["points"][0]["measured_tok_s"] *= 1.5   # now ~2x
    errors = bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                             modeled_only=False)
    assert errors and "/measured/" in errors[0]


def test_measured_present_in_only_one_file_is_an_error():
    base = _load(AGREE)
    cand = {"dry_run": False}
    errors = bench_diff.diff(base, cand, tol=0.02, measured_tol=0.5,
                             modeled_only=False)
    assert any("only one file" in e for e in errors)


# -------------------------------------------------------------------------
# measured section shaping
# -------------------------------------------------------------------------
def test_build_section_shapes_and_rounds():
    raw = {
        "hw": {"n_chips": 8, "peak_flops": 5.1234567e10},
        "iters": 2,
        "points": [{
            "key": "k", "model": "m", "seq": 128, "batch": 8, "tmp": 4,
            "schedule": "oases", "measured_s": 1.23456,
            "measured_tok_s": 829.4321, "modeled_s": 0.0841234,
            "modeled_tok_s": 12163.4567,
        }],
    }
    sec = measured_mod.build_section(raw, host={"hostname": "h"})
    assert sec["host"] == {"hostname": "h"}
    assert sec["iters"] == 2
    p = sec["points"][0]
    assert p["measured_tok_s"] == 829.4
    assert p["modeled_tok_s"] == 12163.5
    assert p["measured_ms"] == 1234.56
    assert p["schedule"] == "oases"


# -------------------------------------------------------------------------
# calibration: override precedence + per-host cache
# -------------------------------------------------------------------------
def test_from_measurements_overrides_beat_measurements():
    from repro.core.planner.costmodel import HWConfig
    hw = HWConfig.from_measurements(repeats=1, n_chips=99,
                                    peak_flops=123.0)
    assert hw.n_chips == 99
    assert hw.peak_flops == 123.0
    assert hw.hbm_bw > 0          # still measured
    assert hw.mxu_base_eff == 1.0  # measurements already include MXU eff


def test_measure_fields_clamps_node_size():
    from repro.core.planner.costmodel import HWConfig
    hw = HWConfig.from_measurements(repeats=1, n_chips=1)
    assert hw.node_size <= hw.n_chips


def test_calibrated_hw_cache_roundtrip(tmp_path, monkeypatch):
    from repro.core.planner import calibrate
    from repro.core.planner.costmodel import HWConfig
    monkeypatch.setenv("REPRO_CAL_CACHE", str(tmp_path))
    monkeypatch.delenv("REPRO_NO_CALIBRATE", raising=False)
    monkeypatch.setattr(calibrate, "_MEM_CACHE", {})
    hw1 = calibrate.calibrated_hw(repeats=1)
    assert os.path.exists(calibrate.cache_path())

    # second call must come from cache: measuring again is an error
    def boom(**_kw):
        raise AssertionError("measure_fields re-ran despite a warm cache")
    monkeypatch.setattr(HWConfig, "measure_fields", classmethod(
        lambda cls, **kw: boom(**kw)))
    hw2 = calibrate.calibrated_hw(repeats=1)
    assert hw2.peak_flops == hw1.peak_flops

    # overrides are applied at load time, on top of the cached fields
    hw3 = calibrate.calibrated_hw(repeats=1, n_chips=64, link_bw=42.0)
    assert hw3.n_chips == 64 and hw3.link_bw == 42.0
    assert hw3.peak_flops == hw1.peak_flops

    # a fresh process (empty mem cache) hits the disk cache
    monkeypatch.setattr(calibrate, "_MEM_CACHE", {})
    hw4 = calibrate.calibrated_hw(repeats=1)
    assert hw4.peak_flops == hw1.peak_flops


def test_calibrated_hw_env_disable(monkeypatch):
    from repro.core.planner import calibrate
    monkeypatch.setenv("REPRO_NO_CALIBRATE", "1")
    hw = calibrate.calibrated_hw(n_chips=16)
    from repro.core.planner.costmodel import HWConfig
    assert hw.peak_flops == HWConfig(n_chips=16).peak_flops


def test_calibrated_hw_clamps_node_size_to_cluster(tmp_path, monkeypatch):
    from repro.core.planner import calibrate
    monkeypatch.setenv("REPRO_CAL_CACHE", str(tmp_path))
    monkeypatch.delenv("REPRO_NO_CALIBRATE", raising=False)
    monkeypatch.setattr(calibrate, "_MEM_CACHE", {})
    hw = calibrate.calibrated_hw(repeats=1, n_chips=1)
    assert hw.node_size == 1


# -------------------------------------------------------------------------
# timing hygiene: hot paths must use the monotonic clock
# -------------------------------------------------------------------------
@pytest.mark.parametrize("modname,fn_name", [
    ("repro.runtime.trainer", "train"),
    ("repro.serving.engine", "run_until_drained"),
])
def test_hot_path_timers_use_perf_counter(modname, fn_name):
    import importlib
    import inspect
    mod = importlib.import_module(modname)
    src = inspect.getsource(mod)
    # the step/drain timers moved off the wall clock; heartbeat and
    # checkpoint timestamps legitimately keep time.time()
    fn_src = [s for s in src.split("def ") if s.startswith(fn_name + "(")]
    assert fn_src, f"{fn_name} not found in {modname}"
    assert "time.perf_counter()" in fn_src[0]


def test_measure_harness_uses_perf_counter():
    with open(os.path.join(ROOT, "benchmarks", "_measure.py")) as f:
        src = f.read()
    body = src.split("def measure(")[1].split("\ndef ")[0]
    assert "time.perf_counter()" in body
    assert "time.time()" not in body


def test_microbench_warmup_is_blocked():
    import inspect
    from repro.core.planner.costmodel import HWConfig
    src = inspect.getsource(HWConfig.measure_fields.__func__)
    # the warm-up dispatch must be synced before the timed loop starts
    assert "block_until_ready" in src.split("perf_counter")[0]


# -------------------------------------------------------------------------
# Pallas block-size autotuning
# -------------------------------------------------------------------------
def test_autotune_heuristic_on_cpu(tmp_path, monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tiles.json"))
    monkeypatch.setattr(autotune, "_MEM_CACHE", {})
    blocks = autotune.tuned_blocks(200, 300, 150, platform="cpu")
    assert blocks == (128, 128, 300)     # clipped heuristic, no timing
    assert os.path.exists(str(tmp_path / "tiles.json"))


def test_autotune_cache_hit_skips_search(tmp_path, monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tiles.json"))
    monkeypatch.setattr(autotune, "_MEM_CACHE", {})
    first = autotune.tuned_blocks(512, 512, 512, platform="cpu")

    def boom(*_a, **_k):
        raise AssertionError("candidate timing ran despite a warm cache")
    monkeypatch.setattr(autotune, "_time_candidate", boom)
    monkeypatch.setattr(autotune, "candidates", boom)
    # memory cache
    assert autotune.tuned_blocks(512, 512, 512, platform="cpu") == first
    # disk cache (fresh process simulated by clearing the mem cache)
    monkeypatch.setattr(autotune, "_MEM_CACHE", {})
    assert autotune.tuned_blocks(512, 512, 512, platform="cpu") == first


def test_autotune_candidates_respect_vmem_budget():
    from repro.kernels import autotune
    for bm, bn, bk in autotune.candidates(4096, 4096, 4096):
        assert autotune._vmem_bytes(bm, bn, bk, 4) \
            <= autotune.VMEM_BUDGET_BYTES


def test_tile_matmul_autotuned_matches_dot(tmp_path, monkeypatch):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import autotune
    from repro.kernels.collective_matmul import pallas_tile_matmul
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tiles.json"))
    monkeypatch.setattr(autotune, "_MEM_CACHE", {})
    x = jax.random.normal(jax.random.PRNGKey(0), (200, 300), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (300, 150), jnp.float32)
    got = pallas_tile_matmul(x, w)       # blocks=None -> tuner
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=2e-5, atol=2e-4)


def test_tile_matmul_explicit_blocks_bypass_tuner(monkeypatch):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import autotune
    from repro.kernels.collective_matmul import pallas_tile_matmul

    def boom(*_a, **_k):
        raise AssertionError("tuner consulted despite explicit blocks")
    monkeypatch.setattr(autotune, "tuned_blocks", boom)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 32), jnp.float32)
    got = pallas_tile_matmul(x, w, block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=2e-5, atol=2e-4)


# -------------------------------------------------------------------------
# measured tier end-to-end (8-virtual-device subprocess)
# -------------------------------------------------------------------------
@pytest.mark.multidevice
def test_measured_tier_one_point_end_to_end():
    from tests.conftest import subprocess_env
    script = os.path.join(ROOT, "benchmarks", "_measure.py")
    p = subprocess.run(
        [sys.executable, script, "--tier", "measured", "--points", "1",
         "--iters", "1"],
        capture_output=True, text=True, timeout=900, env=subprocess_env())
    assert p.returncode == 0, p.stderr[-2000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["hw"]["n_chips"] == 8
    assert len(out["points"]) == 1
    pt = out["points"][0]
    assert pt["measured_tok_s"] > 0
    assert pt["modeled_tok_s"] > 0
    assert pt["schedule"] in {"megatron", "wang", "oases", "fused"}
    # and the section builder accepts the real subprocess output
    sec = measured_mod.build_section(out, host={"hostname": "test"})
    assert sec["points"][0]["measured_tok_s"] > 0
