"""Shared harness for the multi-device subprocess scripts.

Every script in this directory runs as ``python tests/_scripts/<name>.py``
inside a fresh process and talks to its parent test through PASS/FAIL
lines on stdout.  This module centralizes the boilerplate they used to
re-implement: the virtual-device environment (which MUST be configured
before the first jax import — hence ``import runner`` is each script's
first statement), mesh construction, reduced test configs, loss/grad
evaluation under ``shard_map``, and the PASS/FAIL reporting protocol.

Usage:

    import runner                      # sets XLA_FLAGS, first import
    loss, grads = runner.train_loss_and_grads("gemma2-9b", runner.mesh(2, 4))
    runner.check("my-case", grads, ref_grads, tol=5e-3)
"""
import os

N_DEVICES = int(os.environ.get("OASES_TEST_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEVICES}")
# ^ before any jax import: jax locks the device count on first init.

import dataclasses  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import compat                      # noqa: E402
from repro.configs.base import TrainHParams        # noqa: E402
from repro.configs.registry import get_config      # noqa: E402
from repro.models import lm                        # noqa: E402
from repro.models import params as prm             # noqa: E402

_FAILED = [0]


# --------------------------------------------------------------------------
# environment / mesh
# --------------------------------------------------------------------------
def mesh(*shape, axes=None):
    """Mesh over the virtual devices; default axis names by rank:
    1 -> ('model',), 2 -> ('data','model'), 3 -> ('data','model_x','model_y')."""
    if axes is None:
        axes = {1: ("model",), 2: ("data", "model"),
                3: ("data", "model_x", "model_y")}[len(shape)]
    return jax.make_mesh(tuple(shape), tuple(axes))


def factored_mesh(data=1, t=(2, 2, 2)):
    names = ("data",) + tuple(f"t{i+1}" for i in range(len(t)))
    return jax.make_mesh((data,) + tuple(t), names)


def reduced_config(arch: str, *, exact_moe: bool = True):
    """The tiny same-family fp32 config every equivalence script uses.
    ``exact_moe``: no-drop routing + zero aux weight so MoE losses are
    bitwise comparable across meshes."""
    cfg = get_config(arch).reduced().replace(dtype="float32")
    if exact_moe and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=100.0, router_aux_weight=0.0))
    return cfg


def make_batch(cfg, batch: int, seq: int, seed: int = 42):
    k = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                        jnp.int32),
           "labels": jax.random.randint(k, (batch, seq), 0, cfg.vocab_size,
                                        jnp.int32)}
    if cfg.context_len:
        out["ctx"] = 0.02 * jax.random.normal(
            k, (batch, cfg.context_len, cfg.d_model), jnp.float32)
    return out


# --------------------------------------------------------------------------
# loss/grad evaluation
# --------------------------------------------------------------------------
def flatten(tree):
    return {jax.tree_util.keystr(kp): np.asarray(jax.device_get(v))
            for kp, v in jax.tree_util.tree_flatten_with_path(tree)[0]}


def train_loss_and_grads(arch_or_cfg, msh, hp: TrainHParams = None, *,
                         batch: int = 4, seq: int = 64, degrees=None,
                         schedules=None, seqs=None, seed: int = 0,
                         batch_seed: int = 42,
                         canonical_init: bool = False):
    """(loss, flat-grad dict) of the reduced config on a mesh — the body
    every per-feature script used to duplicate.

    ``canonical_init``: initialize parameters in the canonical STACKED
    layout and relayout into the run's grouped (planner-mode) layout, so
    a per-layer-plan run is value-comparable against the 1-device oracle
    (grouped spec trees flatten in a different order, which would
    otherwise deal different RNG keys per leaf).  Pair with
    :func:`canonical_grads` on the result."""
    cfg = (reduced_config(arch_or_cfg) if isinstance(arch_or_cfg, str)
           else arch_or_cfg)
    hp = hp or TrainHParams()
    loss_fn, specs, _ = lm.build_train_loss(
        cfg, msh, hp, global_batch=batch, seq_len=seq, degrees=degrees,
        schedules=schedules, seqs=seqs)
    if canonical_init and (degrees is not None or schedules is not None
                           or seqs is not None):
        from repro.core.axes import mesh_info
        base_specs = prm.model_specs(cfg, mesh_info(msh), max_pos=seq,
                                     layout=hp.tmp_layout)
        p0 = prm.init_params(base_specs, jax.random.PRNGKey(seed))
        flat = prm.relayout_flat(
            cfg, prm.tree_to_flat(p0), {},
            _layout_meta(cfg, degrees, schedules, hp, seqs))
        p = prm.tree_from_flat(specs, flat)
    else:
        p = prm.init_params(specs, jax.random.PRNGKey(seed))
    b = make_batch(cfg, batch, seq, batch_seed)
    with compat.set_mesh(msh):
        loss = float(jax.jit(loss_fn)(p, b)[0])
        grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(p, b)
    return loss, flatten(grads)


def _layout_meta(cfg, degrees, schedules, hp, seqs=None):
    """The relayout descriptor of a (degrees, schedules, seqs) run —
    mirrors lm._normalize_strategy's grouping promotion.  A uniform
    seq_shard on the stacked layout keeps the stacked flat keys, so it
    needs no relayout; only mixed seqs force the grouped layout."""
    seq_uniform = 1
    if seqs is not None and len(set(seqs)) == 1:
        seq_uniform, seqs = seqs[0], None
    if schedules is not None and len(set(schedules)) == 1:
        schedules = None
    if degrees is None and schedules is None and seqs is None:
        return {}
    degs = list(degrees) if degrees is not None \
        else [None] * cfg.num_layers
    scheds = (list(schedules) if schedules is not None
              else [hp.schedule] * cfg.num_layers)
    meta = {"degrees": degs, "schedules": scheds}
    seq_all = seq_uniform if seq_uniform > 1 \
        else getattr(hp, "seq_shard", 1)
    if seqs is not None:
        meta["seqs"] = list(seqs)
    elif seq_all > 1:
        meta["seqs"] = [seq_all] * cfg.num_layers
    return meta


def canonical_grads(arch_or_cfg, g: dict, *, degrees=None, schedules=None,
                    seqs=None, hp: TrainHParams = None) -> dict:
    """Relayout a grouped run's flat grad dict back into the canonical
    stacked layout for oracle comparison."""
    cfg = (reduced_config(arch_or_cfg) if isinstance(arch_or_cfg, str)
           else arch_or_cfg)
    meta = _layout_meta(cfg, degrees, schedules, hp or TrainHParams(),
                        seqs)
    return prm.relayout_flat(cfg, g, meta, {}) if meta else g


# --------------------------------------------------------------------------
# reporting (consumed by tests/test_distributed.py etc.)
# --------------------------------------------------------------------------
def rel_err(a, b) -> float:
    a = [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(a)]
    b = [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(b)]
    return max(float(np.max(np.abs(x - y)))
               / (float(np.max(np.abs(x))) + 1e-6)
               for x, y in zip(a, b))


def grads_err(g1: dict, g2: dict) -> float:
    return max(float(np.max(np.abs(g1[k] - g2[k])))
               / (float(np.max(np.abs(g1[k]))) + 1e-8) for k in g1)


def match_shapes(g: dict, ref: dict) -> dict:
    """Reshape a flat grad dict onto a reference layout.  Pipeline meshes
    stack layer groups [v, pp, n/S, ...] whose row-major flatten is the
    canonical [n, ...] order, so comparing against the single-device
    oracle is a pure reshape per leaf."""
    return {k: v.reshape(ref[k].shape) for k, v in g.items()}


def report(name: str, ok: bool, detail: str = ""):
    _FAILED[0] += 0 if ok else 1
    print(f"{'PASS' if ok else 'FAIL'} {name}"
          + (f" {detail}" if detail else ""), flush=True)
    return ok


def check(name: str, a, b, tol: float):
    err = rel_err(a, b)
    return report(name, err < tol, f"err={err:.2e}")


def check_close(name: str, x: float, y: float, tol: float):
    return report(name, abs(x - y) < tol, f"diff={abs(x - y):.2e}")


def exit_code() -> int:
    """Optional strict exit: scripts may end with sys.exit(runner.exit_code())
    (the parent asserts on FAIL lines either way)."""
    return 1 if _FAILED[0] else 0
