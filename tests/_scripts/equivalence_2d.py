"""Subprocess body: 2D hybrid-partition equivalence.

Part 1 — uniform 2D: a (data=2, model_x=2, model_y=2) mesh must reproduce
the single-device oracle's loss AND gradients for every layer family and
for the megatron/oases/fused schedules (the per-axis decomposition — entry
proj psum_y, exit psum_x + all-gather_y — is numerically exact).

Part 2 — planner-mode mixed degrees on the factored mesh: every 1D/2D
degree assignment of the same grouping structure must agree (same init),
including transitions between groups whose x/y splits differ — the case
that exposed the pre-PR batch-resharding permutation bug.

Prints PASS/FAIL lines consumed by tests/test_distributed.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

from repro.configs.base import TrainHParams

# ---- part 1: uniform 2D vs single-device oracle --------------------------
# (MoE archs included: their MLP keeps the flattened-group 1D layout while
# attention uses the per-axis decomposition — the interplay needs pinning)
for arch in ["internlm2-1.8b", "gemma2-9b", "recurrentgemma-9b",
             "mamba2-130m", "whisper-small", "moonshot-v1-16b-a3b",
             "granite-moe-3b-a800m"]:
    l1, g1 = runner.train_loss_and_grads(arch, runner.mesh(1, 1))
    for sched in ("oases", "megatron", "fused"):
        l2, g2 = runner.train_loss_and_grads(
            arch, runner.mesh(2, 2, 2), TrainHParams(schedule=sched))
        gerr = runner.grads_err(g1, g2)
        runner.report(f"2d-{arch}-{sched}",
                      abs(l1 - l2) < 2e-4 and gerr < 5e-3,
                      f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")

# ---- part 2: mixed 1D/2D plans on the factored mesh ----------------------
fm = runner.factored_mesh(1, (2, 2, 2))
base_l, base_g = runner.train_loss_and_grads("internlm2-1.8b", fm,
                                             batch=8, degrees=[4, 4])
for degrees in ([2, 2], [8, 8], [(2, 2), (2, 2)], [(2, 4), (2, 4)],
                [(4, 2), (4, 2)], [(1, 2), (1, 2)]):
    ls, g = runner.train_loss_and_grads("internlm2-1.8b", fm,
                                        batch=8, degrees=degrees)
    gerr = runner.grads_err(base_g, g)
    runner.report(f"plan-{degrees}",
                  abs(base_l - ls) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(base_l - ls):.2e} gerr={gerr:.2e}")

m_l, m_g = runner.train_loss_and_grads("internlm2-1.8b", fm,
                                       batch=8, degrees=[2, 4])
for degrees in ([4, 2], [2, 8], [(2, 2), 4], [2, (2, 2)],
                [(2, 2), (4, 2)], [(1, 4), (2, 2)]):
    ls, g = runner.train_loss_and_grads("internlm2-1.8b", fm,
                                        batch=8, degrees=degrees)
    gerr = runner.grads_err(m_g, g)
    runner.report(f"plan-mixed-{degrees}",
                  abs(m_l - ls) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(m_l - ls):.2e} gerr={gerr:.2e}")

# heterogeneous per-layer SCHEDULES live in plan_equivalence.py (the
# executable-ParallelPlan tier) to keep this script inside its budget.
