"""Subprocess body: fine-grained recomputation (§3.2) removes the recompute
collectives — count psums in the grad jaxpr."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.models import lm
from repro.models import params as prm

mesh = runner.mesh(2, 4)
counts = {}
for fine in [False, True]:
    cfg = runner.reduced_config("internlm2-1.8b")
    hp = TrainHParams(schedule="oases", fine_remat=fine)
    fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                       seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    b = {"tokens": jnp.zeros((4, 64), jnp.int32),
         "labels": jnp.zeros((4, 64), jnp.int32)}
    with compat.set_mesh(mesh):
        jx = jax.make_jaxpr(jax.grad(lambda p, b: fn(p, b)[0]))(p, b)
    counts[fine] = str(jx).count("psum")
runner.report("remat-collectives", counts[True] < counts[False],
              f"coarse={counts[False]} fine={counts[True]}")
