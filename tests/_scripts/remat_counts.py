"""Subprocess body: fine-grained recomputation (§3.2) removes the recompute
collectives — count psums in the grad jaxpr."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.models import lm
from repro.models import params as prm

mesh = jax.make_mesh((2, 4), ("data", "model"))
counts = {}
for fine in [False, True]:
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    hp = TrainHParams(schedule="oases", fine_remat=fine)
    fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                       seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    b = {"tokens": jnp.zeros((4, 64), jnp.int32),
         "labels": jnp.zeros((4, 64), jnp.int32)}
    with compat.set_mesh(mesh):
        jx = jax.make_jaxpr(jax.grad(lambda p, b: fn(p, b)[0]))(p, b)
    counts[fine] = str(jx).count("psum")
print(f"coarse={counts[False]} fine={counts[True]}")
print("PASS" if counts[True] < counts[False] else "FAIL", flush=True)
