"""Subprocess body: pipeline-parallel (interleaved 1F1B) equivalence.

On the 8-virtual-device CPU mesh, every pp in {2, 4} x TMP in {1, 2} x
schedule in {megatron, oases, fused} combination must reproduce the
single-device oracle's loss AND gradients: the microbatch injection /
ppermute stage transfer / last-stage masking machinery of
core/pipeline.py is numerically invisible, and the transposed loop is the
correct reverse pipeline.  Also pinned: interleaved virtual stages
(v=2), a second architecture family (gemma2: sandwich norms + softcaps +
local attention), and PP composed with the 2D hybrid TMP layout.

Pipeline grads come back in the stage-sharded [v, pp, n/S, ...] stacking;
``runner.match_shapes`` flattens them onto the oracle layout (row-major
order is the canonical layer order — the same property the elastic
checkpoint reshape relies on).

Prints PASS/FAIL lines consumed by tests/test_distributed.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

from repro.configs.base import TrainHParams

BATCH = 8

# ---- part 1: pp x tmp x schedule grid vs single-device oracle ------------
cfg = runner.reduced_config("internlm2-1.8b").replace(num_layers=4)
l1, g1 = runner.train_loss_and_grads(cfg, runner.mesh(1, 1), batch=BATCH)

for pp in (2, 4):
    for tmp in (1, 2):
        data = 8 // (pp * tmp)
        if data < 1:
            continue
        msh = runner.mesh(pp, data, tmp, axes=("pipe", "data", "model"))
        for sched in ("megatron", "oases", "fused"):
            hp = TrainHParams(schedule=sched, microbatch=2)
            l2, g2 = runner.train_loss_and_grads(cfg, msh, hp, batch=BATCH)
            gerr = runner.grads_err(g1, runner.match_shapes(g2, g1))
            runner.report(f"pp{pp}-tmp{tmp}-{sched}",
                          abs(l1 - l2) < 2e-4 and gerr < 5e-3,
                          f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")

# ---- part 2: interleaved virtual stages (v=2 -> 4 stages on 2 devices) ---
msh = runner.mesh(2, 2, 2, axes=("pipe", "data", "model"))
for n_micro in (2, 4):
    hp = TrainHParams(schedule="oases", microbatch=n_micro, virtual_stages=2)
    l2, g2 = runner.train_loss_and_grads(cfg, msh, hp, batch=BATCH)
    gerr = runner.grads_err(g1, runner.match_shapes(g2, g1))
    runner.report(f"pp2-v2-m{n_micro}",
                  abs(l1 - l2) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")

# ---- part 3: second arch family (gemma2) + PP x 2D hybrid TMP ------------
gcfg = runner.reduced_config("gemma2-9b")       # 4 layers, global/local mix
gl1, gg1 = runner.train_loss_and_grads(gcfg, runner.mesh(1, 1), batch=BATCH)
msh = runner.mesh(2, 2, 2, axes=("pipe", "data", "model"))
for sched in ("oases", "fused"):
    hp = TrainHParams(schedule=sched, microbatch=2)
    l2, g2 = runner.train_loss_and_grads(gcfg, msh, hp, batch=BATCH)
    gerr = runner.grads_err(gg1, runner.match_shapes(g2, gg1))
    runner.report(f"gemma2-pp2-{sched}",
                  abs(gl1 - l2) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(gl1 - l2):.2e} gerr={gerr:.2e}")

msh2d = runner.mesh(2, 1, 2, 2, axes=("pipe", "data", "model_x", "model_y"))
hp = TrainHParams(schedule="oases", microbatch=2)
l2, g2 = runner.train_loss_and_grads(cfg, msh2d, hp, batch=BATCH)
gerr = runner.grads_err(g1, runner.match_shapes(g2, g1))
runner.report("pp2-2d-hybrid",
              abs(l1 - l2) < 2e-4 and gerr < 5e-3,
              f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")

# ---- part 4: MoE with the router aux weight ON ---------------------------
# The 1F1B loop accumulates each layer's (mean-normalized) aux once per
# microbatch; without the /n_micro renormalization in lm._pipeline_scan the
# aux term grows with the microbatch count (observed dloss ~2e-2 vs the
# ~2e-4 of a dp-split control).  Loss-only: per-slice load-balance terms
# are nonlinear in the token set, so grads legitimately differ a little —
# the same slicing variance non-PP gradient accumulation has.
import dataclasses  # noqa: E402

mcfg = runner.reduced_config("granite-moe-3b-a800m")
mcfg = mcfg.replace(moe=dataclasses.replace(mcfg.moe,
                                            router_aux_weight=0.01))
ml1, _ = runner.train_loss_and_grads(mcfg, runner.mesh(1, 1), batch=BATCH)
msh = runner.mesh(2, 2, 2, axes=("pipe", "data", "model"))
ml2, _ = runner.train_loss_and_grads(
    mcfg, msh, TrainHParams(microbatch=2), batch=BATCH)
runner.report("moe-aux-pp2", abs(ml1 - ml2) < 2e-3,
              f"dloss={abs(ml1 - ml2):.2e}")
