"""Subprocess body: sequence-parallel (AG/RS) TMP must match the AllReduce
scheme loss/grads exactly.  PASS/FAIL lines consumed by test_distributed."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

from repro.configs.base import TrainHParams

for arch in ["internlm2-1.8b", "gemma2-9b", "recurrentgemma-9b",
             "whisper-small", "mamba2-130m"]:
    mesh = runner.mesh(2, 4)
    l1, g1 = runner.train_loss_and_grads(
        arch, mesh, TrainHParams(schedule="oases", seq_parallel=False))
    l2, g2 = runner.train_loss_and_grads(
        arch, mesh, TrainHParams(schedule="oases", seq_parallel=True))
    gerr = runner.grads_err(g1, g2)
    runner.report(arch, abs(l1 - l2) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")
