import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.core import compat
from repro.configs.registry import get_config
from repro.configs.base import TrainHParams
from repro.models import lm, params as prm

def run(arch, sp, seq=64):
    cfg = get_config(arch).reduced().replace(dtype='float32')
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    hp = TrainHParams(schedule='oases', fine_remat=True, seq_parallel=sp)
    loss_fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4, seq_len=seq)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(42)
    batch = {'tokens': jax.random.randint(k, (4, seq), 0, cfg.vocab_size, jnp.int32),
             'labels': jax.random.randint(k, (4, seq), 0, cfg.vocab_size, jnp.int32)}
    if cfg.context_len:
        batch['ctx'] = 0.02*jax.random.normal(k, (4, cfg.context_len, cfg.d_model), jnp.float32)
    with compat.set_mesh(mesh):
        loss = jax.jit(loss_fn)(p, batch)[0]
        g = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(p, batch)
    flat = {jax.tree_util.keystr(kp): np.asarray(jax.device_get(v))
            for kp, v in jax.tree_util.tree_flatten_with_path(g)[0]}
    return float(loss), flat

for arch in ['internlm2-1.8b', 'gemma2-9b', 'recurrentgemma-9b', 'whisper-small', 'mamba2-130m']:
    l1, g1 = run(arch, False)
    l2, g2 = run(arch, True)
    gerr = max(np.max(np.abs(g1[k]-g2[k]))/(np.max(np.abs(g1[k]))+1e-8) for k in g1)
    ok = abs(l1 - l2) < 2e-4 and gerr < 5e-3
    print(f'{"PASS" if ok else "FAIL"} {arch} dloss={abs(l1-l2):.2e} gerr={gerr:.2e}', flush=True)
