"""Subprocess body: executable-ParallelPlan equivalence.

The tentpole contract: a heterogeneous per-layer (degree, schedule) plan
— consecutive layers with different strategies executing as separate scan
groups under their own TmpCtx/sub-batch split — must reproduce the
1-device oracle's loss AND gradients exactly.

``canonical_init`` initializes parameters in the canonical STACKED layout
and relayouts them into the run's grouped layout (grouped spec trees
flatten in a different order, which would otherwise deal different RNG
keys per leaf), so every case is value-comparable against the oracle —
and every case therefore also exercises the cross-plan relayout helpers
the elastic-resume path uses (models/params.relayout_flat).

Prints PASS/FAIL lines consumed by tests/test_distributed.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

# ---- mixed per-layer schedules at mesh-uniform degrees (plain mesh) ------
cfg = runner.reduced_config("internlm2-1.8b")
o_l, o_g = runner.train_loss_and_grads(cfg, runner.mesh(1, 1))
for scheds in (["oases", "megatron"], ["fused", "oases"],
               ["megatron", "wang"], ["merak", "oases"]):
    ls, g = runner.train_loss_and_grads(cfg, runner.mesh(2, 2),
                                        schedules=scheds,
                                        canonical_init=True)
    gc = runner.canonical_grads(cfg, g, schedules=scheds)
    gerr = runner.grads_err(o_g, gc)
    runner.report(f"sched-internlm2-{scheds}",
                  abs(o_l - ls) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(o_l - ls):.2e} gerr={gerr:.2e}")

# MoE interplay: expert sharding composes with per-layer schedule groups
moe = runner.reduced_config("granite-moe-3b-a800m")
m_l, m_g = runner.train_loss_and_grads(moe, runner.mesh(1, 1))
for scheds in (["fused", "oases"],):
    ls, g = runner.train_loss_and_grads(moe, runner.mesh(2, 2),
                                        schedules=scheds,
                                        canonical_init=True)
    gc = runner.canonical_grads(moe, g, schedules=scheds)
    gerr = runner.grads_err(m_g, gc)
    runner.report(f"sched-moe-{scheds}",
                  abs(m_l - ls) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(m_l - ls):.2e} gerr={gerr:.2e}")

# ---- mixed (degree, schedule) plans on the factored mesh -----------------
fm = runner.factored_mesh(1, (2, 2, 2))
o8_l, o8_g = runner.train_loss_and_grads(cfg, runner.mesh(1, 1), batch=8)
for degrees, scheds in (([4, 2], ["oases", "fused"]),
                        ([8, 8], ["megatron", "oases"]),
                        ([(2, 2), 4], ["fused", "wang"]),
                        # the golden MIXED_CASES strategy shape (high-
                        # degree wang + low-degree oases), scaled to the
                        # 8-device harness
                        ([8, 4], ["wang", "oases"])):
    ls, g = runner.train_loss_and_grads(cfg, fm, batch=8, degrees=degrees,
                                        schedules=scheds,
                                        canonical_init=True)
    gc = runner.canonical_grads(cfg, g, degrees=degrees, schedules=scheds)
    gerr = runner.grads_err(o8_g, gc)
    runner.report(f"plan-sched-{degrees}-{scheds}",
                  abs(o8_l - ls) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(o8_l - ls):.2e} gerr={gerr:.2e}")
