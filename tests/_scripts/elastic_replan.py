"""Subprocess body: ONLINE elastic training (runtime/elastic.py) — the
supervisor detects injected faults mid-run, re-runs the ILP against the
degraded topology, relayouts the live state in memory, and continues with
loss continuity against an uninterrupted oracle.

Case 1  host loss under a mixed-schedule (grouped-layout) plan: replan +
        in-memory grouped->stacked relayout, losses match the oracle 1:1.
Case 2  link-bandwidth degradation: replan without chip loss, continuity.
Case 3  corrupted checkpoint shard + worker failure: the restart restores
        from the previous INTACT checkpoint, not the corrupted one.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import tempfile

import numpy as np

from repro.configs.base import ShapeConfig, TrainHParams
from repro.core.plan import ParallelPlan
from repro.runtime import (ElasticConfig, ElasticSupervisor, FailureInjector,
                           Topology, Trainer)
from repro.runtime import elastic as el

cfg = runner.reduced_config("internlm2-1.8b")
hp = TrainHParams(total_steps=16, warmup_steps=2, learning_rate=1e-3)
shape = ShapeConfig("t", 64, 8, "train")
TOTAL = 16


def run_elastic(injector, start_plan, logs, *, hosts=4, steps=TOTAL):
    ckpt = tempfile.mkdtemp()
    topo = Topology(n_hosts=hosts, chips_per_host=8 // hosts)

    def make_trainer(topology, plan):
        mesh = el.mesh_for(topology, plan or start_plan)
        return Trainer(cfg, mesh, hp, global_batch=8, seq_len=64,
                       ckpt_dir=ckpt, injector=injector,
                       plan=plan if plan is not None else start_plan,
                       log_fn=logs.append)

    sup = ElasticSupervisor(make_trainer, topology=topo, cfg=cfg,
                            shape=shape, hp=hp,
                            econfig=ElasticConfig(backoff_s=0.0,
                                                  replan_time_limit=2.0),
                            log_fn=logs.append)
    return sup.run(steps, ckpt_every=4)


# ---- oracle: uninterrupted run on the healthy mesh -----------------------
mixed = ParallelPlan.from_hparams(hp, cfg.num_layers,
                                  schedules=["oases", "megatron"],
                                  mesh_shape=(2, 4),
                                  mesh_axes=("data", "model"))
oracle = Trainer(cfg, runner.mesh(2, 4), hp, global_batch=8, seq_len=64,
                 ckpt_dir=tempfile.mkdtemp(), plan=mixed,
                 log_fn=lambda s: None).train(TOTAL, ckpt_every=100)
assert len(oracle["losses"]) == TOTAL

# ---- case 1: host loss -> replan -> in-memory relayout -------------------
logs1 = []
r1 = run_elastic(FailureInjector(host_loss=((8, 3),)), mixed, logs1)
carried = any("carried live state" in ln for ln in logs1)
replanned = any("replanned after host-loss" in ln for ln in logs1)
diff = (float(np.max(np.abs(np.array(r1["losses"])
                            - np.array(oracle["losses"]))))
        if len(r1["losses"]) == TOTAL else float("inf"))
runner.report(
    "elastic-host-loss-continuity",
    replanned and r1["replans"] == 1 and r1["final_step"] >= TOTAL
    and r1["topology"].n_chips == 6 and diff < 0.05,
    f"replanned={replanned} carried={carried} chips=8->"
    f"{r1['topology'].n_chips} max|loss-oracle|={diff:.4f}")

# the relayout path must have been the in-memory one, not a checkpoint
# round-trip (losses 1:1 with the oracle implies no step re-execution)
runner.report("elastic-host-loss-in-memory-carry", carried,
              "; ".join(ln for ln in logs1 if "carried" in ln) or "no carry")

# ---- case 2: link degradation -> replan, no chip loss --------------------
logs2 = []
r2 = run_elastic(FailureInjector(link_degrade=((5, 2e9),)), mixed, logs2)
diff2 = (float(np.max(np.abs(np.array(r2["losses"])
                             - np.array(oracle["losses"]))))
         if len(r2["losses"]) == TOTAL else float("inf"))
runner.report(
    "elastic-link-degrade-continuity",
    r2["replans"] == 1 and r2["final_step"] >= TOTAL
    and r2["topology"].n_chips == 8 and r2["topology"].link_bw_y == 2e9
    and diff2 < 0.05,
    f"replans={r2['replans']} bw={r2['topology'].link_bw_y:.1e} "
    f"max|loss-oracle|={diff2:.4f}")

# ---- case 3: corrupted shard -> restart resumes from intact ckpt ---------
logs3 = []
r3 = run_elastic(
    FailureInjector(corrupt_at_steps=(8,), fail_at_steps=(10,)),
    mixed, logs3)
fell_back = any("corrupt" in ln for ln in logs3)
restored_4 = any("restored step 4" in ln for ln in logs3)
end_ok = abs(r3["losses"][-1] - oracle["losses"][-1]) < 0.05
runner.report(
    "elastic-corrupt-shard-intact-fallback",
    fell_back and restored_4 and r3["restarts"] == 1
    and r3["final_step"] >= TOTAL and end_ok,
    f"corrupt-detected={fell_back} restored-intact={restored_4} "
    f"last {r3['losses'][-1]:.3f} vs oracle {oracle['losses'][-1]:.3f}")
