"""Subprocess body: elastic re-mesh — train on a 2x4 mesh, checkpoint, then
resume on a 1x4 mesh (a 'pod' dropped); loss stays continuous."""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax

from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.runtime import Trainer

cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
ckpt = tempfile.mkdtemp()
hp = TrainHParams(total_steps=16, warmup_steps=2, learning_rate=1e-3)

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
t1 = Trainer(cfg, mesh_a, hp, global_batch=8, seq_len=64, ckpt_dir=ckpt,
             log_fn=lambda s: None)
r1 = t1.train(8, ckpt_every=4)

mesh_b = jax.make_mesh((1, 4), ("data", "model"))   # half the devices
logs = []
t2 = Trainer(cfg, mesh_b, hp, global_batch=8, seq_len=64, ckpt_dir=ckpt,
             log_fn=logs.append)
r2 = t2.train(16, ckpt_every=4)

restored = any("restored" in l for l in logs)
ok = restored and r2["final_step"] >= 16 \
    and abs(r2["losses"][0] - r1["losses"][-1]) < 0.5
print(f"resumed_on_smaller_mesh={restored} "
      f"loss {r1['losses'][-1]:.3f} -> {r2['losses'][0]:.3f}")
print("PASS" if ok else "FAIL", flush=True)
