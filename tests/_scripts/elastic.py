"""Subprocess body: elastic re-mesh — train on a 2x4 mesh, checkpoint, then
resume on a 1x4 mesh (a 'pod' dropped); loss stays continuous."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import tempfile

from repro.configs.base import TrainHParams
from repro.runtime import Trainer

cfg = runner.reduced_config("internlm2-1.8b")
ckpt = tempfile.mkdtemp()
hp = TrainHParams(total_steps=16, warmup_steps=2, learning_rate=1e-3)

t1 = Trainer(cfg, runner.mesh(2, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt, log_fn=lambda s: None)
r1 = t1.train(8, ckpt_every=4)

logs = []
t2 = Trainer(cfg, runner.mesh(1, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt, log_fn=logs.append)   # half the devices
r2 = t2.train(16, ckpt_every=4)

restored = any("restored" in l for l in logs)
runner.report(
    "elastic-remesh",
    restored and r2["final_step"] >= 16
    and abs(r2["losses"][0] - r1["losses"][-1]) < 0.5,
    f"resumed={restored} loss {r1['losses'][-1]:.3f} -> "
    f"{r2['losses'][0]:.3f}")
