"""Subprocess body: elastic re-mesh — train on a 2x4 mesh, checkpoint, then
resume on a 1x4 mesh (a 'pod' dropped); loss stays continuous.  Second
case: train with pipeline parallelism (pp=2), checkpoint, then resume on a
pure-TMP mesh — the stage-sharded [v, pp, n/S] param stacking reshapes onto
the canonical [n] layout on restore (checkpoint/store.py)."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import tempfile

from repro.configs.base import TrainHParams
from repro.runtime import Trainer

cfg = runner.reduced_config("internlm2-1.8b")
ckpt = tempfile.mkdtemp()
hp = TrainHParams(total_steps=16, warmup_steps=2, learning_rate=1e-3)

t1 = Trainer(cfg, runner.mesh(2, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt, log_fn=lambda s: None)
r1 = t1.train(8, ckpt_every=4)

logs = []
t2 = Trainer(cfg, runner.mesh(1, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt, log_fn=logs.append)   # half the devices
r2 = t2.train(16, ckpt_every=4)

restored = any("restored" in ln for ln in logs)
runner.report(
    "elastic-remesh",
    restored and r2["final_step"] >= 16
    and abs(r2["losses"][0] - r1["losses"][-1]) < 0.5,
    f"resumed={restored} loss {r1['losses'][-1]:.3f} -> "
    f"{r2['losses'][0]:.3f}")

# ---- PP -> pure-TMP elastic re-mesh --------------------------------------
ckpt_pp = tempfile.mkdtemp()
pipe_mesh = runner.mesh(2, 2, 2, axes=("pipe", "data", "model"))
t3 = Trainer(cfg, pipe_mesh, hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt_pp, log_fn=lambda s: None)
r3 = t3.train(8, ckpt_every=4)

logs_pp = []
t4 = Trainer(cfg, runner.mesh(2, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt_pp, log_fn=logs_pp.append)   # pp dropped
r4 = t4.train(16, ckpt_every=4)

restored_pp = any("restored" in ln for ln in logs_pp)
runner.report(
    "elastic-pp-to-tmp",
    restored_pp and r4["final_step"] >= 16
    and abs(r4["losses"][0] - r3["losses"][-1]) < 0.5,
    f"resumed={restored_pp} loss {r3['losses'][-1]:.3f} -> "
    f"{r4['losses'][0]:.3f}")

# and back: restore the now-TMP checkpoint onto a fresh pp=2 trainer
logs_back = []
t5 = Trainer(cfg, pipe_mesh, hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt_pp, log_fn=logs_back.append)
r5 = t5.train(24, ckpt_every=8)
runner.report(
    "elastic-tmp-to-pp",
    any("restored" in ln for ln in logs_back) and r5["final_step"] >= 24
    and abs(r5["losses"][0] - r4["losses"][-1]) < 0.5,
    f"loss {r4['losses'][-1]:.3f} -> {r5['losses'][0]:.3f}")

# ---- mixed-schedule plan -> global-schedule elastic re-mesh --------------
# Train under a heterogeneous per-layer (degree, schedule) ParallelPlan
# (the GROUPED parameter layout), checkpoint, then resume under a uniform
# plan on a plain mesh (the STACKED layout) — the manifest's recorded plan
# drives an exact grouped->stacked relayout on restore.  And back again.
from repro.core.plan import ParallelPlan

ckpt_plan = tempfile.mkdtemp()
mixed = ParallelPlan.from_hparams(hp, cfg.num_layers,
                                  schedules=["oases", "megatron"])
t6 = Trainer(cfg, runner.mesh(2, 2), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt_plan, plan=mixed, log_fn=lambda s: None)
r6 = t6.train(8, ckpt_every=4)

logs_mix = []
t7 = Trainer(cfg, runner.mesh(1, 4), hp, global_batch=8, seq_len=64,
             ckpt_dir=ckpt_plan, log_fn=logs_mix.append)
r7 = t7.train(16, ckpt_every=4)
relayout = any("relayout grouped -> stacked" in ln for ln in logs_mix)
runner.report(
    "elastic-mixed-plan-to-global",
    relayout and r7["final_step"] >= 16
    and abs(r7["losses"][0] - r6["losses"][-1]) < 0.5,
    f"relayout={relayout} loss {r6['losses'][-1]:.3f} -> "
    f"{r7['losses'][0]:.3f}")

# uniform checkpoint -> mixed-(degree, schedule) plan on the factored mesh
logs_fac = []
plan_fac = ParallelPlan.from_hparams(hp, cfg.num_layers, degrees=[4, 2],
                                     schedules=["oases", "fused"])
t8 = Trainer(cfg, runner.factored_mesh(1, (2, 2, 2)), hp, global_batch=8,
             seq_len=64, ckpt_dir=ckpt_plan, plan=plan_fac,
             log_fn=logs_fac.append)
r8 = t8.train(24, ckpt_every=8)
relayout_b = any("relayout stacked -> grouped" in ln for ln in logs_fac)
runner.report(
    "elastic-global-to-mixed-plan",
    relayout_b and r8["final_step"] >= 24
    and abs(r8["losses"][0] - r7["losses"][-1]) < 0.5,
    f"relayout={relayout_b} loss {r7['losses'][-1]:.3f} -> "
    f"{r8['losses'][0]:.3f}")
