"""Subprocess body: sharded serving (TMP x PP decode) equivalence.

On the 8-virtual-device CPU mesh, greedy decode through the continuous-
batching engine must be TOKEN-IDENTICAL to the single-device oracle for
every pp in {1, 2} x tmp in {1, 2} x schedule in {megatron, oases, fused}
mesh — the sharded KV cache (head-wise alongside the attention weights),
the fused collective-matmul rings chunked over the slot batch, and the
pipeline micro-step streaming (core/pipeline.decode_stream: stage s
decodes micro-group g while stage s-1 decodes g+1, caches staying put per
stage) are all numerically invisible to the decoded token stream.

Also pinned: the 2D hybrid decode layout, explicit decode micro-group
counts (1 = sequential stage traversal, 4 = two groups in flight per
stage), an indivisible slot count on a pipeline mesh, and a second arch
family (gemma2: sandwich norms + softcaps + local-attention ring cache).

The data axis is sized 8/(pp*tmp) as in pipeline_equivalence.py, so the
slot batch is dp-sharded whenever divisible and exercises the replicated
fallback when not (data=8 > slots).

Prints PASS/FAIL lines consumed by tests/test_distributed.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import numpy as np

from repro.configs.base import TrainHParams
from repro.serving import Request, ServingEngine

SLOTS = 4
MAX_SEQ = 48
N_REQ = 6          # > SLOTS: exercises slot reuse + admission backlog


def decode_all(cfg, mesh, hp, *, slots=SLOTS, decode_micro=0, prompts=None,
               **eng_kw):
    eng = ServingEngine(cfg, mesh, slots=slots, max_seq=MAX_SEQ, hp=hp,
                        decode_micro=decode_micro, **eng_kw)
    eng.load(seed=0)
    rng = np.random.default_rng(123)
    reqs = []
    for i in range(N_REQ):
        if prompts is not None:
            p = prompts[i]
        else:
            plen = int(rng.integers(3, 8))
            p = rng.integers(3, cfg.vocab_size, plen, dtype=np.int32)
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=6))
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["admitted"] == N_REQ, stats
    return [r.out_tokens for r in reqs]


def shared_prefix_prompts(vocab):
    """Prompts sharing block-aligned and mid-block prefixes (page_size=8):
    hits shorter and longer than one block, plus mid-block divergence."""
    rng = np.random.default_rng(7)
    base = rng.integers(3, vocab, 12, dtype=np.int32)
    out = []
    for i in range(N_REQ):
        keep = (6, 12, 9, 12, 6, 9)[i]          # mid-block + full reuse
        tail = rng.integers(3, vocab, 3 + (i % 3), dtype=np.int32)
        out.append(np.concatenate([base[:keep], tail]).astype(np.int32))
    return out


def check_tokens(name, got, ref):
    same = got == ref
    detail = "" if same else \
        f"first-mismatch={next(i for i in range(len(ref)) if got[i] != ref[i])}"
    runner.report(name, same, detail)


# ---- part 1: pp x tmp x schedule grid vs single-device oracle ------------
cfg = runner.reduced_config("internlm2-1.8b")
ref = decode_all(cfg, runner.mesh(1, 1), TrainHParams())

for pp in (1, 2):
    for tmp in (1, 2):
        data = 8 // (pp * tmp)
        if pp > 1:
            msh = runner.mesh(pp, data, tmp, axes=("pipe", "data", "model"))
        else:
            msh = runner.mesh(data, tmp)
        for sched in ("megatron", "oases", "fused"):
            got = decode_all(cfg, msh, TrainHParams(schedule=sched))
            check_tokens(f"serve-pp{pp}-tmp{tmp}-{sched}", got, ref)

# ---- part 2: 2D hybrid decode layout -------------------------------------
msh2d = runner.mesh(1, 2, 2, axes=("data", "model_x", "model_y"))
for sched in ("oases", "fused"):
    got = decode_all(cfg, msh2d, TrainHParams(schedule=sched))
    check_tokens(f"serve-2d-2x2-{sched}", got, ref)

# ---- part 3: explicit decode micro-group counts on the pipe mesh ---------
# data=1 so the local slot batch is the full 4: micro=1 is the sequential
# stage traversal, micro=4 puts two groups in flight per stage
msh = runner.mesh(2, 1, 2, axes=("pipe", "data", "model"))
for micro in (1, 2, 4):
    got = decode_all(cfg, msh, TrainHParams(schedule="oases"),
                     decode_micro=micro)
    check_tokens(f"serve-pp2-micro{micro}", got, ref)

# ---- part 4: indivisible slot count streams as one micro-group -----------
ref3 = decode_all(cfg, runner.mesh(1, 1), TrainHParams(), slots=3)
got = decode_all(cfg, runner.mesh(2, 1, 2, axes=("pipe", "data", "model")),
                 TrainHParams(schedule="fused"), slots=3)
check_tokens("serve-pp2-slots3", got, ref3)

# ---- part 5: second arch family (gemma2) ---------------------------------
gcfg = runner.reduced_config("gemma2-9b")   # sandwich norms, softcaps, local
gref = decode_all(gcfg, runner.mesh(1, 1), TrainHParams())
for name, msh in (("pp2-tmp2", runner.mesh(2, 2, 2,
                                           axes=("pipe", "data", "model"))),
                  ("2d-2x2", runner.mesh(1, 2, 2,
                                         axes=("data", "model_x",
                                               "model_y")))):
    got = decode_all(gcfg, msh, TrainHParams(schedule="fused"))
    check_tokens(f"serve-gemma2-{name}-fused", got, gref)

# ---- part 6: paged KV decode reads through the block table ---------------
# page-pool gather must be bitwise-invisible to the token stream on every
# mesh shape (the pool is replicated; pos/tables drive the gather)
for name, msh, hp in (
        ("tmp2-fused", runner.mesh(4, 2), TrainHParams(schedule="fused")),
        ("2d-2x2-oases", runner.mesh(1, 2, 2,
                                     axes=("data", "model_x", "model_y")),
         TrainHParams(schedule="oases")),
        ("pp2-tmp2-fused", runner.mesh(2, 2, 2,
                                       axes=("pipe", "data", "model")),
         TrainHParams(schedule="fused")),
):
    got = decode_all(cfg, msh, hp, paged=True, page_size=8)
    check_tokens(f"serve-paged-{name}", got, ref)

# ---- part 7: prefix reuse (shared blocks + COW) vs dense oracle ----------
sp = shared_prefix_prompts(cfg.vocab_size)
spref = decode_all(cfg, runner.mesh(1, 1), TrainHParams(), prompts=sp)
for name, msh, hp in (
        ("1dev", runner.mesh(1, 1), TrainHParams()),
        ("tmp2-fused", runner.mesh(4, 2), TrainHParams(schedule="fused")),
):
    got = decode_all(cfg, msh, hp, prompts=sp, paged=True, page_size=8,
                     prefix_cache=True)
    check_tokens(f"serve-prefix-{name}", got, spref)

# ---- part 8: speculative decoding vs the undrafted oracle ----------------
# the draft is the same reduced arch under independent weights (load()
# seeds it with seed+1), so proposals genuinely diverge from the target;
# greedy acceptance must still be token-identical to undrafted decode
for name, msh, hp in (
        ("1dev", runner.mesh(1, 1), TrainHParams()),
        ("tmp2-fused", runner.mesh(4, 2), TrainHParams(schedule="fused")),
        ("2d-2x2-oases", runner.mesh(1, 2, 2,
                                     axes=("data", "model_x", "model_y")),
         TrainHParams(schedule="oases")),
):
    got = decode_all(cfg, msh, hp, draft=cfg, spec_k=3)
    check_tokens(f"serve-spec-{name}", got, ref)

# the full production path: paged + prefix reuse + speculative rounds on a
# TMP mesh, against the plain single-device oracle on the same workload
got = decode_all(cfg, runner.mesh(4, 2), TrainHParams(schedule="fused"),
                 prompts=sp, paged=True, page_size=8, prefix_cache=True,
                 draft=cfg, spec_k=3)
check_tokens("serve-spec-paged-prefix-tmp2", got, spref)

# ---- part 9: spec verification rejects a pipeline mesh loudly ------------
try:
    ServingEngine(cfg, runner.mesh(2, 2, 2, axes=("pipe", "data", "model")),
                  slots=SLOTS, max_seq=MAX_SEQ,
                  hp=TrainHParams(schedule="fused"), draft=cfg, spec_k=2)
    runner.report("serve-spec-rejects-pp", False, "no error raised")
except ValueError as e:
    runner.report("serve-spec-rejects-pp", "pipe" in str(e),
                  str(e)[:70])

import sys  # noqa: E402

sys.exit(runner.exit_code())
