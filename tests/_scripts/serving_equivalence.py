"""Subprocess body: sharded serving (TMP x PP decode) equivalence.

On the 8-virtual-device CPU mesh, greedy decode through the continuous-
batching engine must be TOKEN-IDENTICAL to the single-device oracle for
every pp in {1, 2} x tmp in {1, 2} x schedule in {megatron, oases, fused}
mesh — the sharded KV cache (head-wise alongside the attention weights),
the fused collective-matmul rings chunked over the slot batch, and the
pipeline micro-step streaming (core/pipeline.decode_stream: stage s
decodes micro-group g while stage s-1 decodes g+1, caches staying put per
stage) are all numerically invisible to the decoded token stream.

Also pinned: the 2D hybrid decode layout, explicit decode micro-group
counts (1 = sequential stage traversal, 4 = two groups in flight per
stage), an indivisible slot count on a pipeline mesh, and a second arch
family (gemma2: sandwich norms + softcaps + local-attention ring cache).

The data axis is sized 8/(pp*tmp) as in pipeline_equivalence.py, so the
slot batch is dp-sharded whenever divisible and exercises the replicated
fallback when not (data=8 > slots).

Prints PASS/FAIL lines consumed by tests/test_distributed.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import numpy as np

from repro.configs.base import TrainHParams
from repro.serving import Request, ServingEngine

SLOTS = 4
MAX_SEQ = 48
N_REQ = 6          # > SLOTS: exercises slot reuse + admission backlog


def decode_all(cfg, mesh, hp, *, slots=SLOTS, decode_micro=0):
    eng = ServingEngine(cfg, mesh, slots=slots, max_seq=MAX_SEQ, hp=hp,
                        decode_micro=decode_micro)
    eng.load(seed=0)
    rng = np.random.default_rng(123)
    reqs = []
    for i in range(N_REQ):
        plen = int(rng.integers(3, 8))
        reqs.append(Request(rid=i,
                            prompt=rng.integers(3, cfg.vocab_size, plen,
                                                dtype=np.int32),
                            max_new_tokens=6))
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["admitted"] == N_REQ, stats
    return [r.out_tokens for r in reqs]


def check_tokens(name, got, ref):
    same = got == ref
    detail = "" if same else \
        f"first-mismatch={next(i for i in range(len(ref)) if got[i] != ref[i])}"
    runner.report(name, same, detail)


# ---- part 1: pp x tmp x schedule grid vs single-device oracle ------------
cfg = runner.reduced_config("internlm2-1.8b")
ref = decode_all(cfg, runner.mesh(1, 1), TrainHParams())

for pp in (1, 2):
    for tmp in (1, 2):
        data = 8 // (pp * tmp)
        if pp > 1:
            msh = runner.mesh(pp, data, tmp, axes=("pipe", "data", "model"))
        else:
            msh = runner.mesh(data, tmp)
        for sched in ("megatron", "oases", "fused"):
            got = decode_all(cfg, msh, TrainHParams(schedule=sched))
            check_tokens(f"serve-pp{pp}-tmp{tmp}-{sched}", got, ref)

# ---- part 2: 2D hybrid decode layout -------------------------------------
msh2d = runner.mesh(1, 2, 2, axes=("data", "model_x", "model_y"))
for sched in ("oases", "fused"):
    got = decode_all(cfg, msh2d, TrainHParams(schedule=sched))
    check_tokens(f"serve-2d-2x2-{sched}", got, ref)

# ---- part 3: explicit decode micro-group counts on the pipe mesh ---------
# data=1 so the local slot batch is the full 4: micro=1 is the sequential
# stage traversal, micro=4 puts two groups in flight per stage
msh = runner.mesh(2, 1, 2, axes=("pipe", "data", "model"))
for micro in (1, 2, 4):
    got = decode_all(cfg, msh, TrainHParams(schedule="oases"),
                     decode_micro=micro)
    check_tokens(f"serve-pp2-micro{micro}", got, ref)

# ---- part 4: indivisible slot count streams as one micro-group -----------
ref3 = decode_all(cfg, runner.mesh(1, 1), TrainHParams(), slots=3)
got = decode_all(cfg, runner.mesh(2, 1, 2, axes=("pipe", "data", "model")),
                 TrainHParams(schedule="fused"), slots=3)
check_tokens("serve-pp2-slots3", got, ref3)

# ---- part 5: second arch family (gemma2) ---------------------------------
gcfg = runner.reduced_config("gemma2-9b")   # sandwich norms, softcaps, local
gref = decode_all(gcfg, runner.mesh(1, 1), TrainHParams())
for name, msh in (("pp2-tmp2", runner.mesh(2, 2, 2,
                                           axes=("pipe", "data", "model"))),
                  ("2d-2x2", runner.mesh(1, 2, 2,
                                         axes=("data", "model_x",
                                               "model_y")))):
    got = decode_all(gcfg, msh, TrainHParams(schedule="fused"))
    check_tokens(f"serve-gemma2-{name}-fused", got, gref)

import sys  # noqa: E402

sys.exit(runner.exit_code())
