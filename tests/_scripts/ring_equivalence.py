"""Subprocess body: ring attention (DESIGN.md §12) vs the 1-device
oracle.  PASS/FAIL lines consumed by test_distributed.

Two tiers in one subprocess (the 8-virtual-device topology is expensive
to boot, so both ride the same interpreter):

* kernel tier — ``kernels.ring_attention`` under an 8-way shard_map vs
  ``models.attention.chunked_attention``, forward AND grads (the custom
  VJP's reverse ring), fp32 + bf16, causal / sliding-window / GQA /
  softcap, and uneven sequence tiles (padded rows at kv position -1);
* model tier — full train loss+grads through ``lm.build_train_loss``:
  the stacked ring path (uniform ``seq_shard``), the grouped path with
  mixed per-layer seqs, and gemma2 (GQA + softcap + local attention);
  plus the hard-error paths (bad shard factor, indivisible seq_len).
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainHParams
from repro.kernels.ring_attention import ring_attention
from repro.models.attention import chunked_attention

# ---------------------------------------------------------------------------
# kernel tier
# ---------------------------------------------------------------------------
kmesh = jax.make_mesh((runner.N_DEVICES,), ("model",))


def kernel_case(name, *, b=2, s=64, h=4, kvh=4, hd=16, causal=True,
                window=None, softcap=0.0, dtype=jnp.float32, pad=0):
    key = jax.random.PRNGKey(0)
    kq, kk, kv, kd = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, s, h, hd), dtype)
    k = jax.random.normal(kk, (b, s, kvh, hd), dtype)
    v = jax.random.normal(kv, (b, s, kvh, hd), dtype)
    do = jax.random.normal(kd, (b, s, h, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if pad:
        # uneven tiles: the last `pad` rows are padding (kv position -1;
        # their q rows leave the loss via a zero cotangent)
        pos = pos.at[:, s - pad:].set(-1)
        do = do.at[:, s - pad:].set(0.0)

    def loss_ref(q, k, v):
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_positions=pos,
                              kv_positions=pos)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32)), o

    def ring_body(q, k, v, qp, kvp):
        return ring_attention(q, k, v, axes=("model",), causal=causal,
                              window=window, softcap=softcap,
                              q_positions=qp, kv_positions=kvp)

    smap = shard_map(ring_body, mesh=kmesh,
                     in_specs=(P(None, "model"), P(None, "model"),
                               P(None, "model"), P(None, "model"),
                               P(None, "model")),
                     out_specs=P(None, "model"), check_rep=False)

    def loss_ring(q, k, v):
        o = smap(q, k, v, pos, pos)
        return jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32)), o

    (_, o_ref), g_ref = jax.value_and_grad(loss_ref, argnums=(0, 1, 2),
                                           has_aux=True)(q, k, v)
    (_, o_ring), g_ring = jax.value_and_grad(loss_ring, argnums=(0, 1, 2),
                                             has_aux=True)(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    errs = []
    # fully-masked rows (padding) carry unspecified values in both
    # implementations — mask them out of the comparison
    live = (pos >= 0)[:, :, None, None]
    errs.append(("out", float(jnp.max(jnp.abs(
        jnp.where(live, o_ref, 0).astype(jnp.float32)
        - jnp.where(live, o_ring, 0).astype(jnp.float32))))))
    for nm, a, bb in zip("qkv", g_ref, g_ring):
        errs.append((f"d{nm}", float(
            jnp.max(jnp.abs(a.astype(jnp.float32)
                            - bb.astype(jnp.float32)))
            / (float(jnp.max(jnp.abs(a))) + 1e-6))))
    runner.report(f"kernel-{name}", all(e < tol for _, e in errs),
                  " ".join(f"{nm}={e:.2e}" for nm, e in errs))


kernel_case("causal-fp32")
kernel_case("noncausal-fp32", causal=False)
kernel_case("window-fp32", window=24)
kernel_case("window-subblock-fp32", window=4)
kernel_case("gqa-fp32", h=8, kvh=2)
kernel_case("softcap-gqa-fp32", h=8, kvh=2, softcap=30.0)
kernel_case("causal-bf16", dtype=jnp.bfloat16)
kernel_case("gqa-window-bf16", h=8, kvh=2, window=24, dtype=jnp.bfloat16)
kernel_case("uneven-pad-fp32", pad=5)
kernel_case("uneven-pad-window-fp32", pad=13, window=24)

# ---------------------------------------------------------------------------
# model tier
# ---------------------------------------------------------------------------
hp0 = TrainHParams()
msh1 = runner.mesh(1, 1)
msh = runner.mesh(1, runner.N_DEVICES)
hp_ring = dataclasses.replace(hp0, seq_shard=runner.N_DEVICES,
                              seq_parallel=True)

l_ref, g_ref = runner.train_loss_and_grads("internlm2-1.8b", msh1, hp0)

# stacked ring: uniform seq_shard over the model axis (implied SP)
l_ring, g_ring = runner.train_loss_and_grads("internlm2-1.8b", msh, hp_ring)
runner.report("model-ring-stacked-loss", abs(l_ref - l_ring) < 2e-4,
              f"dloss={abs(l_ref - l_ring):.2e}")
runner.check("model-ring-stacked-grads", g_ring, g_ref, 5e-3)

# seq_shard alone must imply the sequence-parallel activation layout
l_r2, _ = runner.train_loss_and_grads(
    "internlm2-1.8b", msh,
    dataclasses.replace(hp0, seq_shard=runner.N_DEVICES))
runner.report("model-ring-implied-sp-loss", abs(l_ref - l_r2) < 2e-4,
              f"dloss={abs(l_ref - l_r2):.2e}")

# grouped path: mixed per-layer seqs (half ring, half classic)
cfg = runner.reduced_config("internlm2-1.8b")
n = cfg.num_layers
seqs = [runner.N_DEVICES] * (n // 2) + [1] * (n - n // 2)
l_mix, g_mix = runner.train_loss_and_grads(
    "internlm2-1.8b", msh, hp0, seqs=seqs, canonical_init=True)
g_mix = runner.canonical_grads("internlm2-1.8b", g_mix, seqs=seqs, hp=hp0)
runner.report("model-ring-mixed-loss", abs(l_ref - l_mix) < 2e-4,
              f"dloss={abs(l_ref - l_mix):.2e}")
runner.check("model-ring-mixed-grads", g_mix, g_ref, 5e-3)

# gemma2: GQA + softcap + alternating local/global attention + post-norms
l_g_ref, g_g_ref = runner.train_loss_and_grads("gemma2-9b", msh1, hp0)
l_g, g_g = runner.train_loss_and_grads("gemma2-9b", msh, hp_ring)
runner.report("model-ring-gemma2-loss", abs(l_g_ref - l_g) < 2e-4,
              f"dloss={abs(l_g_ref - l_g):.2e}")
runner.check("model-ring-gemma2-grads", g_g, g_g_ref, 5e-3)

# error paths: an unsatisfiable seq_shard is a hard error, never a
# silent fallback (cf. models/lm.py ring_blockers)
try:
    runner.train_loss_and_grads(
        "internlm2-1.8b", msh,
        dataclasses.replace(hp0, seq_shard=max(runner.N_DEVICES // 2, 2)))
    runner.report("model-ring-bad-shard-raises", False, "no error")
except ValueError as e:
    runner.report("model-ring-bad-shard-raises", "seq_shard" in str(e))
try:
    runner.train_loss_and_grads(
        "internlm2-1.8b", msh,
        dataclasses.replace(hp0, seq_shard=runner.N_DEVICES),
        seq=runner.N_DEVICES * 8 - 4)
    runner.report("model-ring-bad-seqlen-raises", False, "no error")
except ValueError as e:
    runner.report("model-ring-bad-seqlen-raises", "divisible" in str(e))

import sys
sys.exit(runner.exit_code())
