"""Subprocess body: fused collective-matmul numerics + schedule equivalence.

Part 1 — kernel-level: the ring decompositions (matmul→AR, matmul→RS,
AG→matmul) must match the jnp.dot + lax.psum/psum_scatter/all_gather
oracles in forward AND gradient, for fp32 and bf16 and for uneven
(non-power-of-two chunk) tile shapes, on an 8-virtual-device mesh.

Part 2 — edge cases the suite used to skip: a scatter/gather dim the ring
degree does NOT divide (AR falls back to the blocking reference, RS raises
the explicit divisibility error), degree=1 degeneracy on a real size-1
mesh axis, and bf16 gradient tolerance through the fused rings.

Part 3 — schedule-level: ``schedule="fused"`` must match ``megatron``
loss/grads bitwise-tolerantly under a 2-device model mesh (and the SP
variant under a 4-way axis, the only mode reaching the custom-VJP pair).

Prints PASS/FAIL lines consumed by tests/test_collective_matmul.py.
"""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.kernels import collective_matmul as cm

AXES = ("model",)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-5


def pair(mesh, fused_body, ref_body, in_specs, out_specs, args):
    """((fused_out, fused_grads), (ref_out, ref_grads)) under shard_map."""
    smf = compat.shard_map(fused_body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs)
    smr = compat.shard_map(ref_body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs)

    def loss(f):
        return lambda *a: sum(
            jnp.sum(jnp.tanh(o.astype(jnp.float32)))
            for o in jax.tree_util.tree_leaves(f(*a)))

    of, orf = jax.jit(smf)(*args), jax.jit(smr)(*args)
    gf = jax.jit(jax.grad(loss(smf), argnums=tuple(range(len(args)))))(*args)
    gr = jax.jit(jax.grad(loss(smr), argnums=tuple(range(len(args)))))(*args)
    return (of, gf), (orf, gr)


def kernel_level(dtype, b, s, k, d, mesh=None, axes=AXES, tag_extra=""):
    mesh = mesh or runner.mesh(8, axes=("model",))
    kx, kw, kw2 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (b, s, k), dtype)
    w = (0.1 * jax.random.normal(kw, (k, d))).astype(dtype)
    w2 = (0.1 * jax.random.normal(kw2, (k, d))).astype(dtype)
    tag = f"{dtype.__name__}-{b}x{s}x{k}x{d}{tag_extra}"
    nm = dict(mesh.shape).get("model", 1)
    kspec = "model" if nm > 1 else None

    # matmul -> all-reduce (row-parallel exit, K sharded)
    f, r = pair(
        mesh,
        lambda xl, wl: cm.fused_matmul_allreduce(xl, wl, axes),
        lambda xl, wl: cm.matmul_allreduce_ref(xl, wl, axes),
        (P(None, None, kspec), P(kspec, None)), P(), (x, w))
    runner.check(f"ar-{tag}", f, r, _tol(dtype))

    if s % max(nm, 1) == 0:
        # matmul -> reduce-scatter (SP exit, scatter along seq)
        f, r = pair(
            mesh,
            lambda xl, wl: cm.fused_matmul_reducescatter(xl, wl, axes, 1),
            lambda xl, wl: cm.matmul_reducescatter_ref(xl, wl, axes, 1),
            (P(None, None, kspec), P(kspec, None)),
            P(None, kspec, None), (x, w))
        runner.check(f"rs-{tag}", f, r, _tol(dtype))

        # all-gather -> matmul, two weights on one ring (SP entry)
        f, r = pair(
            mesh,
            lambda xl, w1, w2: cm.fused_allgather_matmul(xl, (w1, w2),
                                                         axes, 1),
            lambda xl, w1, w2: cm.allgather_matmul_ref(xl, (w1, w2),
                                                       axes, 1),
            (P(None, kspec, None), P(None, kspec), P(None, kspec)),
            (P(None, None, kspec), P(None, None, kspec)), (x, w, w2))
        runner.check(f"ag-{tag}", f, r, _tol(dtype))


# ---- part 1: ring-vs-oracle fwd+grad, fp32/bf16, uneven tiles ------------
for dtype in (jnp.float32, jnp.bfloat16):
    kernel_level(dtype, 2, 32, 64, 48)
kernel_level(jnp.float32, 1, 24, 40, 56)       # uneven: chunks of 3 rows
kernel_level(jnp.float32, 3, 16, 104, 72)      # uneven K_local=13
# bf16 gradient tolerance through the ring on uneven tiles
kernel_level(jnp.bfloat16, 1, 24, 40, 56, tag_extra="-uneven")

# ---- part 2: edge cases ---------------------------------------------------
# (a) scatter dim NOT divisible by the ring degree: the AR flavour must
# fall back to the blocking reference and stay exact (s=30, n=8)
kernel_level(jnp.float32, 2, 30, 64, 48, tag_extra="-nodiv")

# (b) reduce-scatter semantics genuinely need divisibility: explicit error
mesh8 = runner.mesh(8, axes=("model",))
x30 = jax.random.normal(jax.random.PRNGKey(1), (2, 30, 64))
w64 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (64, 48))
try:
    sm = compat.shard_map(
        lambda xl, wl: cm.fused_matmul_reducescatter(xl, wl, AXES, 1),
        mesh=mesh8, in_specs=(P(None, None, "model"), P("model", None)),
        out_specs=P(None, "model", None))
    jax.jit(sm)(x30, w64)
    runner.report("rs-nodiv-raises", False, "no error raised")
except ValueError as e:
    runner.report("rs-nodiv-raises", "not divisible" in str(e), str(e)[:60])

# (c) degree=1 degeneracy: a real size-1 model axis must degrade to the
# plain dot (backend 'ref'), forward and gradient
mesh1 = jax.make_mesh((8, 1), ("data", "model"))
xb = jax.random.normal(jax.random.PRNGKey(3), (8, 16, 32))
wb = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (32, 24))
f, r = pair(
    mesh1,
    lambda xl, wl: cm.fused_matmul_allreduce(xl, wl, AXES),
    lambda xl, wl: jnp.dot(xl, wl),
    (P("data", None, None), P(None, None)), P("data", None, None),
    (xb, wb))
runner.check("ar-degree1", f, r, 2e-5)
f, r = pair(
    mesh1,
    lambda xl, wl: cm.fused_matmul_reducescatter(xl, wl, AXES, 1),
    lambda xl, wl: jnp.dot(xl, wl),
    (P("data", None, None), P(None, None)), P("data", None, None),
    (xb, wb))
runner.check("rs-degree1", f, r, 2e-5)

# ---- part 3: schedule equivalence ----------------------------------------
def run(schedule, mesh, sp=False):
    hp = TrainHParams(schedule=schedule, fine_remat=True, seq_parallel=sp)
    return runner.train_loss_and_grads("internlm2-1.8b", mesh, hp)


mesh2 = runner.mesh(1, 2)
l_meg, g_meg = run("megatron", mesh2)
l_fus, g_fus = run("fused", mesh2)
runner.report("sched-loss", abs(l_meg - l_fus) < 1e-6,
              f"dloss={abs(l_meg - l_fus):.2e}")
runner.check("sched-grads", g_meg, g_fus, 5e-4)

# fused + sequence-parallel: the only mode reaching the custom-VJP pair
# (fused_allgather_matmul / fused_matmul_reducescatter) through the model,
# on a 4-way model axis so the rings actually run
mesh4 = runner.mesh(2, 4)
l_meg_sp, g_meg_sp = run("megatron", mesh4, sp=True)
l_fus_sp, g_fus_sp = run("fused", mesh4, sp=True)
runner.report("sched-sp-loss", abs(l_meg_sp - l_fus_sp) < 1e-6,
              f"dloss={abs(l_meg_sp - l_fus_sp):.2e}")
runner.check("sched-sp-grads", g_meg_sp, g_fus_sp, 5e-4)
