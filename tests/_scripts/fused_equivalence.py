"""Subprocess body: fused collective-matmul numerics + schedule equivalence.

Part 1 — kernel-level: the ring decompositions (matmul→AR, matmul→RS,
AG→matmul) must match the jnp.dot + lax.psum/psum_scatter/all_gather
oracles in forward AND gradient, for fp32 and bf16 and for uneven
(non-power-of-two chunk) tile shapes, on an 8-virtual-device mesh.

Part 2 — schedule-level: ``schedule="fused"`` must match ``megatron``
loss/grads bitwise-tolerantly under a 2-device model mesh.

Prints PASS/FAIL lines consumed by tests/test_collective_matmul.py.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.kernels import collective_matmul as cm
from repro.models import lm
from repro.models import params as prm

AXES = ("model",)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-5


def check(name, a, b, tol):
    a = [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(a)]
    b = [np.asarray(x, np.float32) for x in jax.tree_util.tree_leaves(b)]
    err = max(float(np.max(np.abs(x - y))) / (float(np.max(np.abs(x))) + 1e-6)
              for x, y in zip(a, b))
    print(f"{'PASS' if err < tol else 'FAIL'} {name} err={err:.2e}",
          flush=True)


def kernel_level(dtype, b, s, k, d):
    mesh = jax.make_mesh((8,), ("model",))
    kx, kw, kw2 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (b, s, k), dtype)
    w = (0.1 * jax.random.normal(kw, (k, d))).astype(dtype)
    w2 = (0.1 * jax.random.normal(kw2, (k, d))).astype(dtype)
    tag = f"{dtype.__name__}-{b}x{s}x{k}x{d}"

    def pair(fused_body, ref_body, in_specs, out_specs, args, nout=1):
        smf = compat.shard_map(fused_body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)
        smr = compat.shard_map(ref_body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs)

        def loss(f):
            return lambda *a: sum(
                jnp.sum(jnp.tanh(o.astype(jnp.float32)))
                for o in jax.tree_util.tree_leaves(f(*a)))

        of, orf = jax.jit(smf)(*args), jax.jit(smr)(*args)
        gf = jax.jit(jax.grad(loss(smf), argnums=tuple(range(len(args)))))(*args)
        gr = jax.jit(jax.grad(loss(smr), argnums=tuple(range(len(args)))))(*args)
        return (of, gf), (orf, gr)

    # matmul -> all-reduce (row-parallel exit, K sharded)
    f, r = pair(
        lambda xl, wl: cm.fused_matmul_allreduce(xl, wl, AXES),
        lambda xl, wl: cm.matmul_allreduce_ref(xl, wl, AXES),
        (P(None, None, "model"), P("model", None)), P(), (x, w))
    check(f"ar-{tag}", f, r, _tol(dtype))

    # matmul -> reduce-scatter (SP exit, scatter along seq)
    f, r = pair(
        lambda xl, wl: cm.fused_matmul_reducescatter(xl, wl, AXES, 1),
        lambda xl, wl: cm.matmul_reducescatter_ref(xl, wl, AXES, 1),
        (P(None, None, "model"), P("model", None)),
        P(None, "model", None), (x, w))
    check(f"rs-{tag}", f, r, _tol(dtype))

    # all-gather -> matmul, two weights on one ring (SP entry)
    f, r = pair(
        lambda xl, w1, w2: cm.fused_allgather_matmul(xl, (w1, w2), AXES, 1),
        lambda xl, w1, w2: cm.allgather_matmul_ref(xl, (w1, w2), AXES, 1),
        (P(None, "model", None), P(None, "model"), P(None, "model")),
        (P(None, None, "model"), P(None, None, "model")), (x, w, w2))
    check(f"ag-{tag}", f, r, _tol(dtype))


for dtype in (jnp.float32, jnp.bfloat16):
    kernel_level(dtype, 2, 32, 64, 48)
kernel_level(jnp.float32, 1, 24, 40, 56)       # uneven: chunks of 3 rows
kernel_level(jnp.float32, 3, 16, 104, 72)      # uneven K_local=13


# ---- schedule equivalence: fused == megatron on a 2-device model mesh ----
def run(schedule, mesh, sp=False):
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    hp = TrainHParams(schedule=schedule, fine_remat=True, seq_parallel=sp)
    loss_fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                            seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    kb = jax.random.PRNGKey(42)
    batch = {"tokens": jax.random.randint(kb, (4, 64), 0, cfg.vocab_size,
                                          jnp.int32),
             "labels": jax.random.randint(kb, (4, 64), 0, cfg.vocab_size,
                                          jnp.int32)}
    with compat.set_mesh(mesh):
        loss = float(jax.jit(loss_fn)(p, batch)[0])
        grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(p, batch)
    return loss, grads


mesh2 = jax.make_mesh((1, 2), ("data", "model"))
l_meg, g_meg = run("megatron", mesh2)
l_fus, g_fus = run("fused", mesh2)
print(f"{'PASS' if abs(l_meg - l_fus) < 1e-6 else 'FAIL'} "
      f"sched-loss dloss={abs(l_meg - l_fus):.2e}", flush=True)
check("sched-grads", g_meg, g_fus, 5e-4)

# fused + sequence-parallel: the only mode reaching the custom-VJP pair
# (fused_allgather_matmul / fused_matmul_reducescatter) through the model,
# on a 4-way model axis so the rings actually run
mesh4 = jax.make_mesh((2, 4), ("data", "model"))
l_meg_sp, g_meg_sp = run("megatron", mesh4, sp=True)
l_fus_sp, g_fus_sp = run("fused", mesh4, sp=True)
print(f"{'PASS' if abs(l_meg_sp - l_fus_sp) < 1e-6 else 'FAIL'} "
      f"sched-sp-loss dloss={abs(l_meg_sp - l_fus_sp):.2e}", flush=True)
check("sched-sp-grads", g_meg_sp, g_fus_sp, 5e-4)
