"""Subprocess body: TMP-sharded loss/grads must equal single-device values.
Prints PASS/FAIL lines consumed by tests/test_distributed.py."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.models import lm
from repro.models import params as prm


def run(arch, mesh_shape, schedule="oases", fine=True):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    if cfg.moe is not None:   # exactness needs no-drop, no per-shard aux
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=100.0, router_aux_weight=0.0))
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    hp = TrainHParams(schedule=schedule, fine_remat=fine)
    loss_fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                            seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(42)
    batch = {"tokens": jax.random.randint(k, (4, 64), 0, cfg.vocab_size,
                                          jnp.int32),
             "labels": jax.random.randint(k, (4, 64), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.context_len:
        batch["ctx"] = 0.02 * jax.random.normal(
            k, (4, cfg.context_len, cfg.d_model), jnp.float32)
    with compat.set_mesh(mesh):
        loss = jax.jit(loss_fn)(p, batch)[0]
        grads = jax.jit(jax.grad(lambda p, b: loss_fn(p, b)[0]))(p, batch)
    flat = {jax.tree_util.keystr(kp): np.asarray(jax.device_get(v))
            for kp, v in jax.tree_util.tree_flatten_with_path(grads)[0]}
    return float(loss), flat


ARCHS = ["internlm2-1.8b", "gemma2-9b", "recurrentgemma-9b",
         "moonshot-v1-16b-a3b", "granite-moe-3b-a800m", "whisper-small",
         "mamba2-130m"]

for arch in ARCHS:
    l1, g1 = run(arch, (1, 1))
    l2, g2 = run(arch, (2, 4))
    gerr = max(np.max(np.abs(g1[k] - g2[k])) / (np.max(np.abs(g1[k])) + 1e-8)
               for k in g1)
    ok = abs(l1 - l2) < 2e-4 and gerr < 5e-3
    print(f"{'PASS' if ok else 'FAIL'} {arch} dloss={abs(l1-l2):.2e} "
          f"gerr={gerr:.2e}", flush=True)

# all four schedules agree on the loss
losses = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))
for sched in ["megatron", "wang", "merak", "oases"]:
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    hp = TrainHParams(schedule=sched)
    fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                       seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    b = {"tokens": jnp.ones((4, 64), jnp.int32),
         "labels": jnp.ones((4, 64), jnp.int32)}
    with compat.set_mesh(mesh):
        losses[sched] = float(jax.jit(fn)(p, b)[0])
spread = max(losses.values()) - min(losses.values())
print(f"{'PASS' if spread < 1e-5 else 'FAIL'} schedules spread={spread:.2e}",
      flush=True)
