"""Subprocess body: TMP-sharded loss/grads must equal single-device values.
Prints PASS/FAIL lines consumed by tests/test_distributed.py."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.models import lm
from repro.models import params as prm

ARCHS = ["internlm2-1.8b", "gemma2-9b", "recurrentgemma-9b",
         "moonshot-v1-16b-a3b", "granite-moe-3b-a800m", "whisper-small",
         "mamba2-130m"]

for arch in ARCHS:
    l1, g1 = runner.train_loss_and_grads(arch, runner.mesh(1, 1))
    l2, g2 = runner.train_loss_and_grads(arch, runner.mesh(2, 4))
    gerr = runner.grads_err(g1, g2)
    runner.report(arch, abs(l1 - l2) < 2e-4 and gerr < 5e-3,
                  f"dloss={abs(l1 - l2):.2e} gerr={gerr:.2e}")

# all four program-order schedules agree on the loss
losses = {}
mesh = runner.mesh(2, 4)
for sched in ["megatron", "wang", "merak", "oases"]:
    cfg = runner.reduced_config("internlm2-1.8b")
    hp = TrainHParams(schedule=sched)
    fn, specs, _ = lm.build_train_loss(cfg, mesh, hp, global_batch=4,
                                       seq_len=64)
    p = prm.init_params(specs, jax.random.PRNGKey(0))
    b = {"tokens": jnp.ones((4, 64), jnp.int32),
         "labels": jnp.ones((4, 64), jnp.int32)}
    with compat.set_mesh(mesh):
        losses[sched] = float(jax.jit(fn)(p, b)[0])
spread = max(losses.values()) - min(losses.values())
runner.report("schedules", spread < 1e-5, f"spread={spread:.2e}")
