"""Subprocess body: telemetry end-to-end on a real TMP mesh — a short
training run with a JSONL sink must produce a schema-valid trace carrying
step-time histograms, per-host heartbeat metrics, and the overlap probe's
per-layer-group events (measured vs modeled exposed communication)."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import json
import os
import tempfile

from repro import obs
from repro.configs.base import TrainHParams
from repro.obs.schema import SchemaError, validate_lines
from repro.runtime import Trainer
from repro.runtime import elastic as el

mesh = runner.mesh(2, 4)
cfg = runner.reduced_config("internlm2-1.8b")
ckpt = tempfile.mkdtemp()
tel = tempfile.mkdtemp()

logs = []
rec = obs.Recorder(tel, flush_every=1, console=logs.append)
trainer = Trainer(cfg, mesh,
                  TrainHParams(schedule="oases", total_steps=8,
                               warmup_steps=2, learning_rate=1e-3),
                  global_batch=8, seq_len=64, ckpt_dir=ckpt,
                  telemetry=rec, host_id=1)
res = trainer.train(8, ckpt_every=4)
rec.close()

# ---- schema-valid JSONL trace --------------------------------------------
lines = open(os.path.join(tel, "telemetry.jsonl")).read().splitlines()
try:
    recs = validate_lines(lines)
    runner.report("telemetry-schema", len(recs) >= 8,
                  f"{len(recs)} records, all valid")
except SchemaError as e:
    runner.report("telemetry-schema", False, str(e))
    recs = []

names = [r["name"] for r in recs]

# ---- trainer metrics -----------------------------------------------------
steps = [r for r in recs if r["name"] == "trainer.step_time_s"]
runner.report("telemetry-step-hist",
              len(steps) == 8 and all(r["kind"] == "histogram"
                                      and r["value"] > 0 for r in steps),
              f"{len(steps)} step samples")
runner.report("telemetry-ckpt-latency",
              any(r["name"] == "trainer.ckpt_write_s" for r in recs),
              "async checkpoint write latency recorded")
runner.report("telemetry-console",
              any("loss" in ln for ln in logs),
              f"{len(logs)} console lines preserved")

# ---- overlap probe (the PR acceptance signal) ----------------------------
groups = [r for r in recs if r["name"] == "overlap.group"]
ok = bool(groups)
for g in groups:
    t = g.get("tags", {})
    ok = ok and 0.0 <= t.get("measured_exposed_frac", -1) <= 1.0 \
        and t.get("schedule") == "oases"
runner.report("telemetry-overlap-groups", ok,
              f"{len(groups)} layer-group events, schedule tags intact")
runner.report("telemetry-overlap-gauges",
              "overlap.measured_exposed_frac" in names
              and "overlap.model_residual" in names,
              "overall exposed fraction + model residual gauges present")

# ---- enriched heartbeat (straggler localization input) -------------------
hb = el.read_heartbeat(el.heartbeat_path(ckpt))
runner.report("telemetry-heartbeat",
              hb is not None and hb.get("host") == 1
              and isinstance(hb.get("step_time_ewma_s"), float)
              and hb.get("step") == 7,
              json.dumps(hb))

runner.report("telemetry-run-complete",
              res["final_step"] >= 8 and len(res["losses"]) == 8,
              f"final_step={res['final_step']}")
