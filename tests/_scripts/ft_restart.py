"""Subprocess body: failure injection -> supervisor restart -> checkpoint
restore -> run to completion."""
import runner  # noqa: F401  (must be first: sets XLA_FLAGS before jax)

import tempfile

from repro.configs.base import TrainHParams
from repro.runtime import FailureInjector, Trainer, run_with_restarts

mesh = runner.mesh(2, 4)
cfg = runner.reduced_config("internlm2-1.8b")
ckpt = tempfile.mkdtemp()
logs = []
calls = [0]


def factory():
    calls[0] += 1
    inject = (12,) if calls[0] == 1 else ()
    return Trainer(cfg, mesh,
                   TrainHParams(total_steps=20, warmup_steps=2,
                                learning_rate=1e-3),
                   global_batch=8, seq_len=64, ckpt_dir=ckpt,
                   injector=FailureInjector(fail_at_steps=inject),
                   log_fn=logs.append)


res = run_with_restarts(factory, total_steps=20, ckpt_every=5)
restored = any("restored" in ln for ln in logs)
runner.report(
    "ft-restart",
    calls[0] == 2 and restored and res["final_step"] >= 20
    and res["losses"][-1] < res["losses"][0] + 0.5,
    f"restarts={calls[0]-1} final={res['final_step']} "
    f"loss {res['losses'][0]:.3f}->{res['losses'][-1]:.3f}")
