"""Subprocess body: failure injection -> supervisor restart -> checkpoint
restore -> run to completion."""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax

from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.runtime import FailureInjector, Trainer, run_with_restarts

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
ckpt = tempfile.mkdtemp()
logs = []
calls = [0]


def factory():
    calls[0] += 1
    inject = (12,) if calls[0] == 1 else ()
    return Trainer(cfg, mesh,
                   TrainHParams(total_steps=20, warmup_steps=2,
                                learning_rate=1e-3),
                   global_batch=8, seq_len=64, ckpt_dir=ckpt,
                   injector=FailureInjector(fail_at_steps=inject),
                   log_fn=logs.append)


res = run_with_restarts(factory, total_steps=20, ckpt_every=5)
restored = any("restored" in l for l in logs)
ok = (calls[0] == 2 and restored and res["final_step"] >= 20
      and res["losses"][-1] < res["losses"][0] + 0.5)
print(f"restarts={calls[0]-1} final={res['final_step']} "
      f"loss {res['losses'][0]:.3f}->{res['losses'][-1]:.3f}")
print("PASS" if ok else "FAIL", flush=True)
