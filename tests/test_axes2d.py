"""Unit tests for the two-axis (model_x x model_y) mesh algebra — the
degree type (int | (dx, dy)) and the x/y split MeshInfo hands the 2D
TmpCtx.  AbstractMesh keeps these in-process (no devices needed)."""
import pytest
from jax.sharding import AbstractMesh

from repro.core.axes import T_AXES, deg_total, deg_xy, mesh_info


def _info(*shape_axes):
    return mesh_info(AbstractMesh(tuple(shape_axes)))


def test_degree_helpers():
    assert deg_total(None) is None
    assert deg_total(8) == 8
    assert deg_total((4, 2)) == 8
    assert deg_xy(8) == (8, 1)
    assert deg_xy((2, 4)) == (2, 4)


def test_mesh_info_detects_2d_axes():
    info = _info(("data", 2), ("model_x", 4), ("model_y", 2))
    assert info.model_axes == ("model_x", "model_y")
    assert info.twod and not info.factored
    assert info.tp == 8 and info.dp == 2
    assert info.xy_axes() == (("model_x",), ("model_y",))
    assert info.tp_axes((4, 2)) == ("model_x", "model_y")


def test_uniform_1d_mesh_has_empty_y():
    info = _info(("data", 2), ("model", 4))
    assert not info.twod and not info.factored
    assert info.xy_axes() == (("model",), ())


def test_2d_degree_must_match_mesh_layout():
    info = _info(("data", 1), ("model_x", 4), ("model_y", 2))
    with pytest.raises(ValueError):
        info.xy_axes((2, 4))          # transposed vs the mesh
    assert info.xy_axes((4, 2)) == (("model_x",), ("model_y",))


def test_factored_mesh_prefix_split():
    info = _info(("data", 16), *((t, 2) for t in T_AXES))
    assert info.factored and not info.twod
    assert info.xy_axes(4) == (("t1", "t2"), ())
    assert info.xy_axes((4, 2)) == (("t1", "t2"), ("t3",))
    assert info.xy_axes((1, 4)) == ((), ("t1", "t2"))
    assert info.xy_axes((2, 8)) == (("t1",), ("t2", "t3", "t4"))
    assert info.tp_axes((2, 2)) == ("t1", "t2")
    # extra-dp axes follow the combined group
    assert info.extra_dp_axes((2, 2)) == ("t3", "t4")
    with pytest.raises(ValueError):
        info.xy_axes((4, 8))          # 32 > 16-way model group
    with pytest.raises(ValueError):
        info.xy_axes((3, 2))          # non-power-of-two


def test_uniform_mesh_rejects_per_layer_2d():
    info = _info(("data", 2), ("model", 8))
    with pytest.raises(ValueError):
        info.xy_axes((2, 2))          # needs factored or model_x/model_y
    assert info.xy_axes((8, 1)) == (("model",), ())
