"""First-class executable ParallelPlan (core/plan.py).

Pinned here:
* JSON round-trip is the identity (hypothesis property over random plans)
  and malformed / unknown-field payloads are rejected with friendly errors;
* schedule names validate at TrainHParams / plan construction (the valid
  set is named, nothing silently falls through to megatron-like behavior);
* the legacy-flag desugaring (launch/mesh.resolve_launch and
  ParallelPlan.from_hparams/apply) is lossless for the knobs it carries;
* the checkpoint manifest records the plan and it survives a save/load;
* plan() attaches an executable .plan whose layers match its decision;
* the cross-plan relayout (models/params.relayout_flat) is an exact
  inverse pair over every layout (stacked / pipeline-stacked / grouped).
"""
import json

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.core import plan as planmod
from repro.core.plan import LayerStrategy, ParallelPlan, validate_schedule
from repro.core.schedule import SCHEDULES as EXEC_SCHEDULES


# --------------------------------------------------------------------------
# schedule-name validation (satellite: no more silent fallthrough)
# --------------------------------------------------------------------------
def test_schedule_sets_agree():
    """core/plan.py keeps an import-cycle-free mirror of the executable
    schedule set — they must never drift."""
    assert tuple(planmod.SCHEDULES) == tuple(EXEC_SCHEDULES)


def test_unknown_schedule_rejected_at_hparams():
    with pytest.raises(ValueError, match="valid schedules are"):
        TrainHParams(schedule="megatorn")
    with pytest.raises(ValueError, match="tmp_layout"):
        TrainHParams(tmp_layout="3d")


def test_unknown_schedule_rejected_at_effective_split():
    from repro.core.schedule import effective_split
    with pytest.raises(ValueError, match="valid schedules"):
        effective_split("oasis", 2, 8)
    assert effective_split("oases", 2, 8) == 2


def test_validate_schedule_names_the_set():
    with pytest.raises(ValueError) as ei:
        validate_schedule("wat")
    for s in EXEC_SCHEDULES:
        assert s in str(ei.value)


# --------------------------------------------------------------------------
# construction validation
# --------------------------------------------------------------------------
def test_layer_strategy_validation():
    assert LayerStrategy((4, 1), "oases").degree == 4   # canonicalized
    with pytest.raises(ValueError, match="powers of two"):
        LayerStrategy(3, "oases")
    with pytest.raises(ValueError, match="powers of two"):
        LayerStrategy((4, 3), "oases")
    with pytest.raises(ValueError, match="layer schedule"):
        LayerStrategy(4, "bogus")


def test_plan_validation():
    ls = (LayerStrategy(None, "oases"),)
    with pytest.raises(ValueError, match="at least one layer"):
        ParallelPlan(layers=())
    with pytest.raises(ValueError, match="matching lengths"):
        ParallelPlan(layers=ls, mesh_shape=(2, 4), mesh_axes=("data",))
    with pytest.raises(ValueError, match="tmp_layout"):
        ParallelPlan(layers=ls, tmp_layout="5d")
    with pytest.raises(ValueError, match="pp"):
        ParallelPlan(layers=ls, pp=0)
    # pp > 1 requires a uniform strategy
    with pytest.raises(ValueError, match="pipeline"):
        ParallelPlan(layers=(LayerStrategy(None, "oases"),
                             LayerStrategy(None, "megatron")), pp=2)


def test_plan_views():
    p = ParallelPlan(layers=(LayerStrategy(8, "oases"),
                             LayerStrategy((4, 2), "wang"),
                             LayerStrategy(8, "oases")))
    assert p.is_mixed and p.uniform_schedule is None
    assert p.degrees == (8, (4, 2), 8)
    assert p.schedules == ("oases", "wang", "oases")
    assert p.planned_degrees == (8, (4, 2), 8)
    assert p.grouping_signature()[0] == "grouped"
    u = ParallelPlan(layers=(LayerStrategy(None, "fused"),) * 3)
    assert not u.is_mixed and u.uniform_schedule == "fused"
    assert u.planned_degrees is None
    assert u.grouping_signature() == ("stacked", 1, 1)
    # mixed schedules on a uniform mesh degree: fused leads decode
    m = ParallelPlan(layers=(LayerStrategy(None, "oases"),
                             LayerStrategy(None, "fused")))
    assert m.primary_schedule == "fused"


# --------------------------------------------------------------------------
# JSON round-trip
# --------------------------------------------------------------------------
def _plans_strategy():
    try:
        import hypothesis  # noqa: F401
    except ModuleNotFoundError:
        return None         # @given stub marks the test skipped
    degrees = st.one_of(st.none(), st.sampled_from([1, 2, 4, 8, 16]),
                        st.tuples(st.sampled_from([2, 4, 8]),
                                  st.sampled_from([2, 4])))
    layer = st.builds(LayerStrategy, degree=degrees,
                      schedule=st.sampled_from(list(EXEC_SCHEDULES)))
    return st.builds(
        ParallelPlan,
        layers=st.lists(layer, min_size=1, max_size=6).map(tuple),
        tmp_layout=st.sampled_from(["auto", "1d", "2d"]),
        virtual_stages=st.integers(1, 4),
        split=st.integers(1, 4),
        microbatch=st.integers(0, 8),
        decode_micro=st.integers(0, 4),
        zero1=st.booleans(),
        grad_compress=st.booleans(),
        seq_parallel=st.booleans())


@settings(max_examples=50, deadline=None)
@given(p=_plans_strategy())
def test_plan_json_roundtrip_property(p):
    assert ParallelPlan.from_json(p.to_json()) == p
    assert ParallelPlan.from_dict(json.loads(p.to_json())) == p


def test_plan_json_roundtrip_cases():
    """Deterministic fallback for the hypothesis property (runs even
    without the optional dep): a spread of layouts, degrees and knobs."""
    cases = [
        ParallelPlan(layers=(LayerStrategy(None, "oases"),)),
        ParallelPlan(layers=(LayerStrategy(2, "megatron"),
                             LayerStrategy((4, 2), "fused"),
                             LayerStrategy(None, "wang")),
                     tmp_layout="2d", split=1, zero1=False),
        ParallelPlan(layers=(LayerStrategy(16, "merak"),) * 5,
                     microbatch=8, decode_micro=2, grad_compress=True,
                     seq_parallel=True),
        ParallelPlan(layers=(LayerStrategy(8, "fused"),) * 4,
                     mesh_shape=(2, 1, 8), mesh_axes=("pipe", "data",
                                                      "model"),
                     pp=2, virtual_stages=2),
    ]
    for p in cases:
        assert ParallelPlan.from_json(p.to_json()) == p
        assert ParallelPlan.from_dict(json.loads(p.to_json())) == p


def test_plan_json_roundtrip_with_mesh():
    p = ParallelPlan(layers=(LayerStrategy(None, "oases"),) * 4,
                     mesh_shape=(2, 2, 2), mesh_axes=("pipe", "data",
                                                      "model"),
                     pp=2, virtual_stages=2, microbatch=4)
    assert ParallelPlan.from_json(p.to_json()) == p


def test_plan_json_rejects_malformed():
    with pytest.raises(ValueError, match="malformed plan JSON"):
        ParallelPlan.from_json("{not json")
    with pytest.raises(ValueError, match="JSON object"):
        ParallelPlan.from_json("[1, 2]")
    with pytest.raises(ValueError, match="missing required field"):
        ParallelPlan.from_json("{}")
    good = ParallelPlan(layers=(LayerStrategy(4, "oases"),))
    payload = good.to_dict()
    payload["frobnicate"] = 1
    with pytest.raises(ValueError, match="unknown plan field"):
        ParallelPlan.from_dict(payload)
    with pytest.raises(ValueError, match="layer 0"):
        ParallelPlan.from_dict({"layers": [[4, "oases", "extra"]]})
    with pytest.raises(ValueError, match="unknown strategy field"):
        ParallelPlan.from_dict(
            {"layers": [{"degree": 4, "schedule": "oases", "x": 1}]})
    with pytest.raises(ValueError, match="powers of two"):
        ParallelPlan.from_dict({"layers": [[3, "oases"]]})


# --------------------------------------------------------------------------
# desugaring (hp <-> plan)
# --------------------------------------------------------------------------
def test_from_hparams_apply_roundtrip():
    hp = TrainHParams(schedule="fused", tmp_layout="1d", split=4,
                      microbatch=2, virtual_stages=2, zero1=False,
                      grad_compress=True, seq_parallel=True)
    p = ParallelPlan.from_hparams(hp, 6, pp=1)
    assert p.num_layers == 6 and not p.is_mixed
    hp2 = p.apply(TrainHParams())
    for f in ("schedule", "tmp_layout", "split", "microbatch",
              "virtual_stages", "zero1", "grad_compress", "seq_parallel"):
        assert getattr(hp2, f) == getattr(hp, f), f


def test_from_hparams_length_checks():
    hp = TrainHParams()
    with pytest.raises(ValueError, match="entries"):
        ParallelPlan.from_hparams(hp, 4, degrees=[2, 2])
    with pytest.raises(ValueError, match="entries"):
        ParallelPlan.from_hparams(hp, 4, schedules=["oases"] * 3)


def test_validate_for_config():
    cfg = get_config("internlm2-1.8b").reduced()
    p = ParallelPlan.from_hparams(TrainHParams(), cfg.num_layers)
    assert p.validate_for(cfg) is p
    bad = ParallelPlan.from_hparams(TrainHParams(), cfg.num_layers + 1)
    with pytest.raises(ValueError, match="layer strategies"):
        bad.validate_for(cfg)


def test_resolve_launch_desugars_flags(tmp_path):
    from repro.launch.mesh import resolve_launch
    cfg = get_config("internlm2-1.8b").reduced()
    hp = TrainHParams(schedule="megatron", split=1)
    out = tmp_path / "plan.json"
    mesh, plan, hp2 = resolve_launch(cfg, hp, mesh="auto",
                                     save_plan=str(out),
                                     log=lambda *_: None)
    assert plan.uniform_schedule == "megatron"
    assert plan.mesh_shape == tuple(mesh.shape.values())
    assert plan.mesh_axes == tuple(mesh.axis_names)
    # the saved file round-trips and drives a later --plan launch
    loaded = ParallelPlan.load(str(out))
    assert loaded == plan
    mesh2, plan2, hp3 = resolve_launch(cfg, TrainHParams(),
                                       plan_file=str(out),
                                       log=lambda *_: None)
    assert plan2 == plan
    assert tuple(mesh2.shape.values()) == tuple(mesh.shape.values())
    assert hp3.schedule == "megatron" and hp3.split == 1


def test_plan_save_creates_parent_dirs(tmp_path):
    # --save-plan into a not-yet-existing run directory must work (the
    # checkpoint dir is only created later, at train() time)
    p = ParallelPlan(layers=(LayerStrategy(8, "oases"),))
    out = tmp_path / "new" / "run" / "plan.json"
    p.save(str(out))
    assert ParallelPlan.load(str(out)) == p


# --------------------------------------------------------------------------
# checkpoint manifest metadata
# --------------------------------------------------------------------------
def test_manifest_plan_survives_save_load(tmp_path):
    from repro.checkpoint import store
    p = ParallelPlan(layers=(LayerStrategy(8, "oases"),
                             LayerStrategy(16, "wang")),
                     mesh_shape=(2, 8), mesh_axes=("data", "model"))
    tree = {"w": np.ones((2, 2), np.float32)}
    store.save(str(tmp_path), 3, tree, metadata={"plan": p.to_dict()})
    man = store.read_manifest(str(tmp_path), 3)
    assert ParallelPlan.from_dict(man["metadata"]["plan"]) == p
    _, meta = store.restore(str(tmp_path), 3, tree)
    assert ParallelPlan.from_dict(meta["plan"]) == p


# --------------------------------------------------------------------------
# planner attaches an executable plan
# --------------------------------------------------------------------------
def test_plan_result_carries_executable_plan():
    from repro.configs.base import SHAPES
    from repro.core.planner import plan
    cfg = get_config("whisper-small")
    r = plan(cfg, SHAPES["train_4k"], TrainHParams())
    assert r.plan is not None
    assert r.plan.num_layers == cfg.num_layers
    assert list(r.plan.degrees) == [d if isinstance(d, int) else tuple(d)
                                    for d in r.degrees]
    assert list(r.plan.schedules) == list(r.schedules)
    # a plan is always JSON-serializable
    assert ParallelPlan.from_json(r.plan.to_json()) == r.plan


# --------------------------------------------------------------------------
# cross-plan relayout (models/params.relayout_flat)
# --------------------------------------------------------------------------
def _fake_layers(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [{"['w']": rng.normal(size=(3, 2)).astype(np.float32),
             "['b']": rng.normal(size=(4,)).astype(np.float32)}
            for _ in range(cfg.num_layers)]


@pytest.mark.parametrize("src,dst", [
    ({}, {"degrees": [4, 2], "schedules": ["oases", "fused"]}),
    ({"degrees": [None, None], "schedules": ["oases", "megatron"]}, {}),
    ({"degrees": [2, 2], "schedules": ["wang", "wang"]},
     {"degrees": [8, 4], "schedules": ["oases", "oases"]}),
    ({"pp": 2, "virtual_stages": 1}, {}),
    ({}, {"pp": 2, "virtual_stages": 1}),
    ({"pp": 2, "virtual_stages": 1},
     {"degrees": [4, 4], "schedules": ["oases", "megatron"]}),
])
def test_relayout_flat_is_exact_inverse(src, dst):
    from repro.models import params as prm
    cfg = get_config("internlm2-1.8b").reduced()      # 2 layers
    per = _fake_layers(cfg)
    static = {"['embed']": np.arange(6, dtype=np.float32)}
    flat_src = prm.pack_layer_flat(cfg, static, per, **src)
    flat_dst = prm.relayout_flat(cfg, flat_src, src, dst)
    back = prm.relayout_flat(cfg, flat_dst, dst, src)
    assert set(back) == set(flat_src)
    for k in flat_src:
        np.testing.assert_array_equal(back[k], flat_src[k])
    # and the canonical per-layer decomposition is order-preserving
    _, per2 = prm.split_layer_flat(cfg, flat_dst, **dst)
    for a, b in zip(per, per2):
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_relayout_refuses_groups_without_plan():
    from repro.models import params as prm
    cfg = get_config("internlm2-1.8b").reduced()
    flat = {"['groups'][0]['w']": np.zeros((2, 3))}
    with pytest.raises(ValueError, match="no per-layer plan"):
        prm.split_layer_flat(cfg, flat)
