"""Serving engine on the 1-device mesh: continuous batching semantics."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    from repro.core import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    eng = ServingEngine(cfg, mesh, slots=2, max_seq=48)
    eng.load(seed=0)
    return eng


def test_more_requests_than_slots(engine):
    for i in range(5):
        engine.submit(Request(rid=i, prompt=np.arange(3, 8, dtype=np.int32),
                              max_new_tokens=4))
    stats = engine.run_until_drained()
    assert stats["admitted"] == 5
    assert stats["decoded_tokens"] >= 5          # eos may end early
    assert all(a is None for a in engine.active)


def test_greedy_determinism():
    from repro.core import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")

    def decode_once():
        eng = ServingEngine(cfg, mesh, slots=1, max_seq=32)
        eng.load(seed=0)
        r = Request(rid=0, prompt=np.arange(3, 8, dtype=np.int32),
                    max_new_tokens=6)
        eng.submit(r)
        eng.run_until_drained()
        return r.out_tokens

    assert decode_once() == decode_once()
