"""Serving engine on the 1-device mesh: continuous batching semantics."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    from repro.core import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    eng = ServingEngine(cfg, mesh, slots=2, max_seq=48)
    eng.load(seed=0)
    return eng


def test_more_requests_than_slots(engine):
    for i in range(5):
        engine.submit(Request(rid=i, prompt=np.arange(3, 8, dtype=np.int32),
                              max_new_tokens=4))
    stats = engine.run_until_drained()
    assert stats["admitted"] == 5
    assert stats["decoded_tokens"] >= 5          # eos may end early
    assert all(a is None for a in engine.active)


def test_greedy_determinism():
    from repro.core import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")

    def decode_once():
        eng = ServingEngine(cfg, mesh, slots=1, max_seq=32)
        eng.load(seed=0)
        r = Request(rid=0, prompt=np.arange(3, 8, dtype=np.int32),
                    max_new_tokens=6)
        eng.submit(r)
        eng.run_until_drained()
        return r.out_tokens

    assert decode_once() == decode_once()


def _mk_engine(**kw):
    from repro.core import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    eng = ServingEngine(cfg, mesh, **{"slots": 2, "max_seq": 48, **kw})
    eng.load(seed=0)
    return eng


def test_slot_exhaustion_backs_up_admission_queue():
    """More requests than slots: the surplus waits in the admission queue
    (not dropped, not over-admitted) and drains as slots free up."""
    eng = _mk_engine()
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(3, 6, dtype=np.int32),
                           max_new_tokens=3))
    eng.step()
    assert eng.stats["admitted"] == 2          # slot pool is the limit
    assert eng.queued == 3                     # backlog intact
    assert all(a is not None for a in eng.active)
    stats = eng.run_until_drained()
    assert stats["admitted"] == 5
    assert eng.queued == 0
    assert all(a is None for a in eng.active)


def test_eos_mid_batch_frees_slot_for_queued_request():
    """A sequence hitting EOS mid-batch releases its slot; the next queued
    request is admitted into it while the other slot keeps decoding."""
    # probe run: learn the greedy continuation, then re-run with eos_id
    # set to the second decoded token of request 0
    probe = _mk_engine()
    reqs = [Request(rid=i, prompt=np.arange(3 + i, 8 + i, dtype=np.int32),
                    max_new_tokens=8) for i in range(2)]
    for r in reqs:
        probe.submit(r)
    probe.run_until_drained()
    eos = reqs[0].out_tokens[1]
    if eos in (reqs[1].out_tokens or [eos]):
        # extremely unlikely on the random-init model; fall back to a
        # token only request 0 produces second
        eos = next((t for t in reqs[0].out_tokens
                    if t not in reqs[1].out_tokens), eos)

    eng = _mk_engine(eos_id=int(eos))
    r0 = Request(rid=0, prompt=np.arange(3, 8, dtype=np.int32),
                 max_new_tokens=8)
    r1 = Request(rid=1, prompt=np.arange(4, 9, dtype=np.int32),
                 max_new_tokens=8)
    r2 = Request(rid=2, prompt=np.arange(5, 10, dtype=np.int32),
                 max_new_tokens=8)
    for r in (r0, r1, r2):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert r0.done and r0.out_tokens[-1] == eos
    assert len(r0.out_tokens) < 8              # EOS cut generation short
    assert stats["admitted"] == 3              # r2 took the freed slot
    assert r1.done and r2.done


def test_prompt_longer_than_prefill_len_rejected_at_submit():
    eng = _mk_engine(prefill_len=8)
    assert eng.prefill_len == 8
    with pytest.raises(ValueError, match="prefill_len"):
        eng.submit(Request(rid=0,
                           prompt=np.arange(3, 12, dtype=np.int32)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(rid=1, prompt=np.zeros((0,), np.int32)))
    # boundary prompt admits and decodes fine
    r = Request(rid=2, prompt=np.arange(3, 11, dtype=np.int32),
                max_new_tokens=2)
    eng.submit(r)
    eng.run_until_drained()
    assert r.done and len(r.out_tokens) == 2


def test_prefill_len_derived_and_validated():
    eng = _mk_engine(max_seq=48)
    assert eng.prefill_len == 24               # derived: max_seq // 2
    with pytest.raises(ValueError, match="max_seq"):
        _mk_engine(max_seq=32, prefill_len=32)
    with pytest.raises(ValueError, match="max_seq"):
        _mk_engine(max_seq=32, prefill_len=0)


def test_donate_argnums_backend_branch(monkeypatch):
    """The KV cache is donated on accelerators only: the CPU backend
    ignores donation (and would warn every step), so the engine keys the
    donate_argnums off jax.default_backend()."""
    eng_cpu = _mk_engine()
    assert eng_cpu.donate_argnums == ()

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    eng_tpu = _mk_engine()
    assert eng_tpu.donate_argnums == (1,)
    monkeypatch.undo()

    # both engines decode the same tokens (donation is a memory
    # optimization, not a semantic change; XLA:CPU ignores the aliasing)
    def run(eng):
        r = Request(rid=0, prompt=np.arange(3, 8, dtype=np.int32),
                    max_new_tokens=4)
        eng.submit(r)
        eng.run_until_drained()
        return r.out_tokens

    assert run(eng_cpu) == run(eng_tpu)
