"""Unit + property tests for the TMP primitives (single-device: the
collective axes are empty tuples, which must degrade to identity)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import tmp as tmpc


def test_reduce_from_tmp_no_axes_identity():
    x = jnp.arange(6.0)
    np.testing.assert_array_equal(tmpc.reduce_from_tmp(x, ()), x)


def test_vocab_parallel_xent_matches_dense():
    k = jax.random.PRNGKey(0)
    t, d, v = 64, 32, 97
    x = jax.random.normal(k, (2, t // 2, d))
    head = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, t // 2), 0, v)
    loss_sum, count = tmpc.vocab_parallel_xent(x, head, labels, (), chunk=16)
    logits = (x.reshape(-1, d) @ head).astype(jnp.float32)
    dense = -jax.nn.log_softmax(logits)[jnp.arange(t), labels.reshape(-1)]
    np.testing.assert_allclose(float(loss_sum), float(jnp.sum(dense)),
                               rtol=1e-5)
    assert int(count) == t


def test_xent_gradient_matches_dense():
    k = jax.random.PRNGKey(3)
    t, d, v = 16, 8, 23
    x = jax.random.normal(k, (1, t, d))
    head = jax.random.normal(jax.random.PRNGKey(4), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(5), (1, t), 0, v)

    def ours(h):
        s, c = tmpc.vocab_parallel_xent(x, h, labels, (), chunk=5)
        return s / c

    def dense(h):
        logits = (x.reshape(-1, d) @ h).astype(jnp.float32)
        return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(t),
                                                    labels.reshape(-1)])

    g1 = jax.grad(ours)(head)
    g2 = jax.grad(dense)(head)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 64), st.integers(2, 40))
def test_xent_positive_and_bounded(t, v):
    x = jax.random.normal(jax.random.PRNGKey(t), (1, t, 8))
    head = jax.random.normal(jax.random.PRNGKey(v), (8, v))
    labels = jax.random.randint(jax.random.PRNGKey(7), (1, t), 0, v)
    s, c = tmpc.vocab_parallel_xent(x, head, labels, (), chunk=7)
    nll = float(s / c)
    assert 0.0 <= nll < 50.0


def test_softcap_bounds_logits_effect():
    x = jnp.ones((1, 4, 8)) * 100.0
    head = jnp.ones((8, 16))
    labels = jnp.zeros((1, 4), jnp.int32)
    s_cap, _ = tmpc.vocab_parallel_xent(x, head, labels, (), softcap=30.0)
    assert np.isfinite(float(s_cap))


def test_rms_norm_unit_output():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 13.0
    y = tmpc.rms_norm(x, jnp.zeros((64,)))
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1)
    np.testing.assert_allclose(ms, jnp.ones_like(ms), rtol=1e-3)
