"""Fused collective-matmul kernels: backend dispatch, single-device
degradation, interpret-mode tile microkernel numerics, and the 8-virtual-
device ring-vs-oracle + fused-vs-megatron equivalence subprocess."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_env

from repro.kernels import collective_matmul as cm

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# single-device degradation: empty axes -> plain matmul, no collectives
# --------------------------------------------------------------------------
def test_no_axes_is_plain_matmul():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 32))
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (32, 24))
    ref = jnp.dot(x, w)
    np.testing.assert_allclose(cm.fused_matmul_allreduce(x, w, ()), ref,
                               rtol=1e-6)
    np.testing.assert_allclose(
        cm.fused_matmul_reducescatter(x, w, (), 1), ref, rtol=1e-6)
    (o,) = cm.fused_allgather_matmul(x, (w,), (), 1)
    np.testing.assert_allclose(o, ref, rtol=1e-6)


def test_no_axes_gradients_match_dot():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (16, 12))

    def f_fused(x, w):
        return jnp.sum(jnp.tanh(cm.fused_matmul_reducescatter(x, w, (), 1)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(jnp.dot(x, w)))

    gf = jax.grad(f_fused, argnums=(0, 1))(x, w)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, w)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# backend dispatch rules
# --------------------------------------------------------------------------
def test_backend_selection():
    assert cm.backend((), 64) == "ref"                  # no axes
    assert cm.backend(("t1", "t2"), 64) == "ref"        # multi-axis group
    # single axis but outside a mesh context: axes_size would need a mesh,
    # so exercise via divisibility on a fake 1-sized axis is not possible
    # here; divisibility is covered by the subprocess (uneven shapes hit
    # the ring because they stay divisible by the ring size).


# --------------------------------------------------------------------------
# interpret-mode tile microkernel (the per-ring-step compute)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 64, 64, 128),
    (100, 200, 72, 32, 32, 64),          # uneven tiles, padded
    (33, 48, 17, 16, 16, 16),            # heavily uneven
])
def test_pallas_tile_matmul_sweep(dtype, m, k, n, bm, bn, bk):
    x = jax.random.normal(jax.random.PRNGKey(4), (m, k), dtype)
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (k, n)).astype(dtype)
    o = cm.pallas_tile_matmul(x, w, block_m=bm, block_n=bn, block_k=bk,
                              interpret=True)
    r = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------
# multi-device ring numerics + schedule equivalence (subprocess: needs 8
# virtual CPU devices, set before jax import)
# --------------------------------------------------------------------------
@pytest.mark.multidevice
@pytest.mark.slow
def test_fused_equivalence_subprocess():
    import os
    script = os.path.join(os.path.dirname(__file__), "_scripts",
                          "fused_equivalence.py")
    r = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, env=subprocess_env(), timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    lines = [ln for ln in r.stdout.splitlines()
             if ln.startswith(("PASS", "FAIL"))]
    assert lines, r.stdout
    bad = [ln for ln in lines if ln.startswith("FAIL")]
    assert not bad, "\n".join(bad)
