"""Friendly-validation units for mesh/degree spec parsing (launch/mesh.py).

``parse_mesh_spec`` is the pure parser (no device construction), so these
run on the 1-device tier; malformed specs must fail with the grammar in
the message instead of a deep axis-algebra crash."""
import pytest

from repro.launch.mesh import parse_degrees, parse_mesh_spec


# --------------------------------------------------------------------------
# mesh specs
# --------------------------------------------------------------------------
def test_parse_mesh_spec_accepts_1d_2d_and_pipeline():
    assert parse_mesh_spec("32x8") == ((32, 8), ("data", "model"))
    assert parse_mesh_spec("16x8x2") == ((16, 8, 2),
                                         ("data", "model_x", "model_y"))
    assert parse_mesh_spec("4x2", pp=2) == ((2, 4, 2),
                                            ("pipe", "data", "model"))
    assert parse_mesh_spec("1x2x2", pp=2) == (
        (2, 1, 2, 2), ("pipe", "data", "model_x", "model_y"))
    # pp=1 is a no-op, not a 1-sized axis
    assert parse_mesh_spec("4x2", pp=1) == ((4, 2), ("data", "model"))


@pytest.mark.parametrize("bad", ["8,4x2", "axb", "4x", "x4", "-2x4",
                                 "0x4", "4x2.5", "", "4"])
def test_parse_mesh_spec_rejects_malformed(bad):
    with pytest.raises(ValueError, match="mesh spec"):
        parse_mesh_spec(bad)


def test_parse_mesh_spec_too_many_components():
    with pytest.raises(ValueError, match="component"):
        parse_mesh_spec("2x2x2x2")


def test_parse_mesh_spec_bad_pp():
    with pytest.raises(ValueError, match="pipeline degree"):
        parse_mesh_spec("4x2", pp=-1)


def test_parse_mesh_spec_errors_name_the_offender():
    with pytest.raises(ValueError, match="component 'p'"):
        parse_mesh_spec("pxdxm")


# --------------------------------------------------------------------------
# degree specs
# --------------------------------------------------------------------------
def test_parse_degrees_accepts_1d_and_2d_entries():
    assert parse_degrees("8,4x2,16") == [8, (4, 2), 16]
    assert parse_degrees("1") == [1]
    assert parse_degrees(" 2 , 4x4 ") == [2, (4, 4)]


@pytest.mark.parametrize("bad", ["8,,2", "axb", "4x", "4x2x2", "-2",
                                 "0", "3x0", ""])
def test_parse_degrees_rejects_malformed(bad):
    with pytest.raises(ValueError, match="degree spec"):
        parse_degrees(bad)


@pytest.mark.parametrize("bad", ["3", "8,6x2", "5x4"])
def test_parse_degrees_rejects_non_power_of_two(bad):
    """Paper §4.2: partitioning degrees are powers of two — the axis
    algebra would otherwise crash deep in _log2_exact."""
    with pytest.raises(ValueError, match="powers of two"):
        parse_degrees(bad)


def test_dryrun_parse_degrees_is_the_validated_one():
    """launch/dryrun.py must route through the validated parser (without
    importing dryrun, which would set XLA device flags in-process)."""
    import ast
    import os
    src = open(os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro", "launch", "dryrun.py")).read()
    tree = ast.parse(src)
    fns = [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)
           and n.name == "parse_degrees"]
    assert fns and "from repro.launch.mesh import" in ast.get_source_segment(
        src, fns[0])
