"""Required per-arch smoke tests: REDUCED config, one forward/train step on
CPU, assert output shapes + no NaNs.  (Full configs are exercised only via
the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import ASSIGNED, get_config
from repro.models import lm
from repro.models import params as prm


def _batch(cfg, b=2, s=32):
    k = jax.random.PRNGKey(7)
    out = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.context_len:
        out["ctx"] = 0.02 * jax.random.normal(
            k, (b, cfg.context_len, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch, smoke_mesh):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    hp = TrainHParams(schedule="oases", fine_remat=True)
    loss_fn, specs, _ = lm.build_train_loss(cfg, smoke_mesh, hp,
                                            global_batch=2, seq_len=32)
    params = prm.init_params(specs, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with compat.set_mesh(smoke_mesh):
        (loss, aux), grads = jax.jit(
            jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert gn > 0 and not jnp.isnan(gn)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_smoke(arch, smoke_mesh):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    hp = TrainHParams()
    b, s = 2, 32
    pf, specs, st_specs = lm.build_prefill(cfg, smoke_mesh, hp,
                                           global_batch=b, seq_len=s)
    df, _, _ = lm.build_decode(cfg, smoke_mesh, hp, global_batch=b,
                               seq_len=s)
    params = prm.init_params(specs, jax.random.PRNGKey(0))
    batch = {k: v for k, v in _batch(cfg, b, s).items() if k != "labels"}
    with compat.set_mesh(smoke_mesh):
        tok, state = jax.jit(pf)(params, batch)
        tok2, state2 = jax.jit(df)(params, state, tok,
                                   jnp.full((b,), s - 1, jnp.int32))
    assert tok.shape == (b,) and tok2.shape == (b,)
    assert int(tok.max()) < cfg.padded_vocab()
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(state2))
    for l1, l2 in zip(jax.tree_util.tree_leaves(state),
                      jax.tree_util.tree_leaves(state2)):
        assert l1.shape == l2.shape
        assert not bool(jnp.any(jnp.isnan(l2.astype(jnp.float32))))
