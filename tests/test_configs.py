import pytest

from repro.configs.registry import ASSIGNED, all_cells, get_config


def test_all_assigned_archs_present():
    assert len(ASSIGNED) == 10
    for a in ASSIGNED:
        cfg = get_config(a)
        assert cfg.num_layers > 0 and cfg.d_model > 0


def test_cell_count_is_40():
    cells = all_cells()
    assert len(cells) == 40


def test_long500k_only_subquadratic():
    for cfg, shape, applicable in all_cells():
        if shape.name == "long_500k":
            assert applicable == cfg.sub_quadratic


@pytest.mark.parametrize("arch,expected_b", [
    ("internlm2-20b", 19.0e9), ("granite-8b", 8.0e9),
    ("internlm2-1.8b", 1.8e9), ("gemma2-9b", 9.0e9),
    # NOTE: the assigned spec (48L x 64e x d_ff 1408) yields ~28B total;
    # the HF model's 16B comes from 27 layers — we implement the spec as
    # assigned (DESIGN.md).
    ("moonshot-v1-16b-a3b", 28.0e9), ("mamba2-130m", 0.13e9),
])
def test_param_counts_near_nameplate(arch, expected_b):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert 0.55 * expected_b < n < 1.6 * expected_b, (arch, n)


def test_moe_active_params_below_total():
    cfg = get_config("moonshot-v1-16b-a3b")
    assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_vocab_padding_divisible():
    for a in ASSIGNED:
        assert get_config(a).padded_vocab() % 256 == 0


def test_reduced_configs_small():
    for a in ASSIGNED:
        r = get_config(a).reduced()
        assert r.d_model <= 128 and r.num_layers <= get_config(a).num_layers
