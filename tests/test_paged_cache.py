"""Paged KV cache edge cases: fragmentation, COW divergence mid-block,
prefix hits shorter/longer than a block, cache-full admission
backpressure, and slot-release leak accounting.

The host-side allocator tests need no JAX; the engine-level tests run the
reduced 1.8B on a 1-device mesh like tests/test_serving.py.
"""
import numpy as np
import pytest

from repro.serving.paged_cache import PagedKVCache


def _cache(**kw):
    return PagedKVCache(**{"pages": 16, "page_size": 4, "slots": 2,
                           "max_seq": 16, "prefix_cache": True, **kw})


# ----------------------------------------------------------------------
# allocator / release accounting
# ----------------------------------------------------------------------

def test_release_returns_every_page():
    pc = _cache(prefix_cache=False)
    pc.admit(0, prompt_len=6, max_new=8)
    pc.ensure_writable(0, 0, 9)           # maps blocks 0..2
    assert pc.mapped(0) == 3
    assert pc.free_pages == pc.pages - 1 - 3
    pc.release(0)
    assert pc.mapped(0) == 0
    assert pc.free_pages == pc.pages - 1   # no leak
    pc.check()


def test_release_keeps_index_shared_pages_alive():
    pc = _cache()
    pc.admit(0, prompt_len=6, max_new=2)
    pc.ensure_writable(0, 0, 5)
    prompt = np.arange(10, 16, dtype=np.int32)
    pc.insert(0, prompt)                   # index takes its own refs
    pc.release(0)
    pc.check()
    # the two prompt blocks survive in the index, not the free list
    assert pc.index_size == 2
    assert pc.free_pages == pc.pages - 1 - 2
    pages, span = pc.lookup(prompt)
    assert span == 6 and len(pages) == 2


def test_fragmented_free_list_after_mixed_length_release():
    """Mixed-length slots released out of order fragment the free list;
    subsequent admissions map non-contiguous physical pages and the
    accounting audit still balances."""
    pc = PagedKVCache(pages=12, page_size=4, slots=3, max_seq=16)
    lens = {0: 14, 1: 3, 2: 9}             # 4, 1 and 3 blocks
    for s, ln in lens.items():
        pc.admit(s, prompt_len=ln, max_new=0)
        pc.ensure_writable(s, 0, ln - 1)
    assert pc.free_pages == 11 - 8
    pc.release(1)                          # middle slot first
    pc.release(0)
    pc.check()
    # re-admit into the fragmented pool: pages come back in release order,
    # so the new slot's table is physically non-contiguous
    pc.admit(0, prompt_len=14, max_new=1)
    pc.ensure_writable(0, 0, 13)
    row = [int(p) for p in pc.table[0] if p]
    assert len(row) == 4
    assert row != sorted(row)              # genuinely fragmented
    pc.check()
    pc.release(0)
    pc.release(2)
    assert pc.free_pages == 11
    pc.check()


def test_pool_exhaustion_is_loud():
    pc = PagedKVCache(pages=5, page_size=4, slots=1, max_seq=16)
    pc.admit(0, prompt_len=16, max_new=0)
    pc.ensure_writable(0, 0, 15)           # all 4 allocatable pages
    pc2 = PagedKVCache(pages=5, page_size=4, slots=2, max_seq=16)
    pc2.admit(0, prompt_len=16, max_new=0)
    pc2.ensure_writable(0, 0, 15)
    with pytest.raises(RuntimeError, match="exhausted"):
        pc2.ensure_writable(1, 0, 0)


# ----------------------------------------------------------------------
# copy-on-write
# ----------------------------------------------------------------------

def test_cow_on_shared_tail_block():
    """A reader that diverges mid-block must not scribble on the donor's
    page: the first write into a shared block swaps in a fresh page and
    reports the (src, dst) copy."""
    pc = _cache()
    prompt = np.arange(20, 26, dtype=np.int32)      # 6 tokens: 1 full + tail
    pc.admit(0, prompt_len=6, max_new=4)
    pc.ensure_writable(0, 0, 5)
    pc.insert(0, prompt)
    pages, span = pc.lookup(prompt)
    assert span == 6
    pc.admit(1, prompt_len=6, max_new=4, shared=pages)
    shared_tail = int(pc.table[1, 1])
    assert shared_tail == int(pc.table[0, 1])       # same physical page
    assert pc.ref[shared_tail] == 3                 # slot0 + slot1 + index

    # slot 1 writes position 5 (inside the shared tail block) -> COW
    cow = pc.ensure_writable(1, 5, 5)
    assert len(cow) == 1 and cow[0][0] == shared_tail
    assert int(pc.table[1, 1]) == cow[0][1] != shared_tail
    assert pc.ref[shared_tail] == 2                 # slot1 dropped its ref
    assert pc.stats["cow"] == 1
    pc.check()

    # writing again into the now-exclusive page is free
    assert pc.ensure_writable(1, 5, 7) == []
    pc.check()


def test_no_cow_for_exclusive_blocks():
    pc = _cache(prefix_cache=False)
    pc.admit(0, prompt_len=8, max_new=4)
    pc.ensure_writable(0, 0, 7)
    assert pc.ensure_writable(0, 0, 7) == []
    assert pc.stats["cow"] == 0


# ----------------------------------------------------------------------
# prefix index granularity
# ----------------------------------------------------------------------

def test_prefix_hit_shorter_than_a_block():
    """A 3-token prompt with page_size=4 lives entirely in a tail entry;
    an identical prompt hits the full 3-token span."""
    pc = _cache()
    prompt = np.array([7, 8, 9], np.int32)
    pc.admit(0, prompt_len=3, max_new=2)
    pc.ensure_writable(0, 0, 2)
    pc.insert(0, prompt)
    pages, span = pc.lookup(prompt)
    assert span == 3 and len(pages) == 1
    # a shorter query is a *different* tail key: no partial-tail hit
    _, span2 = pc.lookup(prompt[:2])
    assert span2 == 0


def test_prefix_hit_longer_than_a_block():
    """A 10-token prompt spans 2 full blocks + a 2-token tail; lookups hit
    at every granularity the chain records."""
    pc = _cache()
    prompt = np.arange(40, 50, dtype=np.int32)
    pc.admit(0, prompt_len=10, max_new=2)
    pc.ensure_writable(0, 0, 9)
    pc.insert(0, prompt)
    pages, span = pc.lookup(prompt)
    assert span == 10 and len(pages) == 3           # 2 full + tail
    # a query that only shares the full blocks hits the 8-token span
    other = np.concatenate([prompt[:8], np.array([99, 98], np.int32)])
    pages8, span8 = pc.lookup(other)
    assert span8 == 8 and len(pages8) == 2
    # a query diverging inside block 0 misses entirely
    div = prompt.copy()
    div[1] = 77
    _, span0 = pc.lookup(div)
    assert span0 == 0


def test_lru_eviction_is_leaf_first():
    """Evicting to free pages drops LRU *leaves*, never an interior chain
    block — every surviving chain stays reachable from block 0."""
    pc = PagedKVCache(pages=8, page_size=4, slots=1, max_seq=16,
                      prefix_cache=True)
    long = np.arange(60, 72, dtype=np.int32)        # 3 blocks
    pc.admit(0, prompt_len=12, max_new=0)
    pc.ensure_writable(0, 0, 11)
    pc.insert(0, long)
    pc.release(0)
    assert pc.index_size == 3 and pc.free_pages == 4
    # demand 6 fresh pages: two LRU leaves must be evicted, root survives
    pc.admit(0, prompt_len=16, max_new=0)
    pc.ensure_writable(0, 0, 15)                    # needs 4, free has 4
    pc.release(0)
    pc._evict(need=6)
    pc.check()
    assert pc.stats["evicted"] == 2
    pages, span = pc.lookup(long)
    assert span == 4 and len(pages) == 1            # root block still hits


# ----------------------------------------------------------------------
# admission reservations / engine backpressure
# ----------------------------------------------------------------------

def test_can_admit_reserves_for_active_slots():
    pc = PagedKVCache(pages=7, page_size=8, slots=2, max_seq=48)
    assert pc.can_admit(10, 16)                     # 4 blocks, 6 free
    pc.admit(0, prompt_len=10, max_new=16)
    # slot 0's outstanding worst case (4 pages, none mapped yet) counts
    assert not pc.can_admit(10, 16)
    assert pc.can_admit(10, 4)                      # 2 blocks still fit
    pc.ensure_writable(0, 0, 25)                    # slot 0 fully mapped
    assert not pc.can_admit(10, 16)                 # only 2 pages left


def _mk_paged_engine(**kw):
    from repro.configs.registry import get_config
    from repro.core import compat
    from repro.serving import ServingEngine
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    eng = ServingEngine(cfg, mesh, **{"slots": 2, "max_seq": 48,
                                      "paged": True, "page_size": 8, **kw})
    eng.load(seed=0)
    return eng


def test_cache_full_admission_backpressure():
    """A pool sized for one big request parks the second in the one-deep
    pending buffer (FIFO preserved) until the first releases its pages."""
    from repro.serving import Request
    eng = _mk_paged_engine(pages=7)                 # 6 allocatable pages
    reqs = [Request(rid=i, prompt=np.arange(3 + i, 13 + i, dtype=np.int32),
                    max_new_tokens=16) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    # each request reserves ceil((10+16+1)/8) = 4 pages: only one fits
    assert eng.stats["admitted"] == 1
    assert eng._pending is reqs[1]                  # parked, not dropped
    assert eng.queued == 2
    stats = eng.run_until_drained()
    assert stats["admitted"] == 3
    assert all(r.done and len(r.out_tokens) >= 1 for r in reqs)
    assert eng._pending is None and eng.queued == 0
    assert eng.paged.free_pages == eng.paged.pages - 1   # drained clean


def test_pending_request_admits_before_later_arrivals():
    """The parked request keeps its place at the head of the line."""
    from repro.serving import Request
    eng = _mk_paged_engine(pages=7)
    r0 = Request(rid=0, prompt=np.arange(3, 13, dtype=np.int32),
                 max_new_tokens=16)
    r1 = Request(rid=1, prompt=np.arange(4, 14, dtype=np.int32),
                 max_new_tokens=16)
    r2 = Request(rid=2, prompt=np.arange(5, 15, dtype=np.int32),
                 max_new_tokens=16)
    for r in (r0, r1, r2):
        eng.submit(r)
    eng.step()
    assert eng._pending is r1
    while not r1.done and eng.stats["steps"] < 200:
        eng.step()
        if eng.active[0] is not None and eng.active[0].rid == 2:
            raise AssertionError("r2 overtook the parked r1")
        if eng.active[1] is not None and eng.active[1].rid == 2 \
                and not (r1.done or any(
                    a is not None and a.rid == 1 for a in eng.active)):
            raise AssertionError("r2 overtook the parked r1")
    eng.run_until_drained()
    assert r0.done and r1.done and r2.done


def test_paged_prefix_engine_matches_dense_mid_block_divergence():
    """End-to-end: two requests share a prefix and diverge mid-block; the
    paged+prefix engine (COW path) emits exactly the dense engine's
    tokens."""
    from repro.serving import Request

    base = np.arange(3, 13, dtype=np.int32)         # 10 tokens, ps=8
    fork = base.copy()
    fork[9] = 99                                    # diverges inside block 1

    def run(**kw):
        eng = _mk_paged_engine(**kw) if kw else None
        if eng is None:
            from repro.configs.registry import get_config
            from repro.core import compat
            from repro.serving import ServingEngine
            mesh = compat.make_mesh((1, 1), ("data", "model"),
                                    axis_types=compat.auto_axis_types(2))
            cfg = get_config("internlm2-1.8b").reduced().replace(
                dtype="float32")
            eng = ServingEngine(cfg, mesh, slots=2, max_seq=48)
            eng.load(seed=0)
        reqs = [Request(rid=0, prompt=base, max_new_tokens=6),
                Request(rid=1, prompt=fork, max_new_tokens=6),
                Request(rid=2, prompt=base.copy(), max_new_tokens=6)]
        for r in reqs:
            eng.submit(r)
        stats = eng.run_until_drained()
        return [r.out_tokens for r in reqs], stats

    dense, _ = run()
    paged, pstats = run(prefix_cache=True)
    assert paged == dense
    assert pstats["prefix_hits"] >= 1               # rid=1/2 reused blocks
    assert pstats["paged"]["cow"] >= 1              # divergence forced COW
