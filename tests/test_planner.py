"""Oases planner: ILP validity, memory constraint behaviour, cost-model
monotonicity, solve latency (paper: sub-second, Table 6)."""
import time

import pytest

from repro.configs.base import SHAPES, TrainHParams
from repro.configs.registry import get_config
from repro.core.planner import V5E, estimate_iteration, overlapped_time, plan
from repro.core.planner.costmodel import HWConfig


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-9b",
                                  "granite-8b"])
def test_plan_valid_degrees(arch):
    cfg = get_config(arch)
    r = plan(cfg, SHAPES["train_4k"], TrainHParams())
    assert len(r.degrees) == cfg.num_layers
    assert all(d in (2, 4, 8, 16) for d in r.degrees)
    assert r.predicted_s > 0


def test_plan_solve_time_subsecond():
    cfg = get_config("internlm2-20b")           # largest layer count (48)
    t0 = time.time()
    r = plan(cfg, SHAPES["train_4k"], TrainHParams())
    assert time.time() - t0 < 10.0
    assert r.solve_ms < 10_000


def test_tighter_memory_pushes_degrees_up():
    cfg = get_config("granite-8b")
    hp = TrainHParams()
    loose = plan(cfg, SHAPES["train_4k"], hp, mem_cap=64e9)
    tight = plan(cfg, SHAPES["train_4k"], hp, mem_cap=8e9)
    assert sum(tight.degrees) >= sum(loose.degrees)


def test_cost_model_comm_grows_with_degree():
    cfg = get_config("internlm2-1.8b")
    hp = TrainHParams()
    est = {d: estimate_iteration(cfg, SHAPES["train_4k"], hp,
                                 [d] * cfg.num_layers)
           for d in (2, 4, 8, 16)}
    # memory per chip shrinks with degree; iteration time grows for the
    # comm-heavy high degrees
    assert est[16]["mem_bytes"] <= est[2]["mem_bytes"]
    assert est[16]["iter_s"] >= est[2]["iter_s"]


def test_overlap_schedule_faster_than_blocking():
    cfg = get_config("internlm2-1.8b")
    d = [8] * cfg.num_layers
    t_oases = estimate_iteration(cfg, SHAPES["train_4k"],
                                 TrainHParams(schedule="oases"), d)
    t_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                               TrainHParams(schedule="megatron"), d)
    assert t_oases["iter_s"] < t_meg["iter_s"]


def test_fine_remat_cheaper_backward_comm():
    cfg = get_config("internlm2-1.8b")
    d = [8] * cfg.num_layers
    fine = estimate_iteration(cfg, SHAPES["train_4k"],
                              TrainHParams(schedule="megatron",
                                           fine_remat=True), d)
    coarse = estimate_iteration(cfg, SHAPES["train_4k"],
                                TrainHParams(schedule="megatron",
                                             fine_remat=False), d)
    assert fine["bwd_s"] < coarse["bwd_s"]


def test_mixed_plan_on_memory_cliff():
    """With a cap between uniform-low and uniform-high memory, the ILP must
    pick a mixed (or higher-degree) plan that fits."""
    cfg = get_config("granite-8b")
    hp = TrainHParams()
    e2 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [2] * cfg.num_layers)["mem_bytes"]
    e16 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                             [16] * cfg.num_layers)["mem_bytes"]
    cap = (e2 + e16) / 2
    r = plan(cfg, SHAPES["train_4k"], hp, mem_cap=cap)
    est = estimate_iteration(cfg, SHAPES["train_4k"], hp, r.degrees)
    assert est["mem_bytes"] < cap * 1.05


@pytest.mark.parametrize("arch", ["internlm2-20b", "recurrentgemma-9b",
                                  "moonshot-v1-16b-a3b", "whisper-small",
                                  "mamba2-130m", "llama-3.2-vision-11b"])
def test_plan_every_family(arch):
    """The planner must produce a valid plan for every assigned family
    (attention-free and MoE blocks model as compute-only / EP nodes)."""
    cfg = get_config(arch)
    r = plan(cfg, SHAPES["train_4k"], TrainHParams(), time_limit=30.0)
    assert len(r.degrees) == cfg.num_layers
    assert all(d in (2, 4, 8, 16) for d in r.degrees)


def test_overlapped_time_is_max_plus_fill():
    """The fused node cost is max(T_comm, T_compute) per tile-ring plus one
    ring step of pipeline fill — never the serial sum."""
    d, c = 3.0, 2.0
    t = overlapped_time(d, c, ring_steps=4)
    assert t == pytest.approx(max(d, c) + min(d, c) / 4)
    assert max(d, c) <= t < d + c
    # fully comm-bound and fully compute-bound degenerate symmetrically
    assert overlapped_time(5.0, 0.0, 8) == 5.0
    assert overlapped_time(0.0, 5.0, 8) == 5.0
    # more ring steps -> less exposed fill
    assert overlapped_time(d, c, 16) < overlapped_time(d, c, 2)


def test_fused_schedule_beats_blocking_in_cost_model():
    """Fused nodes cost max{} instead of sum — strictly cheaper than the
    blocking schedule at every degree, with a gap that grows with degree
    (higher degree => more comm to hide)."""
    cfg = get_config("internlm2-1.8b")
    gaps = {}
    for dg in (2, 8, 16):
        d = [dg] * cfg.num_layers
        t_fused = estimate_iteration(cfg, SHAPES["train_4k"],
                                     TrainHParams(schedule="fused"), d)
        t_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                                   TrainHParams(schedule="megatron"), d)
        # a degree-2 ring has a single transfer (nothing to pipeline
        # against inside the ring), so fused == blocking there; beyond
        # that the hidden comm is a strict win
        assert t_fused["iter_s"] <= t_meg["iter_s"]
        gaps[dg] = t_meg["iter_s"] - t_fused["iter_s"]
    assert gaps[8] > 0 and gaps[16] > 0
    assert gaps[16] > gaps[8]


def test_plan_with_fused_schedule():
    """The ILP linearizes the fused max{} term; plans must stay valid and
    predict no worse than the same plan under megatron."""
    cfg = get_config("granite-8b")
    r = plan(cfg, SHAPES["train_4k"], TrainHParams(schedule="fused"))
    assert len(r.degrees) == cfg.num_layers
    assert all(dg in (2, 4, 8, 16) for dg in r.degrees)
    est_fused = estimate_iteration(cfg, SHAPES["train_4k"],
                                   TrainHParams(schedule="fused"), r.degrees)
    est_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                                 TrainHParams(schedule="megatron"), r.degrees)
    assert est_fused["iter_s"] <= est_meg["iter_s"]


def test_estimate_all_shapes():
    cfg = get_config("recurrentgemma-9b")
    hp = TrainHParams()
    for sname in ("train_4k", "prefill_32k"):
        est = estimate_iteration(cfg, SHAPES[sname], hp,
                                 [16] * cfg.num_layers)
        assert est["iter_s"] > 0 and est["tokens_per_s"] > 0
