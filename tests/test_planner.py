"""Oases planner: ILP validity, memory constraint behaviour, cost-model
monotonicity, solve latency (paper: sub-second, Table 6), and the
Planner-v2 2D hybrid-partition search space."""
import time

import pytest

from repro.configs.base import SHAPES, TrainHParams
from repro.configs.registry import get_config
from repro.core.planner import (estimate_iteration, expand_options,
                                overlapped_time, plan)
from repro.core.planner.costmodel import HWConfig


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-9b",
                                  "granite-8b"])
def test_plan_valid_degrees(arch):
    cfg = get_config(arch)
    r = plan(cfg, SHAPES["train_4k"], TrainHParams())
    assert len(r.degrees) == cfg.num_layers
    assert all(d in (2, 4, 8, 16) for d in r.degrees)
    assert r.predicted_s > 0


def test_plan_solve_time_subsecond():
    cfg = get_config("internlm2-20b")           # largest layer count (48)
    t0 = time.time()
    r = plan(cfg, SHAPES["train_4k"], TrainHParams())
    assert time.time() - t0 < 10.0
    assert r.solve_ms < 10_000


def test_tighter_memory_pushes_degrees_up():
    cfg = get_config("granite-8b")
    hp = TrainHParams()
    loose = plan(cfg, SHAPES["train_4k"], hp, mem_cap=64e9)
    tight = plan(cfg, SHAPES["train_4k"], hp, mem_cap=8e9)
    assert sum(tight.degrees) >= sum(loose.degrees)


def test_cost_model_comm_grows_with_degree():
    cfg = get_config("internlm2-1.8b")
    hp = TrainHParams()
    est = {d: estimate_iteration(cfg, SHAPES["train_4k"], hp,
                                 [d] * cfg.num_layers)
           for d in (2, 4, 8, 16)}
    # memory per chip shrinks with degree; iteration time grows for the
    # comm-heavy high degrees
    assert est[16]["mem_bytes"] <= est[2]["mem_bytes"]
    assert est[16]["iter_s"] >= est[2]["iter_s"]


def test_overlap_schedule_faster_than_blocking():
    cfg = get_config("internlm2-1.8b")
    d = [8] * cfg.num_layers
    t_oases = estimate_iteration(cfg, SHAPES["train_4k"],
                                 TrainHParams(schedule="oases"), d)
    t_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                               TrainHParams(schedule="megatron"), d)
    assert t_oases["iter_s"] < t_meg["iter_s"]


def test_fine_remat_cheaper_backward_comm():
    cfg = get_config("internlm2-1.8b")
    d = [8] * cfg.num_layers
    fine = estimate_iteration(cfg, SHAPES["train_4k"],
                              TrainHParams(schedule="megatron",
                                           fine_remat=True), d)
    coarse = estimate_iteration(cfg, SHAPES["train_4k"],
                                TrainHParams(schedule="megatron",
                                             fine_remat=False), d)
    assert fine["bwd_s"] < coarse["bwd_s"]


def test_mixed_plan_on_memory_cliff():
    """With a cap between uniform-low and uniform-high memory, the ILP must
    pick a mixed (or higher-degree) plan that fits."""
    cfg = get_config("granite-8b")
    hp = TrainHParams()
    e2 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [2] * cfg.num_layers)["mem_bytes"]
    e16 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                             [16] * cfg.num_layers)["mem_bytes"]
    cap = (e2 + e16) / 2
    r = plan(cfg, SHAPES["train_4k"], hp, mem_cap=cap)
    est = estimate_iteration(cfg, SHAPES["train_4k"], hp, r.degrees)
    assert est["mem_bytes"] < cap * 1.05


@pytest.mark.parametrize("arch", ["internlm2-20b", "recurrentgemma-9b",
                                  "moonshot-v1-16b-a3b", "whisper-small",
                                  "mamba2-130m", "llama-3.2-vision-11b"])
def test_plan_every_family(arch):
    """The planner must produce a valid plan for every assigned family
    (attention-free and MoE blocks model as compute-only / EP nodes)."""
    cfg = get_config(arch)
    r = plan(cfg, SHAPES["train_4k"], TrainHParams(), time_limit=30.0)
    assert len(r.degrees) == cfg.num_layers
    assert all(d in (2, 4, 8, 16) for d in r.degrees)


def test_overlapped_time_is_max_plus_fill():
    """The fused node cost is max(T_comm, T_compute) per tile-ring plus one
    ring step of pipeline fill — never the serial sum."""
    d, c = 3.0, 2.0
    t = overlapped_time(d, c, ring_steps=4)
    assert t == pytest.approx(max(d, c) + min(d, c) / 4)
    assert max(d, c) <= t < d + c
    # fully comm-bound and fully compute-bound degenerate symmetrically
    assert overlapped_time(5.0, 0.0, 8) == 5.0
    assert overlapped_time(0.0, 5.0, 8) == 5.0
    # more ring steps -> less exposed fill
    assert overlapped_time(d, c, 16) < overlapped_time(d, c, 2)


def test_fused_schedule_beats_blocking_in_cost_model():
    """Fused nodes cost max{} instead of sum — strictly cheaper than the
    blocking schedule at every degree, with a gap that grows with degree
    (higher degree => more comm to hide)."""
    cfg = get_config("internlm2-1.8b")
    gaps = {}
    for dg in (2, 8, 16):
        d = [dg] * cfg.num_layers
        t_fused = estimate_iteration(cfg, SHAPES["train_4k"],
                                     TrainHParams(schedule="fused"), d)
        t_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                                   TrainHParams(schedule="megatron"), d)
        # a degree-2 ring has a single transfer (nothing to pipeline
        # against inside the ring), so fused == blocking there; beyond
        # that the hidden comm is a strict win
        assert t_fused["iter_s"] <= t_meg["iter_s"]
        gaps[dg] = t_meg["iter_s"] - t_fused["iter_s"]
    assert gaps[8] > 0 and gaps[16] > 0
    assert gaps[16] > gaps[8]


def test_plan_with_fused_schedule():
    """The ILP linearizes the fused max{} term; plans must stay valid and
    predict no worse than the same plan under megatron."""
    cfg = get_config("granite-8b")
    r = plan(cfg, SHAPES["train_4k"], TrainHParams(schedule="fused"))
    assert len(r.degrees) == cfg.num_layers
    assert all(dg in (2, 4, 8, 16) for dg in r.degrees)
    est_fused = estimate_iteration(cfg, SHAPES["train_4k"],
                                   TrainHParams(schedule="fused"), r.degrees)
    est_meg = estimate_iteration(cfg, SHAPES["train_4k"],
                                 TrainHParams(schedule="megatron"), r.degrees)
    assert est_fused["iter_s"] <= est_meg["iter_s"]


def test_estimate_all_shapes():
    cfg = get_config("recurrentgemma-9b")
    hp = TrainHParams()
    for sname in ("train_4k", "prefill_32k"):
        est = estimate_iteration(cfg, SHAPES[sname], hp,
                                 [16] * cfg.num_layers)
        assert est["iter_s"] > 0 and est["tokens_per_s"] > 0


# --------------------------------------------------------------------------
# Planner v2: 2D hybrid partitions
# --------------------------------------------------------------------------
def test_expand_options_spaces():
    cfg = get_config("internlm2-1.8b")
    hw = HWConfig(n_chips=16, node_size=8)
    one_d = expand_options(cfg, hw, (2, 4, 8, 16), "1d")
    assert one_d == [2, 4, 8, 16]
    auto = expand_options(cfg, hw, (2, 4, 8, 16), "auto")
    assert set(one_d) <= set(a for a in auto if isinstance(a, int))
    for o in auto:
        if isinstance(o, tuple):
            dx, dy = o
            assert dx * dy in one_d
            assert dx <= hw.node_size          # x-ring stays intra-node
            assert cfg.d_model % dy == 0
    two_d = expand_options(cfg, hw, (2, 4, 8, 16), "2d")
    assert all(isinstance(o, tuple) for o in two_d)
    assert (16, 1) in two_d                    # 1D-equivalent degenerate
    assert (16, 2) not in two_d                # dx must stay intra-node


def test_estimate_iteration_accepts_tuple_degrees():
    cfg = get_config("internlm2-1.8b")
    hp = TrainHParams(schedule="fused")
    e1 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [8] * cfg.num_layers)
    e2 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [(8, 1)] * cfg.num_layers)
    assert e1["iter_s"] == pytest.approx(e2["iter_s"], rel=1e-9)
    e3 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [(4, 2)] * cfg.num_layers)
    assert e3["iter_s"] > 0
    # same total degree -> same parameter memory
    assert e3["mem_bytes"] == pytest.approx(e1["mem_bytes"], rel=1e-6)


def test_y_traffic_charged_at_inter_node_bandwidth():
    """2D comm splits per axis: throttling only the inter-node (y) links
    must slow a (dx, dy>1) node but leave pure-1D intra-node plans alone."""
    cfg = get_config("internlm2-1.8b")
    hp = TrainHParams(schedule="fused")
    fast = HWConfig(n_chips=16, node_size=8, link_bw_x=100e9,
                    link_bw_y=100e9)
    slow_y = HWConfig(n_chips=16, node_size=8, link_bw_x=100e9,
                      link_bw_y=2e9)
    d2 = [(8, 2)] * cfg.num_layers
    d1 = [8] * cfg.num_layers
    assert estimate_iteration(cfg, SHAPES["train_4k"], hp, d2, slow_y)["iter_s"] \
        > estimate_iteration(cfg, SHAPES["train_4k"], hp, d2, fast)["iter_s"]
    assert estimate_iteration(cfg, SHAPES["train_4k"], hp, d1, slow_y)["iter_s"] \
        == pytest.approx(
            estimate_iteration(cfg, SHAPES["train_4k"], hp, d1, fast)["iter_s"],
            rel=1e-9)


def test_1d_ring_spanning_nodes_pays_nic_bandwidth():
    """AMP-style heterogeneity: a 16-way 1D ring over two 8-chip nodes is
    bottlenecked by the inter-node hop, so the hybrid (8,2) plan must be
    strictly cheaper there."""
    cfg = get_config("internlm2-1.8b")
    hp = TrainHParams(schedule="oases")
    hetero = HWConfig(n_chips=16, node_size=8, link_bw_x=100e9,
                      link_bw_y=2e9)
    t1 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [16] * cfg.num_layers, hetero)["iter_s"]
    t2 = estimate_iteration(cfg, SHAPES["train_4k"], hp,
                            [(8, 2)] * cfg.num_layers, hetero)["iter_s"]
    assert t2 < t1


def test_plan_layout_2d_valid_and_no_worse():
    cfg = get_config("granite-8b")
    hp = TrainHParams(schedule="fused")
    hw = HWConfig(n_chips=16, node_size=8, link_bw_x=100e9, link_bw_y=2e9)
    p1 = plan(cfg, SHAPES["train_4k"], hp, hw, layout="1d")
    p2 = plan(cfg, SHAPES["train_4k"], hp, hw, layout="auto")
    assert len(p2.degrees) == cfg.num_layers
    assert p2.predicted_s <= p1.predicted_s * (1 + 1e-9)
    pf = plan(cfg, SHAPES["train_4k"], hp, hw, layout="2d")
    assert all(isinstance(d, tuple) for d in pf.degrees)
