"""Telemetry subsystem unit tier (src/repro/obs/).

Covers the PR-8 acceptance list: JSONL schema round-trip, ring-buffer
eviction, histogram percentiles, the disabled-mode overhead guard, the
overlap-probe residual math on synthetic group models, straggler
localization from enriched peer heartbeats, and the schedule-phase
named scopes surviving into compiled HLO.
"""
import json
import os
import time

import pytest

from repro.obs import recorder as rec_mod
from repro.obs.recorder import NULL, Recorder
from repro.obs.schema import SchemaError, validate_lines, validate_record


@pytest.fixture(autouse=True)
def _isolate_global_recorder():
    """Tests that install a global recorder must not leak it."""
    prev = rec_mod.get_recorder()
    yield
    rec_mod.set_recorder(prev)


# --------------------------------------------------------------------------
# schema round-trip
# --------------------------------------------------------------------------
def test_schema_roundtrip(tmp_path):
    d = str(tmp_path / "tel")
    with Recorder(d, flush_every=1) as r:
        r.counter("c.things", 2, host=0)
        r.gauge("g.depth", 3.5)
        r.observe("h.step_s", 0.01, step=1)
        r.event("e.fault", msg="[test] something happened", kind="host_loss")
        with r.span("s.phase", layer=0):
            pass
    path = os.path.join(d, "telemetry.jsonl")
    lines = open(path).read().splitlines()
    assert len(lines) == 5
    recs = validate_lines(lines)       # raises SchemaError on any bad line
    assert len(recs) == 5
    kinds = [json.loads(ln)["kind"] for ln in lines]
    assert kinds == ["counter", "gauge", "histogram", "event", "span"]


def test_schema_rejects_malformed():
    ok = {"ts": 1.0, "kind": "gauge", "name": "x", "value": 1}
    validate_record(ok)
    for bad in (
        {"kind": "gauge", "name": "x", "value": 1},            # no ts
        {"ts": 1.0, "kind": "nope", "name": "x"},              # bad kind
        {"ts": 1.0, "kind": "gauge", "name": "x"},             # no value
        {"ts": 1.0, "kind": "gauge", "name": "x", "value": "y"},
        {"ts": 1.0, "kind": "span", "name": "x", "dur_s": -1},
        {"ts": 1.0, "kind": "event", "name": "x", "bogus": 1},  # extra field
        {"ts": 1.0, "kind": "event", "name": "x",
         "tags": {"nested": {"a": 1}}},                        # non-flat tag
    ):
        with pytest.raises(SchemaError):
            validate_record(bad)


# --------------------------------------------------------------------------
# ring buffer / aggregates
# --------------------------------------------------------------------------
def test_ring_eviction():
    r = Recorder(ring_size=4)
    for i in range(10):
        r.gauge("g", i)
    assert len(r.ring) == 4
    assert [rec["value"] for rec in r.ring] == [6, 7, 8, 9]


def test_histogram_percentiles():
    r = Recorder()
    for v in range(1, 101):
        r.observe("h", v)
    assert r.percentile("h", 0) == 1
    assert r.percentile("h", 100) == 100
    assert r.percentile("h", 50) in (50, 51)      # nearest-rank
    assert r.percentile("h", 90) in (90, 91)
    assert r.percentile("h", 99) in (99, 100)
    assert r.percentile("missing", 50) is None
    s = r.summary()["histograms"]["h"]
    assert s["count"] == 100 and abs(s["mean"] - 50.5) < 1e-9


def test_counters_gauges_aggregate():
    r = Recorder()
    r.counter("c", 1)
    r.counter("c", 2)
    r.gauge("g", 7)
    r.gauge("g", 9)
    s = r.summary()
    assert s["counters"]["c"] == 3
    assert s["gauges"]["g"] == 9


def test_span_records_duration():
    r = Recorder()
    with r.span("phase", layer=3):
        time.sleep(0.001)
    rec = r.ring[-1]
    assert rec["kind"] == "span" and rec["name"] == "phase"
    assert rec["dur_s"] >= 0.001
    assert rec["tags"] == {"layer": 3}


def test_console_passthrough_keeps_legacy_lines():
    seen = []
    r = Recorder(console=seen.append)
    r.event("trainer.step", msg="[trainer] step 10 loss 2.0")
    r.gauge("g", 1)                    # non-events never hit the console
    assert seen == ["[trainer] step 10 loss 2.0"]


def test_flush_every_must_be_positive(tmp_path):
    with pytest.raises(ValueError, match="positive"):
        Recorder(str(tmp_path), flush_every=0)


# --------------------------------------------------------------------------
# disabled-mode overhead guard
# --------------------------------------------------------------------------
def test_null_recorder_overhead():
    """NullRecorder calls must stay near-zero (~0.1µs measured); the 2µs
    bound is generous for CI jitter but still catches an accidental
    allocation or dict build on the disabled path."""
    n = 200_000
    NULL.counter("warm")
    t0 = time.perf_counter()
    for _ in range(n):
        NULL.counter("x", 1, step=0)
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 2e-6, f"disabled-mode cost {per_call*1e9:.0f} ns/call"
    with NULL.span("x"):
        pass


# --------------------------------------------------------------------------
# overlap-probe residual math (synthetic group models — no jax needed)
# --------------------------------------------------------------------------
def _groups():
    from repro.obs.probe import GroupModel
    g1 = GroupModel(label="g0:attn[4/oases]x2", kind="attn",
                    schedule="oases", degree=4, layers=2,
                    compute_s=0.08, comm_s=0.02, predicted_s=0.09)
    g2 = GroupModel(label="g1:mlp[4/megatron]x2", kind="mlp",
                    schedule="megatron", degree=4, layers=2,
                    compute_s=0.02, comm_s=0.02, predicted_s=0.04)
    return [g1, g2]


def test_probe_predicted_fractions():
    g1, g2 = _groups()
    assert abs(g1.predicted_exposed_s - 0.01) < 1e-12
    assert abs(g1.predicted_exposed_frac - 0.5) < 1e-12
    assert abs(g2.predicted_exposed_frac - 1.0) < 1e-12


def test_probe_residual_math():
    from repro.obs.probe import OverlapProbe
    probe = OverlapProbe(_groups())
    # totals: compute 0.10, comm 0.04, modeled 0.13
    out = probe.report(0.12)
    # exposed = 0.12 - 0.10 = 0.02, split by equal comm share
    assert abs(out["measured_exposed_frac"] - 0.5) < 1e-9
    r1, r2 = out["groups"]
    assert abs(r1["measured_exposed_frac"] - 0.5) < 1e-9
    assert abs(r1["residual"] - 0.0) < 1e-9        # 0.08+0.01 vs 0.09
    assert abs(r2["residual"] - (-0.25)) < 1e-9    # 0.02+0.01 vs 0.04
    assert not out["calibration_stale"]            # (0.12-0.13)/0.13 ~ -8%


def test_probe_clamps_exposed():
    from repro.obs.probe import OverlapProbe
    probe = OverlapProbe(_groups())
    below = probe.report(0.05)         # under the compute floor
    assert below["measured_exposed_frac"] == 0.0
    above = probe.report(1.0)          # way over compute + comm
    assert above["measured_exposed_frac"] == 1.0   # clamped to comm total
    assert above["calibration_stale"]


def test_probe_emits_stale_event_through_recorder():
    from repro.obs.probe import OverlapProbe
    r = Recorder()
    OverlapProbe(_groups()).report(1.0, r, step=7)
    names = [rec["name"] for rec in r.ring]
    assert names.count("overlap.group") == 2
    assert "calibration_stale" in names
    assert abs(r.gauges["overlap.measured_exposed_frac"] - 1.0) < 1e-9
    stale = [rec for rec in r.ring if rec["name"] == "calibration_stale"][0]
    assert "re-run calibration" in stale["msg"]
    assert stale["tags"]["step"] == 7


def test_probe_skips_without_comm():
    from repro.obs.probe import GroupModel, OverlapProbe
    g = GroupModel(label="g0", kind="attn", schedule="oases", degree=1,
                   layers=2, compute_s=0.1, comm_s=0.0, predicted_s=0.1)
    r = Recorder()
    out = OverlapProbe([g]).report(0.2, r)
    assert out["skipped"] == "no-comm"
    assert r.ring[-1]["name"] == "overlap.skip"


# --------------------------------------------------------------------------
# straggler localization from enriched peer heartbeats
# --------------------------------------------------------------------------
def _write_hb(path, host, ewma):
    with open(path, "w") as f:
        json.dump({"step": 10, "time": time.time(), "host": host,
                   "step_time_s": ewma, "step_time_ewma_s": ewma}, f)


def test_straggler_localization(tmp_path):
    from repro.runtime.elastic import StragglerEscalation
    paths = {}
    for h, ewma in enumerate([0.10, 0.11, 0.10, 0.50]):
        p = str(tmp_path / f"hb{h}.json")
        _write_hb(p, h, ewma)
        paths[h] = p
    esc = StragglerEscalation(peer_paths=paths)
    host, detail = esc.localize()
    assert host == 3
    assert "h3=500.0ms" in detail

    # escalation carries the localized host into the FaultEvent
    esc = StragglerEscalation(peer_paths=paths, escalate_after=1)
    for step in range(8):
        assert esc.observe_step(step, 0.1) is None
    ev = esc.observe_step(8, 1.0)
    assert ev is not None and ev.kind == "straggler"
    assert ev.host == 3
    assert "per-host ewma" in ev.detail


def test_straggler_localization_no_outlier(tmp_path):
    from repro.runtime.elastic import StragglerEscalation
    paths = {}
    for h in range(3):
        p = str(tmp_path / f"hb{h}.json")
        _write_hb(p, h, 0.1)
        paths[h] = p
    assert StragglerEscalation(peer_paths=paths).localize()[0] is None
    # <2 readable peers -> no localization
    assert StragglerEscalation(
        peer_paths={0: paths[0]}).localize() == (None, "")


def test_read_heartbeat_tolerates_garbage(tmp_path):
    from repro.runtime.elastic import read_heartbeat
    assert read_heartbeat(str(tmp_path / "missing.json")) is None
    p = str(tmp_path / "bad.json")
    open(p, "w").write("{half a rec")
    assert read_heartbeat(p) is None


# --------------------------------------------------------------------------
# report CLI
# --------------------------------------------------------------------------
def test_report_render_and_validate(tmp_path, capsys):
    d = str(tmp_path / "tel")
    with Recorder(d, flush_every=1) as r:
        for i in range(5):
            r.observe("trainer.step_time_s", 0.01 * (i + 1), step=i)
        r.counter("serving.decoded_tokens", 64)
        r.gauge("serving.queue_depth", 2)
        r.event("overlap.group", group="g0:attn[4/oases]x2",
                schedule="oases", layers=2,
                predicted_exposed_frac=0.5, measured_exposed_frac=0.25,
                residual=-0.1)
        r.event("trainer.restore", msg="[trainer] restored step 5")
    from repro.obs import report
    assert report.main([d, "--validate"]) == 0
    out = capsys.readouterr().out
    assert "telemetry records OK" in out
    assert report.main([d]) == 0
    out = capsys.readouterr().out
    assert "per-phase breakdown" in out
    assert "trainer.step_time_s" in out
    assert "overlap efficiency" in out and "g0:attn[4/oases]x2" in out


def test_report_validate_catches_corruption(tmp_path, capsys):
    d = str(tmp_path / "tel")
    with Recorder(d, flush_every=1) as r:
        r.gauge("g", 1)
    with open(os.path.join(d, "telemetry.jsonl"), "a") as f:
        f.write('{"ts": 1.0, "kind": "nope", "name": "x"}\n')
    from repro.obs import report
    assert report.main([d, "--validate"]) == 1


# --------------------------------------------------------------------------
# schedule-phase tracing survives into compiled HLO
# --------------------------------------------------------------------------
def test_phase_scope_visible_in_compiled_hlo():
    import jax
    import jax.numpy as jnp

    from repro.obs.tracing import phase_scope

    def f(x):
        with phase_scope("obs_probe_scope"):
            return (x * 2.0).sum()

    txt = jax.jit(f).lower(jnp.ones((4, 4))).compile().as_text()
    assert "obs_probe_scope" in txt


def test_trace_annotation_is_reentrant():
    from repro.obs.tracing import trace_annotation
    with trace_annotation("outer"):
        with trace_annotation("inner"):
            pass


# --------------------------------------------------------------------------
# global recorder plumbing
# --------------------------------------------------------------------------
def test_configure_installs_global(tmp_path):
    d = str(tmp_path / "tel")
    r = rec_mod.configure(d, flush_every=1)
    try:
        assert rec_mod.get_recorder() is r
        rec_mod.get_recorder().gauge("g", 1)
        r.flush()
        assert len(validate_lines(
            open(os.path.join(d,
                              "telemetry.jsonl")).read().splitlines())) == 1
    finally:
        r.close()


def test_set_recorder_none_restores_null():
    rec_mod.set_recorder(Recorder())
    rec_mod.set_recorder(None)
    assert rec_mod.get_recorder() is NULL
