"""HLO cost walker: trip-count handling, collective ring factors, dot flops
— validated against modules with known costs (and against
compiled.cost_analysis() on loop-free graphs)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch import hlo_cost


def test_scan_trip_count_flops():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=8)
        return jnp.sum(y)

    w = jnp.ones((128, 128))
    x = jnp.ones((128, 128))
    comp = jax.jit(f).lower(w, x).compile()
    c = hlo_cost.analyze(comp.as_text())
    expected = 8 * 2 * 128 ** 3
    assert abs(c.dot_flops - expected) / expected < 0.01


def test_loop_free_matches_cost_analysis_flops():
    def f(a, b):
        return jnp.sum(jnp.tanh(a @ b))

    a = jnp.ones((256, 512))
    b = jnp.ones((512, 128))
    comp = jax.jit(f).lower(a, b).compile()
    c = hlo_cost.analyze(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per partition
        ca = ca[0]
    assert abs(c.dot_flops - ca["flops"]) / ca["flops"] < 0.05


def test_nested_scan_trip_multiplication():
    def f(w, x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = lax.scan(outer, x, None, length=8)
        return jnp.sum(y)

    w = jnp.ones((64, 64))
    x = jnp.ones((64, 64))
    comp = jax.jit(f).lower(w, x).compile()
    c = hlo_cost.analyze(comp.as_text())
    expected = 32 * 2 * 64 ** 3
    assert abs(c.dot_flops - expected) / expected < 0.01


def test_collective_parse_ring_factor():
    txt = """
HloModule test

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  ROOT %copy.1 = f32[128,256]{1,0} copy(%all-reduce.1)
}
"""
    c = hlo_cost.analyze(txt, default_group=4)
    payload = 128 * 256 * 4
    assert c.collective_counts.get("all-reduce") == 1
    np.testing.assert_allclose(c.collective_payload_bytes, payload)
    np.testing.assert_allclose(c.collective_link_bytes,
                               2 * payload * 3 / 4)


def test_iota_replica_groups():
    txt = """
ENTRY %main (p0: bf16[64]) -> bf16[64] {
  %p0 = bf16[64]{0} parameter(0)
  ROOT %all-reduce.2 = bf16[64]{0} all-reduce(%p0), replica_groups=[16,16]<=[256]T(1,0), to_apply=%add
}
"""
    c = hlo_cost.analyze(txt, default_group=1)
    assert c.collective_link_bytes == 2 * 64 * 2 * 15 / 16


def test_roofline_seconds_overlap_term():
    """serial = compute + comm; overlapped = max(compute, comm) — the
    fused-schedule bound; mxu_eff scales only the flop term."""
    c = hlo_cost.HloCost(dot_flops=2e12, hbm_bytes=1e9,
                         collective_link_bytes=5e9)
    r = c.roofline_seconds(peak_flops=1e12, hbm_bw=1e10, link_bw=1e9)
    assert r["compute_s"] == 2.0          # flop-bound (2e12/1e12 > 1e9/1e10)
    assert r["comm_s"] == 5.0
    np.testing.assert_allclose(r["serial_s"], 7.0)
    np.testing.assert_allclose(r["overlapped_s"], 5.0)   # comm-bound max
    # halving MXU efficiency doubles the flop term, flipping the bound
    r2 = c.roofline_seconds(peak_flops=1e12, hbm_bw=1e10, link_bw=1e9,
                            mxu_eff=0.25)
    np.testing.assert_allclose(r2["compute_s"], 8.0)
    np.testing.assert_allclose(r2["overlapped_s"], 8.0)
    # wired to the analyzer: terms from a parsed module feed through
    txt = """
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    parsed = hlo_cost.analyze(txt, default_group=4)
    rp = parsed.roofline_seconds(peak_flops=1e12, hbm_bw=1e10, link_bw=1e9)
    assert rp["comm_s"] > 0 and rp["overlapped_s"] <= rp["serial_s"]


def test_dus_inplace_not_overcounted():
    """A scan writing one row per step must cost O(rows), not O(rows^2)."""
    def f(x):
        buf = jnp.zeros((64, 128))
        def body(b, i):
            return lax.dynamic_update_index_in_dim(b, x, i, 0), None
        out, _ = lax.scan(body, buf, jnp.arange(64))
        return jnp.sum(out)

    x = jnp.ones((128,))
    comp = jax.jit(f).lower(x).compile()
    c = hlo_cost.analyze(comp.as_text())
    full_buffer_per_step = 64 * (64 * 128 * 4)
    assert c.hbm_bytes < 0.5 * full_buffer_per_step
