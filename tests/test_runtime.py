"""Fault taxonomy, monitors, failure injection, checkpoint integrity,
restart hardening, and elastic-supervisor units (runtime/elastic.py).

The full replan -> relayout -> loss-continuity path runs as a multidevice
subprocess test (tests/_scripts/elastic_replan.py via test_distributed.py);
these are the fast single-device units around it.
"""
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.base import ShapeConfig, TrainHParams
from repro.configs.registry import get_config
from repro.core.plan import ParallelPlan
from repro.core.planner import costmodel as cm
from repro.core.planner import ilp
from repro.runtime import elastic as el
from repro.runtime.trainer import (FailureInjector, StragglerDetector,
                                   corrupt_checkpoint, run_with_restarts)


def _reduced():
    return get_config("internlm2-1.8b").reduced().replace(dtype="float32")


# ---------------- straggler detection ----------------
def test_straggler_warmup_gate():
    det = StragglerDetector()
    det.observe(0, 1.0)
    # a 1000x outlier inside the warmup window must NOT flag: the EWMA
    # has no baseline yet
    for i in range(1, det.warmup):
        assert not det.observe(i, 1000.0)
    assert det.n == det.warmup


def test_straggler_ewma_tracks_mean():
    det = StragglerDetector()
    for i in range(100):
        det.observe(i, 2.0)
    assert abs(det.mean - 2.0) < 1e-3     # geometric convergence from 0
    assert det.var < 1e-3


def test_straggler_flags_and_records():
    det = StragglerDetector()
    for i in range(20):
        assert not det.observe(i, 1.0 + 0.01 * (i % 3))
    assert det.observe(20, 10.0)
    assert det.slow_steps[0][0] == 20


class _AlwaysSlow:
    """Stand-in detector: every step flags slow against a 1.0s baseline."""
    mean = 1.0

    def observe(self, step, dt):
        return True


class _NeverSlow:
    mean = 1.0

    def observe(self, step, dt):
        return False


def test_straggler_escalation_consecutive():
    esc = el.StragglerEscalation(detector=_AlwaysSlow(), escalate_after=3)
    assert esc.observe_step(0, 4.0) is None
    assert esc.observe_step(1, 4.0) is None
    ev = esc.observe_step(2, 4.0)
    assert ev is not None and ev.kind == "straggler"
    assert abs(ev.slowdown - 4.0) < 1e-9
    # the counter resets after escalating — no immediate re-fire
    assert esc.observe_step(3, 4.0) is None


def test_straggler_escalation_resets_on_healthy_step():
    class Alternating:
        mean = 1.0
        _n = 0

        def observe(self, step, dt):
            self._n += 1
            return self._n % 2 == 1       # slow, healthy, slow, ...

    esc = el.StragglerEscalation(detector=Alternating(), escalate_after=2)
    for i in range(10):                   # never 2 consecutive slow steps
        assert esc.observe_step(i, 5.0) is None


def test_straggler_escalation_never_fires_when_healthy():
    esc = el.StragglerEscalation(detector=_NeverSlow(), escalate_after=1)
    for i in range(5):
        assert esc.observe_step(i, 1.0) is None


# ---------------- heartbeat monitor ----------------
def test_heartbeat_monitor_stale_and_missing():
    now = [1000.0]
    with tempfile.TemporaryDirectory() as d:
        fresh, stale = os.path.join(d, "hb0"), os.path.join(d, "hb1")
        for path, t in ((fresh, 995.0), (stale, 100.0)):
            with open(path, "w") as f:
                json.dump({"step": 1, "time": t}, f)
        mon = el.HeartbeatMonitor(
            paths={0: fresh, 1: stale, 2: os.path.join(d, "never_written")},
            timeout_s=60.0, clock=lambda: now[0])
        evs = [mon.poll(7), mon.poll(7), mon.poll(7)]
        hosts = {e.host for e in evs if e is not None}
        assert hosts == {1, 2}            # stale + missing, each ONCE
        assert all(e.kind == "heartbeat-stale" for e in evs
                   if e is not None)
        assert mon.poll(8) is None        # already reported


def test_heartbeat_monitor_tolerates_torn_write():
    now = [1000.0]
    with tempfile.TemporaryDirectory() as d:
        torn = os.path.join(d, "hb0")
        with open(torn, "w") as f:
            f.write('{"step": 3, "ti')    # half-written JSON
        mon = el.HeartbeatMonitor(paths={0: torn}, timeout_s=60.0,
                                  clock=lambda: now[0])
        ev = mon.poll(0)                  # stale, not a crash
        assert ev is not None and ev.host == 0


# ---------------- failure injection ----------------
def test_injector_one_shot_per_mode():
    inj = FailureInjector(fail_at_steps=(3,), host_loss=((5, 1),),
                          link_degrade=((7, 2e9),))
    inj.check(0)
    with pytest.raises(RuntimeError):
        inj.check(3)
    inj.check(3)                          # consumed: resume revisits safely
    with pytest.raises(el.HostLossError) as ei:
        inj.check(5)
    assert ei.value.event.host == 1 and ei.value.event.step == 5
    inj.check(5)
    with pytest.raises(el.LinkDegradedError) as ei:
        inj.check(7)
    assert ei.value.event.link_bw == 2e9
    inj.check(7)


def test_injector_wrap_save_transient_then_ok():
    inj = FailureInjector(ckpt_fail_saves=2)
    wrapped = inj.wrap_save()
    tree = {"a": np.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        for _ in range(2):
            with pytest.raises(OSError):
                wrapped(d, 1, tree)
        wrapped(d, 1, tree)               # third attempt lands
        assert store.latest_step(d) == 1


def test_injector_wrap_save_corrupts_committed_step():
    inj = FailureInjector(corrupt_at_steps=(2,))
    wrapped = inj.wrap_save()
    tree = {"a": np.arange(64.0)}
    with tempfile.TemporaryDirectory() as d:
        wrapped(d, 1, tree)
        wrapped(d, 2, tree)
        assert store.verify(d, 1)
        assert not store.verify(d, 2)
        assert store.latest_intact_step(d) == 1


def test_injector_passthrough_when_no_ckpt_faults():
    inj = FailureInjector(fail_at_steps=(1,))
    assert inj.wrap_save(store.save) is store.save


# ---------------- checkpoint integrity ----------------
def test_corrupt_checkpoint_detected_on_restore():
    tree = {"a": jnp.arange(64.0), "b": {"c": jnp.ones((8,))}}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 1, tree)
        path = store.save(d, 2, tree)
        corrupt_checkpoint(path)
        with pytest.raises(store.CorruptCheckpointError):
            store.restore(d, 2, tree)
        assert store.latest_intact_step(d) == 1
        out, _ = store.restore(d, 1, tree)   # intact neighbor still loads
        np.testing.assert_array_equal(out["a"], tree["a"])


def test_crc_mismatch_detected_even_with_valid_zip():
    # rewrite the shard as a VALID npz with different values: only the
    # manifest crc32 can catch this class of corruption
    tree = {"a": jnp.arange(16.0)}
    with tempfile.TemporaryDirectory() as d:
        path = store.save(d, 5, tree)
        np.savez(os.path.join(path, "shard_0.npz"),
                 a0=np.arange(16.0) + 1.0)
        assert not store.verify(d, 5)
        with pytest.raises(store.CorruptCheckpointError) as ei:
            store.restore(d, 5, tree)
        assert "integrity" in str(ei.value)


def test_garbled_manifest_is_corrupt_not_crash():
    tree = {"a": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        path = store.save(d, 1, tree)
        with open(os.path.join(path, "manifest.json"), "w") as f:
            f.write("{not json")
        with pytest.raises(store.CorruptCheckpointError):
            store.restore(d, 1, tree)
        assert store.latest_intact_step(d) is None


def test_async_checkpointer_retries_transient_oserror():
    calls = {"n": 0}

    def flaky(ckpt_dir, step, tree, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("transient")
        return store.save(ckpt_dir, step, tree, **kw)

    tree = {"a": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = store.AsyncCheckpointer(d, retries=2, backoff_s=0.0,
                                     save_fn=flaky)
        ck.save(1, tree)
        ck.wait()                          # retry succeeded: no raise
        assert ck.failed_saves == 1
        assert store.latest_step(d) == 1


def test_async_checkpointer_surfaces_exhausted_retries():
    def broken(ckpt_dir, step, tree, **kw):
        raise OSError("disk on fire")

    with tempfile.TemporaryDirectory() as d:
        ck = store.AsyncCheckpointer(d, retries=1, backoff_s=0.0,
                                     save_fn=broken)
        ck.save(1, {"a": jnp.zeros(2)})
        with pytest.raises(OSError):
            ck.wait()
        assert ck.failed_saves == 2        # initial attempt + 1 retry


# ---------------- run_with_restarts hardening ----------------
class _FakeTrainer:
    def __init__(self, outcomes):
        self.outcomes = outcomes           # shared mutable list
        self.log = lambda *a, **k: None

    def train(self, total_steps, **kw):
        out = self.outcomes.pop(0)
        if isinstance(out, BaseException):
            raise out
        return out


def _factory(outcomes, calls):
    def make():
        calls.append(1)
        return _FakeTrainer(outcomes)
    return make


def test_restarts_recover_then_return():
    calls = []
    ok = {"losses": [1.0], "final_step": 2, "slow_steps": []}
    make = _factory([RuntimeError("boom"), ok], calls)
    res = run_with_restarts(make, 2, backoff_s=0.001)
    assert res["final_step"] == 2 and len(calls) == 2


def test_restarts_never_catch_keyboard_interrupt():
    calls = []
    make = _factory([KeyboardInterrupt()], calls)
    with pytest.raises(KeyboardInterrupt):
        run_with_restarts(make, 2)
    assert len(calls) == 1                 # no restart attempt


def test_restarts_never_catch_system_exit():
    calls = []
    make = _factory([SystemExit(3)], calls)
    with pytest.raises(SystemExit):
        run_with_restarts(make, 2)
    assert len(calls) == 1


def test_restarts_respect_restartable_tuple():
    # default tuple: a ValueError is a code defect, not a fault
    calls = []
    make = _factory([ValueError("bug")], calls)
    with pytest.raises(ValueError):
        run_with_restarts(make, 2)
    assert len(calls) == 1
    # opting ValueError in makes it restartable
    calls = []
    ok = {"losses": [], "final_step": 1, "slow_steps": []}
    make = _factory([ValueError("flaky"), ok], calls)
    res = run_with_restarts(make, 1, restartable=(ValueError,),
                            backoff_s=0.0)
    assert res["final_step"] == 1 and len(calls) == 2


def test_restarts_bounded():
    calls = []
    make = _factory([RuntimeError(i) for i in range(10)], calls)
    with pytest.raises(RuntimeError):
        run_with_restarts(make, 2, max_restarts=2, backoff_s=0.0)
    assert len(calls) == 3                 # initial + 2 restarts


def test_restarts_refuse_topology_faults():
    # a FaultError IS a RuntimeError, but restarting the same mesh cannot
    # bring a lost host back — must escalate, not loop
    calls = []
    make = _factory([el.HostLossError(4, 1)], calls)
    with pytest.raises(el.HostLossError):
        run_with_restarts(make, 2, max_restarts=5)
    assert len(calls) == 1


# ---------------- degraded HWConfig ----------------
def test_hwconfig_degrade_clamps():
    hw = cm.V5E
    d = hw.degrade(lost_chips=hw.n_chips + 5)
    assert d.n_chips == 1 and d.node_size <= 1
    d = hw.degrade(n_chips=3)
    assert d.n_chips == 3 and d.node_size <= 3
    assert hw.n_chips != 3                 # original untouched (frozen)


def test_hwconfig_degrade_link_floor_and_scale():
    hw = cm.COMMODITY_25GBE
    d = hw.degrade(link_bw_y=0.0)
    assert d.link_bw_y == 1.0              # floored: never divide by zero
    d = hw.degrade(bw_scale=0.5)
    assert d.link_bw == hw.link_bw * 0.5
    # 0.0 sentinel fields (fall back to link_bw) stay 0.0 under scaling
    if hw.link_bw_x == 0.0:
        assert d.link_bw_x == 0.0


def test_topology_degraded_hw():
    topo = el.Topology(n_hosts=4, chips_per_host=2)
    hw = topo.lose(3).degraded_hw(cm.V5E)
    assert hw.n_chips == 6 and hw.node_size == 2


# ---------------- topology ----------------
def test_topology_lose_and_refuse_last():
    t = el.Topology(n_hosts=2, chips_per_host=4)
    assert t.n_chips == 8
    t2 = t.lose(1)
    assert t2.alive_hosts == (0,) and t2.n_chips == 4
    with pytest.raises(ValueError):
        t2.lose(1)                         # already dead
    with pytest.raises(ValueError):
        t2.lose(0)                         # cannot lose the last host
    with pytest.raises(ValueError):
        t.lose(7)                          # not a host


def test_topology_devices_contiguous_slices():
    devs = list("abcdefgh")                # stand-in device list
    t = el.Topology(n_hosts=4, chips_per_host=2).lose(1)
    assert t.devices(devs) == ["a", "b", "e", "f", "g", "h"]


def test_topology_link_degrade_floor():
    t = el.Topology(n_hosts=2, chips_per_host=1).degrade_link(0.0)
    assert t.link_bw_y == 1.0


# ---------------- replanning ----------------
def test_replan_clamps_options_and_is_executable():
    cfg = _reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    hp = TrainHParams(schedule="oases")
    hw = cm.V5E.degrade(n_chips=4, node_size=4)
    pr = ilp.replan(cfg, shape, hp, hw, options=(2, 4, 8, 16),
                    time_limit=2.0)
    plan = pr.plan.validate_for(cfg)       # executable, right layer count
    assert plan.mesh_shape and int(np.prod(plan.mesh_shape)) <= 4
    # uniform mesh-following form: runs on a plain (data, model) mesh
    assert plan.planned_degrees is None
    assert len(set(plan.schedules)) == 1


def test_replan_single_chip_limit():
    cfg = _reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    hp = TrainHParams(schedule="oases")
    hw = cm.V5E.degrade(n_chips=1, node_size=1)
    pr = ilp.replan(cfg, shape, hp, hw, time_limit=2.0)
    assert int(np.prod(pr.plan.mesh_shape)) == 1


def test_supervisor_fallback_plan_clamps_to_survivors():
    cfg = _reduced()
    hp = TrainHParams(schedule="oases")
    sup = el.ElasticSupervisor(
        make_trainer=None, topology=el.Topology(n_hosts=2, chips_per_host=2),
        cfg=cfg, shape=ShapeConfig("t", 64, 4, "train"), hp=hp,
        log_fn=lambda *a: None)
    big = ParallelPlan.from_hparams(hp, cfg.num_layers,
                                    mesh_shape=(2, 4),
                                    mesh_axes=("data", "model"))
    fb = sup._fallback_plan(big)           # 8-chip plan, 4 survivors
    assert int(np.prod(fb.mesh_shape)) <= 4
    assert fb.primary_schedule == big.primary_schedule
    small = ParallelPlan.from_hparams(hp, cfg.num_layers, mesh_shape=(1, 2),
                                      mesh_axes=("data", "model"))
    assert sup._fallback_plan(small) is small   # still fits: unchanged
    assert sup._fallback_plan(None) is None


def test_fault_event_roundtrip_through_errors():
    ev = el.FaultEvent("host-loss", step=9, host=2, detail="nic down")
    err = el.fault_from_event(ev)
    assert isinstance(err, el.HostLossError)
    assert err.event.host == 2 and err.event.step == 9
    assert isinstance(el.fault_from_event(
        el.FaultEvent("link-degraded", step=1, link_bw=5e9)),
        el.LinkDegradedError)
    generic = el.fault_from_event(el.FaultEvent("heartbeat-stale", host=1))
    assert type(generic) is el.FaultError
