"""Hypothesis property tests for Planner v2 (skipped gracefully when
hypothesis is absent — see conftest.optional_hypothesis).

Properties pinned here:
* ``plan()`` always returns a *feasible* partition for random
  ArchConfig/HWConfig draws: one degree per layer, every total a power of
  two within the option space, every 2D dy dividing d_model (the per-axis
  decomposition slices the contraction dim), and the memory bound holds
  whenever the ILP reports an optimal solve.
* the 2D search space never loses to 1D (it contains it).
* ``overlapped_time(d, c, s)`` is monotone in d and c, never below
  max(d, c), never above the serial sum; the 2D composition degenerates to
  it at c_y == 0 and obeys the same bounds.
"""
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.configs.base import ArchConfig, ShapeConfig, TrainHParams
from repro.core.planner import (estimate_iteration, overlapped_time,
                                overlapped_time_2d, plan)
from repro.core.planner.costmodel import HWConfig, _dtot, _dxy

SHAPE = ShapeConfig("prop_train", 512, 16, "train")


def _arch(num_layers, d_model, heads, ff_mult):
    return ArchConfig(
        name="prop", family="dense", num_layers=num_layers,
        d_model=d_model, num_heads=heads, num_kv_heads=heads // 2 or 1,
        d_ff=d_model * ff_mult, vocab_size=1024, head_dim=d_model // heads)


def _hw(n_chips, node_size, bw, bw_x, bw_y):
    return HWConfig(n_chips=n_chips, node_size=node_size, peak_flops=1e14,
                    hbm_bw=8e11, link_bw=bw, link_bw_x=bw_x, link_bw_y=bw_y,
                    hbm_cap=32e9)


@settings(max_examples=10, deadline=None)
@given(num_layers=st.integers(2, 5),
       d_model=st.sampled_from([128, 256, 512]),
       heads=st.sampled_from([4, 8]),
       ff_mult=st.sampled_from([2, 4]),
       n_chips=st.sampled_from([8, 16]),
       node_size=st.sampled_from([0, 4, 8]),
       bw=st.floats(1e9, 1e11),
       bw_x=st.sampled_from([0.0, 5e10, 2e11]),
       bw_y=st.sampled_from([0.0, 2e9, 1e10]),
       layout=st.sampled_from(["1d", "2d", "auto"]),
       schedule=st.sampled_from(["oases", "megatron", "fused"]))
def test_plan_feasible(num_layers, d_model, heads, ff_mult, n_chips,
                       node_size, bw, bw_x, bw_y, layout, schedule):
    cfg = _arch(num_layers, d_model, heads, ff_mult)
    hw = _hw(n_chips, node_size, bw, bw_x, bw_y)
    hp = TrainHParams(schedule=schedule)
    options = tuple(n for n in (2, 4, 8, 16) if n <= n_chips)
    r = plan(cfg, SHAPE, hp, hw, options=options, layout=layout,
             mem_cap=64e9)
    assert len(r.degrees) == cfg.num_layers
    for d in r.degrees:
        dx, dy = _dxy(d)
        total = dx * dy
        assert total in options, d
        assert dx & (dx - 1) == 0 and dy & (dy - 1) == 0, d
        if dy > 1:
            assert cfg.d_model % dy == 0, d       # proj slices d_model
            ns = hw.node_size or hw.n_chips
            assert dx <= ns, d                    # x-ring stays intra-node
    est = estimate_iteration(cfg, SHAPE, hp, r.degrees, hw)
    assert est["iter_s"] > 0
    if not r.status.startswith("fallback"):
        assert est["mem_bytes"] < 64e9 * 1.05


@settings(max_examples=8, deadline=None)
@given(num_layers=st.integers(2, 4),
       d_model=st.sampled_from([256, 512]),
       heads=st.sampled_from([4, 8]),
       bw_y=st.sampled_from([2e9, 1e10]),
       schedule=st.sampled_from(["oases", "fused"]))
def test_2d_space_never_loses_to_1d(num_layers, d_model, heads, bw_y,
                                    schedule):
    """The 2D option space contains every 1D point, so the planned time
    under layout='auto' can never exceed the best 1D plan."""
    cfg = _arch(num_layers, d_model, heads, 2)
    hw = _hw(16, 8, bw_y, 1e11, bw_y)
    hp = TrainHParams(schedule=schedule)
    p1 = plan(cfg, SHAPE, hp, hw, layout="1d")
    p2 = plan(cfg, SHAPE, hp, hw, layout="auto")
    assert p2.predicted_s <= p1.predicted_s * (1 + 1e-6)


@settings(max_examples=50, deadline=None)
@given(d=st.floats(0.0, 10.0), c=st.floats(0.0, 10.0),
       eps=st.floats(0.0, 5.0), steps=st.integers(1, 16))
def test_overlapped_time_monotone_and_bounded(d, c, eps, steps):
    t = overlapped_time(d, c, steps)
    assert t >= max(d, c) - 1e-12
    assert t <= d + c + 1e-12
    assert overlapped_time(d + eps, c, steps) >= t - 1e-12    # mono in d
    assert overlapped_time(d, c + eps, steps) >= t - 1e-12    # mono in c


@settings(max_examples=50, deadline=None)
@given(d=st.floats(0.0, 10.0), cx=st.floats(0.0, 10.0),
       cy=st.floats(0.0, 10.0), eps=st.floats(0.0, 5.0),
       steps=st.integers(1, 16))
def test_overlapped_time_2d_laws(d, cx, cy, eps, steps):
    t = overlapped_time_2d(d, cx, cy, steps)
    assert t >= max(d, cx) - 1e-12
    assert t >= cy - 1e-12
    assert t <= d + cx + cy + 1e-12
    # degenerates to the 1D law when there is no y traffic
    assert overlapped_time_2d(d, cx, 0.0, steps) == \
        pytest.approx(overlapped_time(d, cx, steps))
    # monotone in every argument
    assert overlapped_time_2d(d + eps, cx, cy, steps) >= t - 1e-12
    assert overlapped_time_2d(d, cx + eps, cy, steps) >= t - 1e-12
    assert overlapped_time_2d(d, cx, cy + eps, steps) >= t - 1e-12


def test_dtot_dxy_roundtrip():
    assert _dxy(8) == (8, 1) and _dtot(8) == 8
    assert _dxy((4, 2)) == (4, 2) and _dtot((4, 2)) == 8
