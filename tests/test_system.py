"""End-to-end behaviour tests for the whole system (single-device mesh)."""
import jax

from repro.core import compat
from repro.configs.base import TrainHParams
from repro.configs.registry import get_config
from repro.launch import steps as steps_mod
from repro.models import params as prm
from repro.optim import adamw
from repro.core.axes import mesh_info


def test_train_step_improves_loss_on_fixed_batch(smoke_mesh):
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    hp = TrainHParams(learning_rate=3e-3, warmup_steps=1, total_steps=50)
    fn, specs = steps_mod.build_train_step(cfg, smoke_mesh, hp,
                                           global_batch=2, seq_len=32)
    info = mesh_info(smoke_mesh)
    params = prm.init_params(specs, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params, specs, info)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (2, 32), 0, cfg.vocab_size)}
    step = jax.jit(fn)
    with compat.set_mesh(smoke_mesh):
        losses = []
        for _ in range(12):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatched_step_matches_full_batch(smoke_mesh):
    """Gradient accumulation must not change the loss value."""
    cfg = get_config("internlm2-1.8b").reduced().replace(dtype="float32")
    k = jax.random.PRNGKey(1)
    tokens = jax.random.randint(k, (4, 32), 0, cfg.vocab_size)
    labels = jax.random.randint(k, (4, 32), 0, cfg.vocab_size)

    def one(hp, batch):
        fn, specs = steps_mod.build_train_step(cfg, smoke_mesh, hp,
                                               global_batch=4, seq_len=32)
        info = mesh_info(smoke_mesh)
        params = prm.init_params(specs, jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params, specs, info)
        with compat.set_mesh(smoke_mesh):
            _, _, m = jax.jit(fn)(params, opt, batch)
        return float(m["loss"])

    l_full = one(TrainHParams(microbatch=1),
                 {"tokens": tokens, "labels": labels})
    l_micro = one(TrainHParams(microbatch=2),
                  {"tokens": tokens.reshape(2, 2, 32),
                   "labels": labels.reshape(2, 2, 32)})
    assert abs(l_full - l_micro) < 1e-4


def test_input_specs_cover_all_cells(smoke_mesh):
    """input_specs() must produce valid abstract inputs for every
    applicable (arch x shape) cell without allocating."""
    from repro.configs.registry import all_cells
    for cfg, shape, applicable in all_cells():
        if not applicable:
            continue
        got = steps_mod.input_specs(cfg, shape, smoke_mesh, TrainHParams())
        leaves = jax.tree_util.tree_leaves(got)
        assert all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in leaves)
