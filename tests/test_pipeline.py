"""In-process pipeline-parallel units: the mesh algebra of the 'pipe'
axis, the stage-layout/microbatch validation, the bubble + P2P cost
composition, and the joint PP x TMP planner goldens on the two fixture
HWConfigs (the acceptance shape of the subsystem — execution equivalence
lives in tests/_scripts/pipeline_equivalence.py under the multidevice
tier)."""
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P  # noqa: F401

from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.configs.registry import get_config
from repro.core import pipeline as pl
from repro.core.axes import batch_pspec, mesh_info
from repro.core.planner import (COMMODITY_25GBE, NVLINK_BOX, p2p_hop_seconds,
                                pipeline_time, plan_joint, stage_hw)
from repro.models import params as prm


def _info(*shape_axes):
    return mesh_info(AbstractMesh(tuple(shape_axes)))


# --------------------------------------------------------------------------
# mesh algebra
# --------------------------------------------------------------------------
def test_mesh_info_detects_pipe_axis():
    info = _info(("pipe", 2), ("data", 2), ("model", 2))
    assert info.pipe_axes == ("pipe",)
    assert info.pp == 2 and info.dp == 2 and info.tp == 2
    assert info.model_axes == ("model",)


def test_pipe_axis_never_carries_the_batch():
    info = _info(("pipe", 4), ("data", 2), ("model", 1))
    assert batch_pspec(info, 8) == P(("data",))
    assert pl.pipeline_batch_axes(info) == ("data", "pipe")


def test_plain_mesh_has_pp_one():
    assert _info(("data", 2), ("model", 4)).pp == 1


# --------------------------------------------------------------------------
# stage layout + microbatch resolution
# --------------------------------------------------------------------------
def test_stage_layout_validation():
    cfg = get_config("internlm2-1.8b").reduced().replace(num_layers=4)
    assert pl.validate_stage_layout(cfg, 4, 0, 2, 2) == 1
    assert pl.validate_stage_layout(cfg, 4, 0, 4, 1) == 1
    with pytest.raises(ValueError, match="equal pipeline stages"):
        pl.validate_stage_layout(cfg, 4, 0, 2, 3)
    with pytest.raises(ValueError, match="tail"):
        pl.validate_stage_layout(cfg, 4, 1, 2, 1)
    enc = get_config("whisper-small").reduced()
    with pytest.raises(ValueError, match="encoder-decoder"):
        pl.validate_stage_layout(enc, 4, 0, 2, 1)


def test_pipeline_specs_flatten_to_canonical_layer_order():
    """The [v, pp, n/S] stacking must be a pure reshape of [n] — the
    property both the oracle-equivalence tests and the elastic checkpoint
    path rely on."""
    cfg = get_config("internlm2-1.8b").reduced().replace(num_layers=4)
    flat = prm.model_specs(cfg, _info(("data", 2), ("model", 2)))
    pipe = prm.model_specs(cfg, _info(("pipe", 2), ("data", 1), ("model", 2)),
                           virtual_stages=2)
    for a, b in zip(prm.tree_map_specs(lambda s: s, flat["blocks"]),
                    prm.tree_map_specs(lambda s: s, pipe["blocks"])):
        for (ka, sa), (kb, sb) in zip(sorted(a.items()), sorted(b.items())):
            assert ka == kb
            assert sb.shape[:3] == (2, 2, 1)
            assert sb.shape[3:] == sa.shape[1:]
            assert tuple(sb.pspec)[:3] == (None, "pipe", None)
    assert pipe["tail"] == []
    # embed/head stay replicated over pipe
    assert "pipe" not in tuple(pipe["embed"].pspec)


def test_pipeline_rejects_planner_degrees():
    cfg = get_config("internlm2-1.8b").reduced()
    with pytest.raises(ValueError, match="planner strategies"):
        prm.model_specs(cfg, _info(("pipe", 2), ("data", 1), ("model", 2)),
                        degrees=[2, 2])


def test_resolve_microbatch():
    assert pl.resolve_microbatch(8, 2) == 4       # 2*pp capped by divisors
    assert pl.resolve_microbatch(8, 4) == 8
    assert pl.resolve_microbatch(6, 2) == 3       # largest divisor <= 4
    assert pl.resolve_microbatch(8, 2, requested=2) == 2
    with pytest.raises(ValueError, match="divisor"):
        pl.resolve_microbatch(8, 2, requested=3)


def test_bubble_fraction():
    assert pl.bubble_fraction(1, 8) == 0.0
    assert pl.bubble_fraction(4, 4) == pytest.approx(3 / 7)
    # interleaving shrinks the bubble
    assert pl.bubble_fraction(4, 4, 2) < pl.bubble_fraction(4, 4, 1)


# --------------------------------------------------------------------------
# cost composition
# --------------------------------------------------------------------------
def test_pipeline_time_degenerates_at_pp1():
    assert pipeline_time(1.0, 1, 8) == (1.0, 0.0, 0.0)


def test_pipeline_time_bubble_and_p2p():
    total, bfrac, p2p = pipeline_time(1.0, 2, 8, 1, 0.0)
    # busy 0.5 + one microbatch-slot bubble 1/(2*8)
    assert total == pytest.approx(0.5 + 1.0 / 16)
    assert bfrac == pytest.approx((1.0 / 16) / total)
    assert p2p == 0.0
    # more microbatches or interleaving shrink the bubble
    assert pipeline_time(1.0, 2, 16)[0] < total
    assert pipeline_time(1.0, 2, 8, 2)[0] < total
    # P2P hops land on the critical path
    t_hop = 0.01
    assert pipeline_time(1.0, 2, 8, 1, t_hop)[2] >= 2 * t_hop


def test_stage_hw_and_hop_bandwidth():
    hw = stage_hw(COMMODITY_25GBE, 2)
    assert hw.n_chips == 8 and hw.node_size == 8
    cfg, _t, _d, gb = PAPER_TABLE4["gpt-h8192"]
    shape = paper_shape(gb)
    # stage == node: the hop crosses the NIC; fewer microbatches = fatter hop
    slow = p2p_hop_seconds(cfg, shape, COMMODITY_25GBE, 2, 4, 8)
    fast = p2p_hop_seconds(cfg, shape, NVLINK_BOX, 2, 4, 8)
    assert slow > fast
    assert p2p_hop_seconds(cfg, shape, COMMODITY_25GBE, 2, 8, 8) < slow


# --------------------------------------------------------------------------
# joint PP x TMP planner goldens (PR acceptance)
# --------------------------------------------------------------------------
def _joint(schedule, hw, **kw):
    cfg, _tmp, _dp, gb = PAPER_TABLE4["gpt-h8192"]
    return plan_joint(cfg, paper_shape(gb), TrainHParams(schedule=schedule),
                      hw, **kw)


@pytest.mark.parametrize("schedule", ["oases", "fused", "megatron"])
def test_joint_plan_spanning_regime_golden(schedule):
    """When the weights must spread over all 16 chips (the spanning
    regime, options=(16,)), the joint search places pipeline stages
    ACROSS the two commodity boxes and keeps TMP rings within a box —
    and its modeled time beats the best TMP-only plan (which must ring
    through the NIC).  On the uniform NVLink box PP buys nothing."""
    r = _joint(schedule, COMMODITY_25GBE, options=(16,))
    assert r.pp == 2, r.summary()
    assert all(d == 8 for d in r.degrees), r.summary()
    assert r.predicted_s <= r.tmp_only_s, r.summary()
    assert r.fits and r.status == "0", r.summary()
    assert 0.0 < r.bubble_fraction < 0.25, r.summary()

    n = _joint(schedule, NVLINK_BOX, options=(16,))
    assert n.pp == 1, n.summary()
    assert n.predicted_s == pytest.approx(n.tmp_only_s)


@pytest.mark.parametrize("fixture", [COMMODITY_25GBE, NVLINK_BOX])
def test_joint_plan_free_space_stays_tmp_only(fixture):
    """With memory to spare PP is pure overhead (bubble + hops): the
    joint search must agree with the TMP-only planner."""
    r = _joint("oases", fixture)
    assert r.pp == 1, r.summary()
    assert r.predicted_s == pytest.approx(r.tmp_only_s)


def test_joint_pp_candidates_are_executable():
    """pp options must divide the scan-GROUP count (num_layers/|pattern|),
    not num_layers — what validate_stage_layout enforces at training
    time."""
    from repro.core.planner.ilp import _default_pp_options
    cfg = get_config("gemma2-9b")            # 42 layers, 2-kind pattern
    groups = cfg.num_layers // len(cfg.layer_pattern)
    for v in (1, 2):
        for p in _default_pp_options(cfg, COMMODITY_25GBE, v):
            if p > 1:
                assert groups % (p * v) == 0, (p, v)
                pl.validate_stage_layout(cfg, groups, 0, p, v)


def test_joint_microbatch_candidates_always_divide_the_batch():
    """The planner must never recommend a microbatch count the runtime
    (pl.resolve_microbatch) would reject."""
    from repro.configs.base import ShapeConfig
    from repro.core.planner.ilp import _default_microbatch_options
    for gb in (8, 12, 6, 7):
        for pp in (2, 4, 8):
            for m in _default_microbatch_options(pp, 1,
                                                 ShapeConfig("t", 64, gb,
                                                             "train")):
                assert m >= 1 and gb % m == 0, (gb, pp, m)


def test_joint_plan_interleaving_shrinks_predicted_time():
    r1 = _joint("oases", COMMODITY_25GBE, options=(16,), virtual_stages=1)
    r2 = _joint("oases", COMMODITY_25GBE, options=(16,), virtual_stages=2)
    assert r2.pp == 2 and r2.bubble_fraction < r1.bubble_fraction


def test_joint_plan_survives_a_one_chip_host():
    """The --calibrate flow runs plan_joint with whatever
    HWConfig.from_measurements saw — on a 1-device host every option
    clamps to degree 1 and the search must still return a plan instead
    of raising."""
    from repro.configs.base import ShapeConfig
    from repro.core.planner.costmodel import HWConfig
    cfg = get_config("internlm2-1.8b")
    r = plan_joint(cfg, ShapeConfig("t", 4096, 256, "train"),
                   TrainHParams(), HWConfig(n_chips=1, node_size=1))
    assert r.pp == 1 and all(d == 1 for d in r.degrees)


def test_pipeline_mem_scales():
    """Weights shrink 1/stages; live activations keep their in-flight
    factor (a 1F1B stage holds min(stages, n_micro) microbatches)."""
    from repro.core.planner.costmodel import pipeline_mem_scales
    assert pipeline_mem_scales(1, 0) == (1.0, 1.0)
    assert pipeline_mem_scales(4, 8) == (0.25, 1.0)     # full in-flight
    assert pipeline_mem_scales(4, 2) == (0.25, 0.5)     # m < stages
    assert pipeline_mem_scales(2, 0) == (0.5, 1.0)      # auto m >= stages


def test_joint_plan_n_micro_divides_the_per_shard_batch():
    """The winning plan must be executable: n_micro must divide the
    per-dp-shard batch under the plan's own degrees (what
    pipeline.resolve_microbatch enforces at launch)."""
    r = _joint("oases", COMMODITY_25GBE, options=(8,), pp_options=[2])
    deg = max(d if isinstance(d, int) else d[0] * d[1] for d in r.degrees)
    dp = (COMMODITY_25GBE.n_chips // r.pp) // deg
    local = PAPER_TABLE4["gpt-h8192"][3] // max(dp, 1)
    assert local % r.n_micro == 0, r.summary()
    pl.resolve_microbatch(local, r.pp, r.virtual_stages, r.n_micro)


# --------------------------------------------------------------------------
# elastic checkpoint restacking guard
# --------------------------------------------------------------------------
def test_restore_reshapes_stage_stacking_but_rejects_transposes(tmp_path):
    import numpy as np

    from repro.checkpoint import store
    n, d1, d2 = 4, 6, 10
    tree = {"w": np.arange(n * d1 * d2, dtype=np.float32
                           ).reshape(n, d1, d2)}
    store.save(str(tmp_path), 1, tree)
    # PP restacking [n, ...] -> [v, pp, n/S, ...]: pure reshape, allowed
    like = {"w": np.zeros((2, 2, 1, d1, d2), np.float32)}
    restored, _meta = store.restore(str(tmp_path), 1, like)
    assert np.array_equal(np.asarray(restored["w"]).reshape(n, d1, d2),
                          tree["w"])
    # PP -> PP with a different (pp, v): also a pure restacking
    store.save(str(tmp_path), 2, {"w": tree["w"].reshape(1, 2, 2, d1, d2)})
    restored, _meta = store.restore(
        str(tmp_path), 2, {"w": np.zeros((2, 2, 1, d1, d2), np.float32)})
    assert np.array_equal(np.asarray(restored["w"]).reshape(n, d1, d2),
                          tree["w"])
    # transposed per-layer dims: same element count, NOT a restacking —
    # must fail loudly instead of restoring scrambled weights
    with pytest.raises(ValueError, match="restacking"):
        store.restore(str(tmp_path), 1, {"w": np.zeros((n, d2, d1),
                                                       np.float32)})
